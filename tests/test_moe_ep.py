"""Expert-parallel MoE (shard_map, §Perf-2) vs the pure-GSPMD baseline:
values and gradients must match on a real multi-device mesh. Runs in a
subprocess because the forced 8-device host platform must be configured
before jax initializes (the main test process keeps 1 device)."""
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_arch
from repro.models import moe as M

mesh = jax.make_mesh((2, 4), ('data', 'model'))
for arch in ['qwen3-moe-235b-a22b', 'kimi-k2-1t-a32b']:
    cfg = get_arch(arch).smoke()
    p = M.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
    y0, a0 = M.moe_apply(p, cfg, x)
    with mesh:
        y1, a1 = jax.jit(lambda p, x: M.moe_apply_ep(p, cfg, x, mesh))(p, x)
        g0 = jax.grad(lambda p: M.moe_apply(p, cfg, x)[0].sum())(p)
        g1 = jax.jit(jax.grad(
            lambda p: M.moe_apply_ep(p, cfg, x, mesh)[0].sum()))(p)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(float(a1), float(a0), rtol=1e-4, atol=1e-5)
    for k0, k1 in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(k1), np.asarray(k0),
                                   rtol=2e-3, atol=2e-3)
    print(arch, 'OK')
print('EP-MATCH')
"""


@pytest.mark.timeout(600)
def test_moe_ep_matches_baseline_on_mesh():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=580,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "HOME": "/root"})
    assert "EP-MATCH" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
