"""The asynchronous role-based league runtime (ISSUE 3): sync/async lineage
equivalence under a step-count gate, winrate-gated freezing, exploiter
reset-on-freeze, LeagueMgr report/PBT bugfixes, and producer/consumer/
hot-swap concurrency on the data plane."""
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FreezeGate, LeagueMgr, MatchResult, ModelKey,
                        ModelPool)
from repro.league import LeagueSpec, RoleSpec, build_runtime
from repro.learners import DataServer


def mk(v, agent="main"):
    return ModelKey(agent, v)


def res(a, b, outcome):
    return MatchResult(learner_key=a, opponent_keys=(b,), outcome=outcome)


# ---------------------------------------------------------------------------
# Freeze gating (LeagueMgr + FreezeGate semantics)
# ---------------------------------------------------------------------------
def test_winrate_gate_triggers_freeze():
    lg = LeagueMgr()
    gate = FreezeGate(winrate=0.6, min_games=4, min_steps=2, timeout_steps=99)
    lg.add_learning_agent("a", {"w": 0}, gate=gate)
    lg.add_learning_agent("b", {"w": 1}, gate=gate)
    # not enough steps, no evidence: no freeze
    assert lg.should_freeze("a", 1) is None
    assert lg.should_freeze("a", 10) is None          # 0 pool games yet
    for _ in range(6):
        lg.report_result(res(mk(0, "a"), mk(0, "b"), +1))
    wr, games = lg.pool_winrate("a")
    assert games == 6 and wr == 1.0
    assert lg.should_freeze("a", 1) is None           # min_steps still gates
    reason = lg.should_freeze("a", 3)
    assert reason is not None and reason.startswith("winrate@")
    # the loser's winrate is 0: only the timeout can freeze it
    assert lg.should_freeze("b", 50) is None
    reason_b = lg.should_freeze("b", 99)
    assert reason_b is not None and reason_b.startswith("timeout@")


def test_step_gate_overrides_winrate():
    lg = LeagueMgr()
    lg.add_learning_agent("a", {"w": 0}, gate=FreezeGate(step_gate=5))
    assert lg.should_freeze("a", 4) is None
    assert lg.should_freeze("a", 5) == "step_gate@5"


def test_agents_without_gate_never_self_trigger():
    lg = LeagueMgr()
    lg.add_learning_agent("a", {"w": 0})
    assert lg.should_freeze("a", 10 ** 9) is None


# ---------------------------------------------------------------------------
# Exploiter reset-on-freeze (AlphaStar reset semantics)
# ---------------------------------------------------------------------------
def test_exploiter_reset_on_freeze_restores_seed_params():
    lg = LeagueMgr()
    seed_params = {"w": np.array([1.0, 2.0])}
    lg.add_learning_agent("ex", seed_params, role="minimax_exploiter",
                          reset_on_freeze="seed")
    trained = {"w": np.array([9.0, 9.0])}
    new = lg.end_learning_period("ex", trained)
    # the frozen model keeps the trained weights...
    np.testing.assert_array_equal(lg.model_pool.pull(mk(0, "ex"))["w"],
                                  trained["w"])
    # ...but theta_{v+1} restarts from the seed, not from theta
    np.testing.assert_array_equal(lg.model_pool.pull(new)["w"],
                                  seed_params["w"])
    # and the stash survives the original being mutated after registration
    seed_params["w"][:] = -1.0
    new2 = lg.end_learning_period("ex", {"w": np.array([7.0, 7.0])})
    np.testing.assert_array_equal(lg.model_pool.pull(new2)["w"],
                                  np.array([1.0, 2.0]))


def test_learner_adopts_pool_params_after_freeze():
    """The Learner's live params must follow the pool's authoritative
    theta_{v+1} (seed reset / PBT exploit), not silently keep training the
    old weights."""
    from repro.learners import Learner
    from repro.optim import adamw

    lg = LeagueMgr()
    seed_params = {"w": jnp.asarray([1.0, 2.0])}
    lg.add_learning_agent("ex", seed_params, role="main_exploiter",
                          reset_on_freeze="seed")
    opt = adamw(1e-3)
    fake_step = lambda p, o, b: (p, o, {"loss": jnp.float32(0)})
    learner = Learner(lg, fake_step, opt, seed_params, agent_id="ex",
                      data_server=DataServer())
    learner.params = {"w": jnp.asarray([5.0, 5.0])}    # pretend training moved
    learner.end_learning_period()
    np.testing.assert_array_equal(np.asarray(learner.params["w"]),
                                  [1.0, 2.0])


# ---------------------------------------------------------------------------
# LeagueMgr bugfixes (satellites)
# ---------------------------------------------------------------------------
def test_report_result_unknown_lineage_records_on_shared_payoff():
    lg = LeagueMgr()
    lg.add_learning_agent("main", {"w": 0})
    ghost, seed = mk(7, "ghost"), mk(0, "main")
    lg.report_result(res(ghost, seed, +1))
    assert "ghost" not in lg.agents
    assert lg.payoff.games(ghost, seed) == 1
    assert lg.payoff.elo[ghost] > 1200.0 > lg.payoff.elo[seed]


def test_pbt_exploit_deep_copies_leader_params():
    lg = LeagueMgr(pbt=True)
    leader_params = {"w": np.array([3.0, 4.0])}
    lg.add_learning_agent("a", leader_params)
    lg.add_learning_agent("b", {"w": np.array([0.0, 0.0])})
    lg.payoff.elo[mk(0, "a")] = 1500.0                 # a leads by >100
    new = lg.end_learning_period("b", {"w": np.array([0.5, 0.5])})
    got = lg.model_pool.pull(new)
    np.testing.assert_array_equal(got["w"], leader_params["w"])
    # exploit must copy, not alias: a donating train step on one lineage
    # must never be able to delete the other's buffers
    assert not np.shares_memory(got["w"],
                                lg.model_pool.pull(mk(0, "a"))["w"])


def test_request_task_opponent_cache_tracks_pool_changes():
    lg = LeagueMgr()
    lg.add_learning_agent("main", {"w": 0})
    assert lg.request_task("main").opponent_keys[0] == mk(0)
    lg.end_learning_period("main", {"w": 1})
    # the cached opponent list must pick up the newly frozen model
    opps = {lg.request_task("main").opponent_keys[0] for _ in range(64)}
    assert mk(0) in opps


def test_model_pool_snapshot_on_pull():
    pool = ModelPool(snapshot_on_pull=True)
    k = mk(0)
    stored = {"w": np.array([1.0, 2.0])}
    pool.push(k, stored)
    pulled = pool.pull(k)
    np.testing.assert_array_equal(pulled["w"], stored["w"])
    assert not np.shares_memory(pulled["w"], stored["w"])
    # per-call override still hands out the raw reference
    assert np.shares_memory(pool.pull(k, copy=False)["w"], stored["w"])


# ---------------------------------------------------------------------------
# Concurrency stress: put/learn/hot-swap never drop or double-count frames
# ---------------------------------------------------------------------------
def _seg(marker, rows=4, t=8, obs_len=3):
    """Segment whose every leaf is a constant `marker` — a torn (mixed-put)
    read is detectable as mixed values inside one sampled minibatch."""
    return {
        "obs": np.full((rows, t, obs_len), marker, np.int32),
        "actions": np.full((rows, t), marker, np.int32),
        "rewards": np.full((rows, t), float(marker), np.float32),
    }


@pytest.mark.timeout(120)
def test_concurrent_put_learn_hotswap_ring_accounting():
    n_producers, puts_each, rows, t = 3, 40, 4, 8
    seg_frames = rows * t
    ds = DataServer(capacity_frames=8 * seg_frames, blocking=True,
                    prefetch=True)
    total_frames = n_producers * puts_each * seg_frames
    errors = []

    def producer(pid):
        try:
            for j in range(puts_each):
                # room-check + write are atomic: concurrent producers can
                # never jointly bury unconsumed frames
                assert ds.put_when_room(_seg(pid * 1000 + j),
                                        timeout=30.0), "no room"
        except BaseException as e:          # noqa: BLE001
            errors.append(e)

    stop = threading.Event()

    def hot_swap():
        # concurrent publisher on the shared pool while the ring churns
        pool = ModelPool(snapshot_on_pull=True)
        pool.push(mk(0), {"w": np.zeros(4)})
        i = 0
        while not stop.is_set():
            pool.push(mk(0), {"w": np.full(4, float(i))}, step=i)
            got = pool.pull(mk(0))["w"]
            assert (got == got[0]).all()    # never a torn pytree
            i += 1

    threads = [threading.Thread(target=producer, args=(p,))
               for p in range(n_producers)]
    swapper = threading.Thread(target=hot_swap)
    for th in threads:
        th.start()
    swapper.start()

    consumed_markers = []
    while ds.frames_consumed < total_frames:
        assert ds.wait_ready(timeout=30.0), (
            f"starved at {ds.frames_consumed}/{total_frames}")
        assert ds.unconsumed_frames <= ds.ring_capacity_frames
        mb = ds.sample_to_device()
        acts = np.asarray(mb["actions"])
        # one sample = one whole segment from one put — never torn
        assert (acts == acts.flat[0]).all()
        assert np.asarray(mb["obs"]).flat[0] == acts.flat[0]
        consumed_markers.append(int(acts.flat[0]))
    stop.set()
    for th in threads:
        th.join(timeout=30.0)
    swapper.join(timeout=30.0)
    assert not errors, errors
    # exact accounting: every produced frame consumed once, none dropped,
    # none double-counted
    assert ds.frames_received == total_frames
    assert ds.frames_consumed == total_frames
    assert ds.unconsumed_frames == 0
    assert len(consumed_markers) == n_producers * puts_each


@pytest.mark.timeout(180)
def test_infserver_hotswap_under_concurrent_clients():
    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params
    import jax

    cfg = get_arch("tleague-policy-s")
    theta = init_params(jax.random.PRNGKey(0), cfg)
    phi = init_params(jax.random.PRNGKey(1), cfg)
    server = InfServer(cfg, 6, theta, max_batch=8)
    obs = np.zeros((2, 26), np.int32)
    server.get(server.submit(obs))          # compile before threading
    errors = []

    def client():
        try:
            for _ in range(40):
                a, logp, v = server.get(server.submit(obs))
                assert np.isfinite(v).all()
        except BaseException as e:          # noqa: BLE001
            errors.append(e)

    def swapper():
        try:
            for i in range(80):
                server.update_params(theta if i % 2 else phi)
        except BaseException as e:          # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=client) for _ in range(2)]
    threads.append(threading.Thread(target=swapper))
    for th in threads:
        th.start()
    for th in threads:
        th.join(timeout=120.0)
    assert not any(th.is_alive() for th in threads)
    assert not errors, errors


# ---------------------------------------------------------------------------
# Sync vs async: same frozen-pool lineage structure under a step-count gate
# ---------------------------------------------------------------------------
@pytest.mark.timeout(600)
def test_sync_and_async_reach_same_lineage_structure():
    from repro.launch.train import (run_league_training,
                                    run_league_training_async)

    periods, steps = 2, 3
    spec = LeagueSpec(roles=(
        RoleSpec(name="main", role="main",
                 gate=FreezeGate(step_gate=steps)),
        RoleSpec(name="exploiter:0", role="minimax_exploiter", target="main",
                 gate=FreezeGate(step_gate=steps)),
    ))
    sync_league, _, _ = run_league_training(
        env_name="rps", num_envs=4, unroll_len=8, periods=periods,
        steps_per_period=steps, league_spec=spec, seed=3, verbose=False)
    async_league, _, report = run_league_training_async(
        spec, env_name="rps", num_envs=4, unroll_len=8, seed=3,
        max_freezes_per_role=periods, max_seconds=240, verbose=False)

    s_state, a_state = sync_league.league_state(), async_league.league_state()
    assert sorted(s_state["frozen_pool"]) == sorted(a_state["frozen_pool"])
    assert s_state["agents"] == a_state["agents"]
    assert report["clean_shutdown"]
    # every freeze the async coordinator applied came from the step gate
    for role in report["roles"].values():
        assert len(role["freezes"]) == periods
        for f in role["freezes"]:
            assert f["reason"].startswith("step_gate@")
            assert f["latency_s"] >= 0.0
