"""Checkpoint roundtrip: params pytree + league state."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_league, load_pytree, save_league, save_pytree
from repro.configs import get_arch
from repro.core import LeagueMgr
from repro.models import init_params


def test_pytree_roundtrip(tmp_path):
    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    path = str(tmp_path / "p.npz")
    save_pytree(path, params)
    loaded = load_pytree(path, params)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_league_state_roundtrip(tmp_path):
    lg = LeagueMgr()
    lg.add_learning_agent("main", {"w": jnp.ones(3)})
    lg.end_learning_period("main", {"w": jnp.ones(3) * 2})
    path = str(tmp_path / "league.json")
    save_league(path, lg.league_state())
    state = load_league(path)
    assert state["frozen_pool"] == ["main:0000"]
    assert state["agents"]["main"] == "main:0001"
