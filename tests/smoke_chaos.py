"""CI chaos smoke (ISSUE 7): a league of 4 actors + 1 pool read replica
survives SIGKILLed workers, a killed pool primary endpoint, a stalled
(SIGSTOP'd) actor, and seeded fault injection — and still reaches the
target learner steps with zero payoff corruption.

Not a pytest module (no `test_` prefix — minutes of wall clock, real
kill -9 semantics): run as `PYTHONPATH=src python tests/smoke_chaos.py`.

The scenario:
  1. Coordinator serves with the lease plane armed (`--lease-ttl 2
     --actor-stale 1.5`) and a seeded FaultPlan injected via the
     REPRO_FAULT_PLAN env seam (dropped pool pulls + delayed pings).
  2. A pool read replica follows the coordinator; actors read params
     replica-first (`--pool-endpoints replica,coordinator`).
  3. Mid-run, two actors are SIGKILLed (their leases go stale and are
     reaped + re-issued) and the replica is SIGKILLed (the surviving
     actors' pool reads fail over to the coordinator endpoint).
  4. A third actor is SIGSTOP'd past the stale threshold — its lease is
     reaped and re-issued while it is frozen — then SIGCONT'd, so its
     late result arrives under a dead task_id and MUST be dropped by the
     generation guard (`dropped_results` telemetry), never double-counted
     into the payoff matrix.
  5. The coordinator must still reach `--max-steps` and exit 0; the
     surviving workers must exit 0.
"""
import json
import os
import pathlib
import re
import signal
import subprocess
import sys
import threading
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

from repro.distributed.transport import FaultPlan, FaultRule  # noqa: E402

SPEC = REPO / "examples" / "league_specs" / "collector_smoke.json"
ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.pathsep.join(
    p for p in (str(REPO / "src"), os.environ.get("PYTHONPATH")) if p)

COMMON = ["--env", "rps", "--num-envs", "4", "--unroll-len", "8"]
TARGET_STEPS = 60

# mild, bounded, seeded chaos: dropped pool pulls ride the idempotent
# retry path; delayed pings stress the slow-vs-dead discrimination
PLAN = FaultPlan([FaultRule("pool.pull*", "drop", p=0.2, max_times=8),
                  FaultRule("ctrl.ping", "delay", delay_s=0.2, p=0.2,
                            max_times=8)], seed=1234)


def spawn(args, extra_env=None, **kw):
    env = dict(ENV, **(extra_env or {}))
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train"] + args,
        env=env, cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, **kw)


def drain_for(proc, pattern):
    """Drain `proc`'s stdout forever on a thread (a filled pipe would
    wedge the child); capture every line, flag the first `pattern` hit."""
    found, box, lines = threading.Event(), {}, []

    def loop():
        for line in proc.stdout:
            lines.append(line)
            m = re.search(pattern, line)
            if m and not found.is_set():
                box["match"] = m.group(1)
                found.set()

    threading.Thread(target=loop, daemon=True).start()
    return found, box, lines


def main() -> int:
    procs = []
    try:
        coord = spawn(["--role", "coordinator", "--league-spec", str(SPEC),
                       "--bind", "127.0.0.1:0", "--max-seconds", "240",
                       "--max-steps", str(TARGET_STEPS),
                       "--lease-ttl", "2", "--actor-stale", "1.5"] + COMMON,
                      extra_env={"REPRO_FAULT_PLAN": PLAN.to_json()})
        procs.append(coord)
        c_found, c_box, c_lines = drain_for(coord,
                                            r"serving league at (\S+)")
        assert c_found.wait(timeout=60), "coordinator never announced"
        address = c_box["match"]
        print(f"[chaos] coordinator at {address} (pid {coord.pid})",
              flush=True)

        replica = spawn(["--role", "pool-replica", "--connect", address,
                         "--bind", "127.0.0.1:0", "--sync-interval", "0.2"]
                        + COMMON)
        procs.append(replica)
        r_found, r_box, _ = drain_for(replica,
                                      r"serving pool replica at (\S+)")
        assert r_found.wait(timeout=60), "replica never announced"
        replica_addr = r_box["match"]
        print(f"[chaos] pool replica at {replica_addr} (pid {replica.pid})",
              flush=True)

        pool_eps = f"{replica_addr},{address}"
        learner = spawn(["--role", "learner", "--league-role", "main",
                         "--connect", address, "--pool-endpoints",
                         f"{address},{replica_addr}"] + COMMON)
        procs.append(learner)
        l_found, _, l_lines = drain_for(learner, r"(learner)")
        actors = []
        for i in range(4):
            a = spawn(["--role", "actor", "--league-role", "main",
                       "--actor-index", str(i), "--connect", address,
                       "--pool-endpoints", pool_eps] + COMMON)
            drain_for(a, r"(actor)")
            actors.append(a)
            procs.append(a)

        time.sleep(12)                 # real progress, leases outstanding
        for i, a in enumerate(actors):
            assert a.poll() is None, f"actor {i} died before the chaos"
        assert learner.poll() is None, "learner died before the chaos"

        print("[chaos] SIGKILL actors 0,1 + the pool replica", flush=True)
        os.kill(actors[0].pid, signal.SIGKILL)
        os.kill(actors[1].pid, signal.SIGKILL)
        os.kill(replica.pid, signal.SIGKILL)

        time.sleep(2)
        print("[chaos] SIGSTOP actor 2 past the stale threshold", flush=True)
        os.kill(actors[2].pid, signal.SIGSTOP)
        time.sleep(6)                  # > actor-stale + reap interval
        os.kill(actors[2].pid, signal.SIGCONT)
        print("[chaos] SIGCONT actor 2 (its reaped lease's late result "
              "must be dropped)", flush=True)

        try:
            coord.wait(timeout=240)
        except subprocess.TimeoutExpired:
            print("[chaos] FAIL: coordinator never reached target steps",
                  flush=True)
            return 1
        ok = coord.returncode == 0
        print(f"[chaos] coordinator exit={coord.returncode}", flush=True)

        # surviving workers observe the stop flag and exit cleanly
        for name, p in [("learner", learner), ("actor2", actors[2]),
                        ("actor3", actors[3])]:
            try:
                p.wait(timeout=60)
            except subprocess.TimeoutExpired:
                p.kill()
                p.wait()
                print(f"[chaos] FAIL: {name} hung after stop", flush=True)
                ok = False
                continue
            print(f"[chaos] {name}: exit={p.returncode}", flush=True)
            if p.returncode != 0:
                ok = False

        time.sleep(0.5)                # let the drainer catch the tail
        out = "".join(c_lines)
        tail = "\n".join(out.splitlines()[-12:])
        print(f"--- coordinator output tail ---\n{tail}", flush=True)

        if "fault plan armed" not in out:
            print("[chaos] FAIL: fault plan never armed", flush=True)
            ok = False
        m = re.search(r"\[coordinator\] done: (\{.*\})", out)
        if not m:
            print("[chaos] FAIL: no progress report", flush=True)
            ok = False
        else:
            steps = m and json.loads(m.group(1))["learner_steps"]
            if steps.get("main", 0) < TARGET_STEPS:
                print(f"[chaos] FAIL: learner steps {steps} < "
                      f"{TARGET_STEPS}", flush=True)
                ok = False
        m = re.search(r"\[coordinator\] leases: (\{.*\})", out)
        if not m:
            print("[chaos] FAIL: no lease report", flush=True)
            ok = False
        else:
            leases = json.loads(m.group(1))
            print(f"[chaos] leases: {leases}", flush=True)
            # the SIGKILLed/SIGSTOP'd actors' leases were reaped+re-issued
            if leases["reaped"] < 1 or leases["reissued"] < 1:
                print("[chaos] FAIL: no lease was reaped+re-issued",
                      flush=True)
                ok = False
            # zero payoff corruption: the stalled actor's late result for
            # its reaped lease was dropped by the generation guard, not
            # double-counted into the payoff matrix
            if leases["dropped_results"] < 1:
                print("[chaos] FAIL: generation guard never fired "
                      "(late result not dropped)", flush=True)
                ok = False

        print(f"[chaos] {'PASS' if ok else 'FAIL'}", flush=True)
        return 0 if ok else 1
    finally:
        for p in procs:
            if p.poll() is None:
                try:
                    os.kill(p.pid, signal.SIGCONT)
                except OSError:
                    pass
                p.kill()


if __name__ == "__main__":
    raise SystemExit(main())
