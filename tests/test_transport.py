"""Process-boundary transport (ISSUE 4): codec round trips, one RPC
round trip per league seam (pool pull/push, league request/report,
infserver submit/poll, dataserver put), killed-server error propagation,
and sharded-vs-single-device InfServer forward parity (local mesh
in-process; a forced multi-device CPU mesh in a subprocess)."""
import subprocess
import sys
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import LeagueMgr, MatchResult, ModelKey
from repro.core.types import FreezeGate, Hyperparam, Task
from repro.distributed import transport as tp
from repro.infserver import InfServer
from repro.launch.mesh import make_local_mesh
from repro.learners import DataServer
from repro.models import init_params


@pytest.fixture(scope="module")
def cfg():
    return get_arch("tleague-policy-s")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture()
def league(params):
    lg = LeagueMgr()
    lg.add_learning_agent("main", params, gate=FreezeGate(step_gate=2))
    return lg


# -- codec -------------------------------------------------------------------
def test_codec_roundtrip_protocol_types():
    task = Task(ModelKey("main", 3), (ModelKey("opp", 1), ModelKey("opp", 2)),
                Hyperparam(learning_rate=1e-3), task_id=7)
    msg = {
        "task": task,
        "result": MatchResult(task.learner_key, task.opponent_keys, -1, 9),
        "gate": FreezeGate(winrate=0.6, step_gate=None),
        "arr_f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "arr_bool": np.array([True, False]),
        "nested_tuple": (1, ("a", 2.5), None),
        "pytree": {"w": np.ones((2, 2)), "b": np.zeros((2,))},
    }
    out = tp.unpackb(tp.packb(msg))
    assert out["task"] == task
    assert out["result"].outcome == -1
    assert out["gate"] == msg["gate"]
    assert out["nested_tuple"] == msg["nested_tuple"]
    assert isinstance(out["nested_tuple"], tuple)
    np.testing.assert_array_equal(out["arr_f32"], msg["arr_f32"])
    assert out["arr_f32"].dtype == np.float32
    np.testing.assert_array_equal(out["arr_bool"], msg["arr_bool"])
    np.testing.assert_array_equal(out["pytree"]["w"], msg["pytree"]["w"])


def test_codec_jax_arrays_become_numpy():
    out = tp.unpackb(tp.packb({"x": jax.numpy.arange(4)}))
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"], np.arange(4))


# -- per-seam RPC round trips ------------------------------------------------
def test_model_pool_seam_roundtrip(league, params):
    with tp.serve_league(league) as srv:
        pool = tp.ModelPoolClient(srv.address)
        key = ModelKey("main", 0)
        pulled = pool.pull(key)
        # remote pull is a snapshot by construction: fresh numpy buffers
        for a, b in zip(jax.tree.leaves(pulled), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
            assert isinstance(a, np.ndarray)
        pool.push(key, pulled, step=5)
        assert pool.pull_attr(key) == {"step": 5, "frozen": False}
        assert key in pool and ModelKey("ghost", 9) not in pool
        assert pool.membership_version == league.model_pool.membership_version


def test_league_seam_roundtrip(league):
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        task = lg.request_task("main")
        assert isinstance(task, Task) and task.learner_key == ModelKey("main", 0)
        lg.report_result(MatchResult(task.learner_key, task.opponent_keys, 1, 3))
        wr, games = lg.pool_winrate("main")
        assert games >= 0.0
        assert lg.should_freeze("main", 0) is None          # step_gate=2
        assert lg.should_freeze("main", 2) == "step_gate@2"
        assert lg.frozen_pool == [ModelKey("main", 0)]
        # a freeze through the wire: params cross as msgpack pytrees
        new_key = lg.end_learning_period("main", lg.model_pool.pull(task.learner_key),
                                         reason="test")
        assert new_key == ModelKey("main", 1)
        assert lg.league_state()["agents"]["main"] == "main:0001"
        # the lazy agents view: one cheap current_model_key RPC, shaped
        # like the in-process registry for Learner.current_key
        assert lg.agents["main"].current == ModelKey("main", 1)


def test_infserver_seam_roundtrip(cfg, params):
    server = InfServer(cfg, 6, max_batch=64)
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    with tp.serve_league(league, server) as srv:
        client = tp.InfServerClient(tp.RpcClient(srv.address))
        client.register_model("theta", params)
        client.ensure_model("phi", params)
        obs = np.zeros((3, 26), np.int32)
        t1 = client.submit(obs, model="theta")
        t2 = client.submit(obs, model="phi")
        assert not client.poll(t1.tid)
        client.flush()                       # θ and φ share one grouped batch
        assert client.poll(t1.tid)
        a1, logp1, v1 = client.get(t1)
        a2, _, _ = client.get(t2)
        assert a1.shape == a2.shape == (3,)
        assert logp1.shape == v1.shape == (3,)
        assert client.stats()["models_hosted"] == 2
        assert client.evict_model("phi")


def test_infserver_rpc_matches_local(cfg, params):
    """The same observations through the in-process server and through the
    RPC client must produce identical outputs (same seed, same routes)."""
    obs = (np.arange(2 * 26).reshape(2, 26) % 16).astype(np.int32)

    def round_trip(get_server):
        server = InfServer(cfg, 6, params, max_batch=64, seed=13)
        with tp.serve_league(LeagueMgr(), server) as srv:
            s = get_server(server, srv)
            return s.get(s.submit(obs))

    local = round_trip(lambda server, srv: server)
    remote = round_trip(
        lambda server, srv: tp.InfServerClient(tp.RpcClient(srv.address)))
    for a, b in zip(local, remote):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_data_seam_roundtrip_and_backpressure():
    rows, T = 4, 8
    traj = {"obs": np.zeros((rows, T, 26), np.int32),
            "actions": np.zeros((rows, T), np.int32)}
    ds = DataServer(capacity_frames=rows * T, blocking=True)
    with tp.RpcServer({"data": ds}) as srv:
        client = tp.DataServerClient(srv.address)
        assert client.put_when_room(traj, timeout=1.0)
        assert client.ready() and ds.num_rows == rows
        # ring full of unconsumed frames: backpressure crosses the boundary
        assert not client.put_when_room(traj, timeout=0.1)
        ds.sample()                              # learner-side consume frees room
        assert client.put_when_room(traj, timeout=1.0)
        assert client.throughput()["rfps"] > 0


def test_killed_server_error_propagation(league):
    srv = tp.serve_league(league)
    lg = tp.LeagueMgrClient(srv.address)
    assert lg.request_task("main").task_id == 0      # connection established
    srv.close()
    with pytest.raises(tp.TransportError):
        lg.request_task("main")
    # a client that never could connect also raises TransportError
    dead = tp.RpcClient("127.0.0.1:1", connect_retries=1, retry_delay_s=0.01)
    with pytest.raises(tp.TransportError):
        dead.call("league.request_task", "main")


def test_remote_exception_carries_server_traceback(league):
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        with pytest.raises(tp.RemoteError) as ei:
            lg.request_task("nonexistent-agent")
        assert "KeyError" in str(ei.value)
        assert "request_task" in ei.value.remote_tb


def test_unserializable_reply_is_remote_error_not_disconnect(league):
    """A result the codec rejects (here: the live PayoffMatrix object via
    an attribute read) must come back as RemoteError and leave the
    connection usable — not kill it, which clients would misread as a
    server shutdown."""
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        with pytest.raises(tp.RemoteError):
            lg._call("payoff")
        assert lg.request_task("main").learner_key == ModelKey("main", 0)


def test_infserver_discard_and_backend_ticket_bound(cfg, params):
    server = InfServer(cfg, 6, params, max_batch=64)
    obs = np.zeros((2, 26), np.int32)
    # discard before flush: the queued rows are dropped from the batch
    t = server.submit(obs)
    server.discard(t)
    assert server.queue_depth == 0
    # discard after flush: the resolved result is dropped
    t = server.submit(obs)
    server.flush()
    server.discard(t)
    with pytest.raises(KeyError):
        server.get(t)
    # the RPC backend evicts the oldest outstanding ticket beyond its cap
    backend = tp.InfServerBackend(server, max_outstanding=2)
    tids = [backend.submit(obs) for _ in range(3)]
    backend.flush()
    with pytest.raises(KeyError):
        backend.get(tids[0])             # evicted
    for tid in tids[1:]:
        a, _, _ = backend.get(tid)
        assert a.shape == (2,)


def test_rpc_server_concurrent_clients(league):
    """N threads, each with its own connection, hammering one seam: the
    backend lock serializes them and every reply routes to its caller."""
    with tp.serve_league(league) as srv:
        results = [None] * 8

        def worker(i):
            lg = tp.LeagueMgrClient(srv.address)
            results[i] = [lg.request_task("main").task_id for _ in range(5)]

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        ids = [tid for r in results for tid in r]
        assert len(ids) == len(set(ids)) == 40   # every task id unique


# -- sharded serving parity --------------------------------------------------
def test_sharded_forward_parity_local_mesh(cfg, params):
    """ISSUE 4 acceptance: sharded forward matches single-device output
    <=1e-4 (exact here) on the make_local_mesh CPU mesh, single and
    grouped (θ+φ) paths."""
    obs_a = (np.arange(5 * 26).reshape(5, 26) % 16).astype(np.int32)
    obs_b = (np.arange(3 * 26).reshape(3, 26) % 16).astype(np.int32)

    def run(mesh):
        s = InfServer(cfg, 6, max_batch=64, seed=3, mesh=mesh)
        s.register_model("theta", params)
        out = [s.get(s.submit(obs_a, model="theta"))]
        s.register_model("phi", params)
        t1, t2 = s.submit(obs_a, model="theta"), s.submit(obs_b, model="phi")
        s.flush()
        out += [s.get(t1), s.get(t2)]
        return out

    single, sharded = run(None), run(make_local_mesh())
    err = max(float(np.max(np.abs(np.asarray(a, np.float64)
                                  - np.asarray(b, np.float64))))
              for ra, rb in zip(single, sharded) for a, b in zip(ra, rb))
    assert err <= 1e-4, f"sharded/single parity {err} > 1e-4"


_MESH_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.configs import get_arch
from repro.infserver import InfServer
from repro.launch.mesh import make_local_mesh
from repro.models import init_params

cfg = get_arch('tleague-policy-s')
params = init_params(jax.random.PRNGKey(0), cfg)
obs = (np.arange(5 * 26).reshape(5, 26) % 16).astype(np.int32)
obs2 = (np.arange(3 * 26).reshape(3, 26) % 16).astype(np.int32)

def run(mesh):
    s = InfServer(cfg, 6, max_batch=64, seed=3, mesh=mesh)
    s.register_model('theta', params)
    s.register_model('phi', params)
    t1, t2 = s.submit(obs, model='theta'), s.submit(obs2, model='phi')
    s.flush()
    return [s.get(t1), s.get(t2)]

single, sharded = run(None), run(make_local_mesh())
err = max(float(np.max(np.abs(np.asarray(a, np.float64)
                              - np.asarray(b, np.float64))))
          for ra, rb in zip(single, sharded) for a, b in zip(ra, rb))
assert err <= 1e-4, err
print('SHARDED-PARITY', err)
"""


@pytest.mark.timeout(600)
def test_sharded_forward_parity_multidevice():
    """The same parity on a REAL 4-device CPU mesh (data=4), where the
    batch actually shards. Subprocess: the forced host platform must be
    set before jax initializes."""
    import os
    import pathlib

    repo = pathlib.Path(__file__).resolve().parent.parent
    pythonpath = os.pathsep.join(
        p for p in (str(repo / "src"), os.environ.get("PYTHONPATH")) if p)
    env = dict(os.environ, PYTHONPATH=pythonpath)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, timeout=580, env=env)
    assert "SHARDED-PARITY" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])
