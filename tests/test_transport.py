"""Process-boundary transport (ISSUE 4): codec round trips, one RPC
round trip per league seam (pool pull/push, league request/report,
infserver submit/poll, dataserver put), killed-server error propagation,
and sharded-vs-single-device InfServer forward parity (local mesh
in-process; a forced multi-device CPU mesh in a subprocess)."""
import os
import subprocess
import sys
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import LeagueMgr, MatchResult, ModelKey
from repro.core.types import FreezeGate, Hyperparam, Task
from repro.distributed import transport as tp
from repro.infserver import InfServer
from repro.launch.mesh import make_local_mesh
from repro.learners import DataServer
from repro.models import init_params


@pytest.fixture(scope="module")
def cfg():
    return get_arch("tleague-policy-s")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


@pytest.fixture()
def league(params):
    lg = LeagueMgr()
    lg.add_learning_agent("main", params, gate=FreezeGate(step_gate=2))
    return lg


# -- codec -------------------------------------------------------------------
def test_codec_roundtrip_protocol_types():
    task = Task(ModelKey("main", 3), (ModelKey("opp", 1), ModelKey("opp", 2)),
                Hyperparam(learning_rate=1e-3), task_id=7)
    msg = {
        "task": task,
        "result": MatchResult(task.learner_key, task.opponent_keys, -1, 9),
        "gate": FreezeGate(winrate=0.6, step_gate=None),
        "arr_f32": np.arange(12, dtype=np.float32).reshape(3, 4),
        "arr_bool": np.array([True, False]),
        "nested_tuple": (1, ("a", 2.5), None),
        "pytree": {"w": np.ones((2, 2)), "b": np.zeros((2,))},
    }
    out = tp.unpackb(tp.packb(msg))
    assert out["task"] == task
    assert out["result"].outcome == -1
    assert out["gate"] == msg["gate"]
    assert out["nested_tuple"] == msg["nested_tuple"]
    assert isinstance(out["nested_tuple"], tuple)
    np.testing.assert_array_equal(out["arr_f32"], msg["arr_f32"])
    assert out["arr_f32"].dtype == np.float32
    np.testing.assert_array_equal(out["arr_bool"], msg["arr_bool"])
    np.testing.assert_array_equal(out["pytree"]["w"], msg["pytree"]["w"])


def test_codec_jax_arrays_become_numpy():
    out = tp.unpackb(tp.packb({"x": jax.numpy.arange(4)}))
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"], np.arange(4))


# -- per-seam RPC round trips ------------------------------------------------
def test_model_pool_seam_roundtrip(league, params):
    with tp.serve_league(league) as srv:
        pool = tp.ModelPoolClient(srv.address)
        key = ModelKey("main", 0)
        pulled = pool.pull(key)
        # a first remote pull lands in fresh numpy buffers
        for a, b in zip(jax.tree.leaves(pulled), jax.tree.leaves(params)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b))
            assert isinstance(a, np.ndarray)
        pool.push(key, pulled, step=5)
        assert pool.pull_attr(key) == {"step": 5, "frozen": False,
                                       "version": 1}
        assert key in pool and ModelKey("ghost", 9) not in pool
        assert pool.membership_version == league.model_pool.membership_version


def test_model_pool_client_version_cache(league, params):
    """The client's local version cache: a repeat pull costs the server a
    NotModified answer (zero param bytes), a push in between costs a
    changed-leaves delta — and both reconstruct the exact pool content."""
    with tp.serve_league(league) as srv:
        pool = tp.ModelPoolClient(srv.address)
        key = ModelKey("main", 0)
        server_pool = league.model_pool
        p1 = pool.pull(key)
        base_noop = server_pool.pull_stats["noop"]
        p2 = pool.pull(key)
        assert p2 is p1                      # cache hit, same object back
        assert server_pool.pull_stats["noop"] == base_noop + 1
        # a push invalidates: the next pull arrives as a delta
        new = jax.tree.map(lambda x: np.asarray(x) + 1.0, p1)
        server_pool.push(key, new, step=9)
        base_delta = server_pool.pull_stats["delta"]
        p3 = pool.pull(key)
        assert server_pool.pull_stats["delta"] == base_delta + 1
        for a, b in zip(jax.tree.leaves(p3), jax.tree.leaves(new)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # copy=True hands out a private copy, not the cache itself
        p4 = pool.pull(key, copy=True)
        assert p4 is not p3
        # raw protocol surface
        assert isinstance(pool.pull_if_changed(key, pool.version(key)),
                          tp.NotModified)
        assert pool.manifest(key).version == server_pool.version(key)


def test_league_seam_roundtrip(league):
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        task = lg.request_task("main")
        assert isinstance(task, Task) and task.learner_key == ModelKey("main", 0)
        lg.report_result(MatchResult(task.learner_key, task.opponent_keys, 1, 3))
        wr, games = lg.pool_winrate("main")
        assert games >= 0.0
        assert lg.should_freeze("main", 0) is None          # step_gate=2
        assert lg.should_freeze("main", 2) == "step_gate@2"
        assert lg.frozen_pool == [ModelKey("main", 0)]
        # a freeze through the wire: params cross as msgpack pytrees
        new_key = lg.end_learning_period("main", lg.model_pool.pull(task.learner_key),
                                         reason="test")
        assert new_key == ModelKey("main", 1)
        assert lg.league_state()["agents"]["main"] == "main:0001"
        # the lazy agents view: one cheap current_model_key RPC, shaped
        # like the in-process registry for Learner.current_key
        assert lg.agents["main"].current == ModelKey("main", 1)


def test_infserver_seam_roundtrip(cfg, params):
    server = InfServer(cfg, 6, max_batch=64)
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    with tp.serve_league(league, server) as srv:
        client = tp.InfServerClient(tp.RpcClient(srv.address))
        client.register_model("theta", params)
        client.ensure_model("phi", params)
        obs = np.zeros((3, 26), np.int32)
        t1 = client.submit(obs, model="theta")
        t2 = client.submit(obs, model="phi")
        assert not client.poll(t1.tid)
        client.flush()                       # θ and φ share one grouped batch
        assert client.poll(t1.tid)
        a1, logp1, v1 = client.get(t1)
        a2, _, _ = client.get(t2)
        assert a1.shape == a2.shape == (3,)
        assert logp1.shape == v1.shape == (3,)
        assert client.stats()["models_hosted"] == 2
        assert client.evict_model("phi")


def test_infserver_rpc_matches_local(cfg, params):
    """The same observations through the in-process server and through the
    RPC client must produce identical outputs (same seed, same routes)."""
    obs = (np.arange(2 * 26).reshape(2, 26) % 16).astype(np.int32)

    def round_trip(get_server):
        server = InfServer(cfg, 6, params, max_batch=64, seed=13)
        with tp.serve_league(LeagueMgr(), server) as srv:
            s = get_server(server, srv)
            return s.get(s.submit(obs))

    local = round_trip(lambda server, srv: server)
    remote = round_trip(
        lambda server, srv: tp.InfServerClient(tp.RpcClient(srv.address)))
    for a, b in zip(local, remote):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_data_seam_roundtrip_and_backpressure():
    rows, T = 4, 8
    traj = {"obs": np.zeros((rows, T, 26), np.int32),
            "actions": np.zeros((rows, T), np.int32)}
    ds = DataServer(capacity_frames=rows * T, blocking=True)
    with tp.RpcServer({"data": ds}) as srv:
        client = tp.DataServerClient(srv.address)
        assert client.put_when_room(traj, timeout=1.0)
        assert client.ready() and ds.num_rows == rows
        # ring full of unconsumed frames: backpressure crosses the boundary
        assert not client.put_when_room(traj, timeout=0.1)
        ds.sample()                              # learner-side consume frees room
        assert client.put_when_room(traj, timeout=1.0)
        assert client.throughput()["rfps"] > 0


def test_killed_server_error_propagation(league):
    srv = tp.serve_league(league)
    lg = tp.LeagueMgrClient(srv.address)
    assert lg.request_task("main").task_id == 0      # connection established
    srv.close()
    with pytest.raises(tp.TransportError):
        lg.request_task("main")
    # a client that never could connect also raises TransportError
    dead = tp.RpcClient("127.0.0.1:1", connect_retries=1, retry_delay_s=0.01)
    with pytest.raises(tp.TransportError):
        dead.call("league.request_task", "main")


def test_remote_exception_carries_server_traceback(league):
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        with pytest.raises(tp.RemoteError) as ei:
            lg.request_task("nonexistent-agent")
        assert "KeyError" in str(ei.value)
        assert "request_task" in ei.value.remote_tb


def test_unserializable_reply_is_remote_error_not_disconnect(league):
    """A result the codec rejects (here: the live PayoffMatrix object via
    an attribute read) must come back as RemoteError and leave the
    connection usable — not kill it, which clients would misread as a
    server shutdown."""
    with tp.serve_league(league) as srv:
        lg = tp.LeagueMgrClient(srv.address)
        with pytest.raises(tp.RemoteError):
            lg._call("payoff")
        assert lg.request_task("main").learner_key == ModelKey("main", 0)


def test_infserver_discard_and_backend_ticket_bound(cfg, params):
    server = InfServer(cfg, 6, params, max_batch=64)
    obs = np.zeros((2, 26), np.int32)
    # discard before flush: the queued rows are dropped from the batch
    t = server.submit(obs)
    server.discard(t)
    assert server.queue_depth == 0
    # discard after flush: the resolved result is dropped
    t = server.submit(obs)
    server.flush()
    server.discard(t)
    with pytest.raises(KeyError):
        server.get(t)
    # the RPC backend evicts the oldest outstanding ticket beyond its cap
    backend = tp.InfServerBackend(server, max_outstanding=2)
    tids = [backend.submit(obs) for _ in range(3)]
    backend.flush()
    with pytest.raises(KeyError):
        backend.get(tids[0])             # evicted
    for tid in tids[1:]:
        a, _, _ = backend.get(tid)
        assert a.shape == (2,)


def test_rpc_server_concurrent_clients(league):
    """N threads, each with its own connection, hammering one seam: the
    backend lock serializes them and every reply routes to its caller."""
    with tp.serve_league(league) as srv:
        results = [None] * 8

        def worker(i):
            lg = tp.LeagueMgrClient(srv.address)
            results[i] = [lg.request_task("main").task_id for _ in range(5)]

        threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=30.0)
        ids = [tid for r in results for tid in r]
        assert len(ids) == len(set(ids)) == 40   # every task id unique


# -- streaming transfer (param plane) ----------------------------------------
def test_chunked_streaming_roundtrip_bit_exact():
    """Leaves above the stream threshold ride out-of-band as bounded
    chunks; the reassembled pytree must be bit-exact, mixed with small
    (in-frame) leaves and protocol dataclasses."""
    rng = np.random.default_rng(3)
    big = rng.normal(size=(512, 600)).astype(np.float32)      # ~1.2 MB
    msg = {"big": big, "small": np.arange(5, dtype=np.int64),
           "key": ModelKey("main", 2), "t": (1, "two")}
    pool = type("Echo", (), {"echo": staticmethod(lambda m: m)})()
    with tp.RpcServer({"e": pool}) as srv:
        c = tp.RpcClient(srv.address)
        out = c.call("e.echo", msg)
        c.close()
    assert out["big"].dtype == big.dtype
    np.testing.assert_array_equal(out["big"], big)
    np.testing.assert_array_equal(out["small"], msg["small"])
    assert out["key"] == msg["key"] and out["t"] == (1, "two")
    # the frame itself really is small: the bulk bytes were hoisted out
    blobs = []
    frame = tp.packb(msg, blobs)
    assert len(frame) < 4096 and sum(b.nbytes for b in blobs) == big.nbytes


def test_chunking_override_is_scoped():
    big = np.zeros((200_000,), np.float32)                    # 800 KB
    with tp.chunking(threshold=1 << 62):
        blobs = []
        assert len(tp.packb({"x": big}, blobs)) > big.nbytes  # monolithic
        assert not blobs
    blobs = []
    tp.packb({"x": big}, blobs)
    assert len(blobs) == 1                                    # restored


@pytest.mark.timeout(60)
def test_killed_server_mid_chunk_raises_transport_error():
    """A peer that dies halfway through a streamed blob must surface as
    TransportError on the receiving side, not hang or return torn data."""
    import socket
    import struct

    arr = np.zeros((300_000,), np.float32)                    # 1.2 MB blob
    blobs = []
    payload = tp.packb({"w": arr}, blobs)
    assert len(blobs) == 1
    raw = blobs[0].tobytes()
    wire = (struct.pack(">BQ", tp._CODEC_ID | tp._STREAM_FLAG, len(payload))
            + payload + struct.pack(">I", 1)
            + struct.pack(">Q", len(raw)) + raw[:len(raw) // 2])  # truncated

    lst = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    lst.bind(("127.0.0.1", 0))
    lst.listen(1)

    def half_server():
        conn, _ = lst.accept()
        conn.sendall(wire)
        conn.close()                        # dies mid-chunk

    t = threading.Thread(target=half_server, daemon=True)
    t.start()
    client = socket.create_connection(lst.getsockname(), timeout=10.0)
    try:
        with pytest.raises(tp.TransportError, match="mid-chunk"):
            tp.recv_msg(client)
    finally:
        client.close()
        lst.close()
        t.join(timeout=5.0)


def test_infserver_client_hash_gated_hot_swap(cfg, params):
    """`update_params(content_hash=...)` over RPC: the second refresh is
    answered by the cheap `has_model` probe and the params are never
    shipped — the server's swap counter must not move."""
    from repro.params import build_manifest

    server = InfServer(cfg, 6, max_batch=16)
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    h = build_manifest(params, 0).tree_hash
    with tp.serve_league(league, server) as srv:
        client = tp.InfServerClient(tp.RpcClient(srv.address))
        client.update_params(params, key="theta", content_hash=h, version=0)
        assert server.swaps == 1
        client.update_params(params, key="theta", content_hash=h, version=0)
        client.ensure_model("theta", params, content_hash=h)
        assert server.swaps == 1             # both gated off server-side
        assert client.has_model("theta", content_hash=h)
        assert not client.has_model("phi")
        stats = client.stats()
        assert stats["swaps"] == 1 and stats["swap_noops"] == 0


def test_concurrent_push_and_delta_pull_over_rpc(league):
    """The param plane under cross-process-style concurrency: one client
    keeps pushing while N cached clients pull — every pulled pytree must
    hash to its own manifest (no torn deltas), versions monotonic."""
    from repro.params import build_manifest

    key = ModelKey("main", 0)
    with tp.serve_league(league) as srv:
        stop = threading.Event()
        errors = []

        def pusher():
            c = tp.ModelPoolClient(srv.address)
            i = 0
            while not stop.is_set():
                i += 1
                c.push(key, {"w": np.full((64, 64), i, np.float32),
                             "b": np.full((4,), i % 3, np.float32)}, step=i)
            c.close()

        def puller():
            try:
                c = tp.ModelPoolClient(srv.address)
                last_v = -1
                for _ in range(25):
                    p = c.pull(key)
                    man = c._puller.manifest(key)
                    assert man.version >= last_v
                    last_v = man.version
                    assert build_manifest(p, man.version).tree_hash \
                        == man.tree_hash, "torn delta"
                c.close()
            except Exception as e:           # noqa: BLE001
                errors.append(e)

        threads = [threading.Thread(target=pusher, daemon=True)] + \
            [threading.Thread(target=puller) for _ in range(3)]
        for t in threads:
            t.start()
        for t in threads[1:]:
            t.join(timeout=60.0)
        stop.set()
        threads[0].join(timeout=10.0)
        assert not errors, errors[0]
        assert league.model_pool.pull_stats["delta"] > 0


# -- sharded serving parity --------------------------------------------------
def test_sharded_forward_parity_local_mesh(cfg, params):
    """ISSUE 4 acceptance: sharded forward matches single-device output
    <=1e-4 (exact here) on the make_local_mesh CPU mesh, single and
    grouped (θ+φ) paths."""
    obs_a = (np.arange(5 * 26).reshape(5, 26) % 16).astype(np.int32)
    obs_b = (np.arange(3 * 26).reshape(3, 26) % 16).astype(np.int32)

    def run(mesh):
        s = InfServer(cfg, 6, max_batch=64, seed=3, mesh=mesh)
        s.register_model("theta", params)
        out = [s.get(s.submit(obs_a, model="theta"))]
        s.register_model("phi", params)
        t1, t2 = s.submit(obs_a, model="theta"), s.submit(obs_b, model="phi")
        s.flush()
        out += [s.get(t1), s.get(t2)]
        return out

    single, sharded = run(None), run(make_local_mesh())
    err = max(float(np.max(np.abs(np.asarray(a, np.float64)
                                  - np.asarray(b, np.float64))))
              for ra, rb in zip(single, sharded) for a, b in zip(ra, rb))
    assert err <= 1e-4, f"sharded/single parity {err} > 1e-4"


_MESH_SCRIPT = r"""
import os
os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=4'
import jax, numpy as np
assert len(jax.devices()) == 4
from repro.configs import get_arch
from repro.infserver import InfServer
from repro.launch.mesh import make_local_mesh
from repro.models import init_params

cfg = get_arch('tleague-policy-s')
params = init_params(jax.random.PRNGKey(0), cfg)
obs = (np.arange(5 * 26).reshape(5, 26) % 16).astype(np.int32)
obs2 = (np.arange(3 * 26).reshape(3, 26) % 16).astype(np.int32)

def run(mesh):
    s = InfServer(cfg, 6, max_batch=64, seed=3, mesh=mesh)
    s.register_model('theta', params)
    s.register_model('phi', params)
    t1, t2 = s.submit(obs, model='theta'), s.submit(obs2, model='phi')
    s.flush()
    return [s.get(t1), s.get(t2)]

single, sharded = run(None), run(make_local_mesh())
err = max(float(np.max(np.abs(np.asarray(a, np.float64)
                              - np.asarray(b, np.float64))))
          for ra, rb in zip(single, sharded) for a, b in zip(ra, rb))
assert err <= 1e-4, err
print('SHARDED-PARITY', err)
"""


@pytest.mark.timeout(600)
def test_sharded_forward_parity_multidevice():
    """The same parity on a REAL 4-device CPU mesh (data=4), where the
    batch actually shards. Subprocess: the forced host platform must be
    set before jax initializes."""
    import os
    import pathlib

    repo = pathlib.Path(__file__).resolve().parent.parent
    pythonpath = os.pathsep.join(
        p for p in (str(repo / "src"), os.environ.get("PYTHONPATH")) if p)
    env = dict(os.environ, PYTHONPATH=pythonpath)
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _MESH_SCRIPT],
                       capture_output=True, text=True, timeout=580, env=env)
    assert "SHARDED-PARITY" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])


# -- pipelined protocol (ISSUE 10) -------------------------------------------
class _Bench:
    """Test backend: an echo that can stall, for out-of-order replies."""

    @staticmethod
    def echo(x, delay=0.0):
        if delay:
            time.sleep(delay)
        return x

    def __init__(self):
        self.seen = []
        self._lock = threading.Lock()

    def record(self, x):
        with self._lock:
            self.seen.append(x)
        return len(self.seen)


def test_pipelined_out_of_order_64_callers():
    """64 requests in flight on ONE connection; the slow ones reply after
    the fast ones, and every future still resolves to ITS OWN payload."""
    with tp.RpcServer({"b": _Bench()}, conn_workers=8) as srv:
        c = tp.RpcClient(srv.address)
        try:
            # even request ids stall so their replies arrive out of order
            futs = [c.call_async("b.echo", i, delay=0.05 if i % 2 == 0 else 0.0)
                    for i in range(64)]
            assert c.transport_stats()["proto"] >= 2
            got = [f.result(timeout=30.0) for f in futs]
            assert got == list(range(64))
        finally:
            c.close()


def test_pipelined_slow_does_not_block_fast():
    """A stalled request must not head-of-line-block the connection: a
    fast call submitted AFTER a slow one completes first."""
    with tp.RpcServer({"b": _Bench()}) as srv:
        c = tp.RpcClient(srv.address)
        try:
            slow = c.call_async("b.echo", "slow", delay=1.0)
            t0 = time.monotonic()
            assert c.call("b.echo", "fast") == "fast"
            fast_s = time.monotonic() - t0
            assert fast_s < 0.5, f"fast call waited {fast_s:.2f}s behind slow"
            assert slow.result(timeout=10.0) == "slow"
        finally:
            c.close()


def test_abort_poisons_inflight_futures():
    """abort() from another thread fails every pipelined future promptly
    (TransportError, not a hang) and poisons the client for new calls."""
    with tp.RpcServer({"b": _Bench()}) as srv:
        c = tp.RpcClient(srv.address)
        futs = [c.call_async("b.echo", i, delay=30.0) for i in range(4)]
        threading.Timer(0.2, c.abort).start()
        for f in futs:
            with pytest.raises(tp.TransportError):
                f.result(timeout=10.0)
        with pytest.raises(tp.TransportError):
            c.call("b.echo", 1)


def test_legacy_server_negotiates_down():
    """New client against a serial v1 server: the hello is rejected, the
    client drops to proto 1, and call/call_async/notify all still work."""
    with tp.RpcServer({"b": _Bench()}, pipeline=False) as srv:
        c = tp.RpcClient(srv.address)
        try:
            assert c.call("b.echo", "x") == "x"
            assert c.transport_stats()["proto"] == 1
            assert c.call_async("b.echo", 7).result(timeout=10.0) == 7
            assert c.notify("b.record", "n1")
            assert c.call("b.record", "n2") == 2   # notify reached the server
        finally:
            c.close()


def test_legacy_client_against_pipelined_server():
    """Old-style client (no hello) against the new server: the serial v1
    loop serves it, interoperating with a pipelined client on the same
    server."""
    with tp.RpcServer({"b": _Bench()}) as srv:
        old = tp.RpcClient(srv.address, pipeline=False)
        new = tp.RpcClient(srv.address)
        try:
            assert old.transport_stats()["proto"] == 0  # never negotiated
            assert old.call("b.echo", "v1") == "v1"
            assert old.transport_stats()["proto"] == 1
            assert new.call("b.echo", "v2") == "v2"
            assert new.transport_stats()["proto"] >= 2
        finally:
            old.close()
            new.close()


def test_shm_ring_wraparound_and_oversize_fallback():
    """A ring much smaller than the traffic wraps repeatedly and every
    frame is still bit-exact; a blob that cannot fit the ring at all
    falls back to in-frame TCP bytes, also bit-exact."""
    rng = np.random.default_rng(7)
    with tp.RpcServer({"b": _Bench()}) as srv:
        c = tp.RpcClient(srv.address, shm_bytes=1 << 20)      # 1 MiB ring
        try:
            # 300 KiB: no whole number of blobs tiles the 1 MiB ring, so
            # the writer must skip the tail gap (a wrap) every few frames
            blob = rng.normal(size=(75, 1024)).astype(np.float32)
            for i in range(8):
                out = c.call("b.echo", {"i": i, "w": blob + i})
                np.testing.assert_array_equal(out["w"], blob + i)
            st = c.transport_stats()
            assert st["shm"], "same-host client should have negotiated shm"
            assert st["shm_blobs"] >= 8
            assert st["shm_wraps"] >= 1, st
            huge = rng.normal(size=(600, 1024)).astype(np.float32)  # 2.4 MiB
            np.testing.assert_array_equal(c.call("b.echo", huge), huge)
            assert c.transport_stats()["shm_fallbacks"] >= 1
        finally:
            c.close()


def test_shm_segment_unlinked_on_close():
    """close() must unlink the shared-memory segment — leaked /dev/shm
    files outlive the process and fill the host."""
    with tp.RpcServer({"b": _Bench()}) as srv:
        c = tp.RpcClient(srv.address)
        c.call("b.echo", np.zeros((200_000,), np.float32))   # force negotiate
        conn = c._conn
        if conn is None or conn.shm is None:
            pytest.skip("shm not negotiated on this host")
        name = conn.shm.name
        assert os.path.exists(f"/dev/shm/{name}")
        c.close()
        assert not os.path.exists(f"/dev/shm/{name}")


def test_chunked_blobs_interleave_with_small_calls():
    """Large streamed payloads and small control calls share one
    pipelined connection: the small calls stay fast and correct while
    multi-chunk blobs are in flight, and the blobs come back bit-exact."""
    rng = np.random.default_rng(11)
    big = rng.normal(size=(900, 1024)).astype(np.float32)     # ~3.7 MB
    with tp.RpcServer({"b": _Bench()}) as srv:
        # shm off: force the TCP chunked path the test is about
        c = tp.RpcClient(srv.address, shm=False)
        try:
            bigs = [c.call_async("b.echo", {"i": i, "w": big * (i + 1)})
                    for i in range(3)]
            smalls = [c.call_async("b.echo", i) for i in range(20)]
            assert [f.result(timeout=30.0) for f in smalls] == list(range(20))
            for i, f in enumerate(bigs):
                out = f.result(timeout=60.0)
                assert out["i"] == i
                np.testing.assert_array_equal(out["w"], big * (i + 1))
        finally:
            c.close()


def test_notify_is_one_way_and_reaches_server():
    """notify() returns without consuming a reply; the effect lands."""
    b = _Bench()
    with tp.RpcServer({"b": b}) as srv:
        c = tp.RpcClient(srv.address)
        try:
            for i in range(10):
                assert c.notify("b.record", i)
            deadline = time.monotonic() + 5.0
            while len(b.seen) < 10 and time.monotonic() < deadline:
                time.sleep(0.01)
            assert b.seen == list(range(10))
            # a round trip after 10 notifies proves framing stayed aligned
            assert c.call("b.echo", "ok") == "ok"
        finally:
            c.close()
