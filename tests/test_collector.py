"""The collector plane (ISSUE 6): VectorEnv slots, Collector drivers,
ticket coalescing across collectors, pluggable replay samplers, and the
prioritized/episode semantics those samplers pin."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.actors import build_rollout, build_served_rollout
from repro.actors.collector import ServedCollector, collect_interleaved
from repro.configs import get_arch
from repro.envs import HostVectorEnv, JaxVectorEnv, make_env
from repro.learners import (DataServer, EpisodeSampler, PrioritizedSampler,
                            SegmentTree, UniformSampler)
from repro.models import init_params


@pytest.fixture(scope="module")
def setup():
    env = make_env("rps")
    cfg = get_arch("tleague-policy-s")
    theta = init_params(jax.random.PRNGKey(0), cfg)
    phi = init_params(jax.random.PRNGKey(1), cfg)
    return env, cfg, theta, phi


# -- reference: the pre-collector build_rollout, verbatim ---------------------
def _reference_rollout(env, cfg, *, num_envs, unroll_len):
    """The scan-based driver exactly as it existed before the collector
    extraction — the bit-identity oracle for the jitted path."""
    from repro.actors.policy import make_obs_policy
    spec = env.spec
    learner_slots = tuple(range(spec.team_size))
    opp_slots = tuple(i for i in range(spec.num_agents)
                      if i not in learner_slots)
    policy = make_obs_policy(cfg, spec.num_actions)
    n_l = len(learner_slots)
    v_reset = jax.vmap(env.reset)
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0))

    def init_carry(rng):
        return v_reset(jax.random.split(rng, num_envs))

    def _act(params, rng, obs_slots):
        E, k, L0 = obs_slots.shape
        a, logp, v = policy.act(params, rng, obs_slots.reshape(E * k, L0))
        return (a.reshape(E, k), logp.reshape(E, k), v.reshape(E, k))

    @jax.jit
    def rollout(learner_params, opponent_params, carry, rng):
        def step_fn(c, rng_t):
            states, obs = c
            r_l, r_o, r_env, r_reset = jax.random.split(rng_t, 4)
            acts = jnp.zeros((num_envs, spec.num_agents), jnp.int32)
            a_l, logp_l, v_l = _act(learner_params, r_l,
                                    obs[:, list(learner_slots)])
            acts = acts.at[:, list(learner_slots)].set(a_l)
            if opp_slots:
                a_o, _, _ = _act(opponent_params, r_o, obs[:, list(opp_slots)])
                acts = acts.at[:, list(opp_slots)].set(a_o)
            states2, obs2, rewards, done, info = v_step(
                states, acts, jax.random.split(r_env, num_envs))
            states3, obs3 = v_reset(jax.random.split(r_reset, num_envs))
            sel = lambda a, b: jnp.where(
                done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
            states_n = jax.tree.map(sel, states3, states2)
            obs_n = jax.tree.map(sel, obs3, obs2)
            rec = {"obs": obs[:, list(learner_slots)], "actions": a_l,
                   "behavior_logp": logp_l, "behavior_values": v_l,
                   "rewards": rewards[:, list(learner_slots)], "done": done,
                   "outcome": info.get("outcome",
                                       jnp.zeros((num_envs,), jnp.int32))}
            return (states_n, obs_n), rec

        ks = jax.random.split(rng, unroll_len + 1)
        carry, recs = jax.lax.scan(step_fn, carry, ks[:-1])
        _, final_obs = carry
        _, _, v_boot = _act(learner_params, ks[-1],
                            final_obs[:, list(learner_slots)])

        def to_bt(x):
            x = jnp.moveaxis(x, 0, 1)
            if x.ndim >= 3 and x.shape[2] == n_l:
                x = jnp.moveaxis(x, 2, 1)
                return x.reshape((num_envs * n_l, unroll_len) + x.shape[3:])
            return x

        done_bt = jnp.repeat(jnp.moveaxis(recs["done"], 0, 1), n_l, axis=0)
        traj = {"obs": to_bt(recs["obs"]), "actions": to_bt(recs["actions"]),
                "behavior_logp": to_bt(recs["behavior_logp"]),
                "behavior_values": to_bt(recs["behavior_values"]),
                "rewards": to_bt(recs["rewards"]), "done": done_bt,
                "bootstrap_value": v_boot.reshape(num_envs * n_l)}
        episodes = {"done": recs["done"], "outcome": recs["outcome"]}
        return carry, traj, episodes

    return rollout, init_carry


def test_jit_collector_bit_identical_to_pre_refactor(setup):
    env, cfg, theta, phi = setup
    r_new, ic_new = build_rollout(env, cfg, num_envs=4, unroll_len=6)
    r_ref, ic_ref = _reference_rollout(env, cfg, num_envs=4, unroll_len=6)
    c_n, c_r = ic_new(jax.random.PRNGKey(2)), ic_ref(jax.random.PRNGKey(2))
    for seg in range(3):                       # carry threads across segments
        rng = jax.random.PRNGKey(100 + seg)
        c_n, t_n, e_n = r_new(theta, phi, c_n, rng)
        c_r, t_r, e_r = r_ref(theta, phi, c_r, rng)
        for k in t_r:
            assert np.array_equal(np.asarray(t_n[k]), np.asarray(t_r[k])), k
        for k in e_r:
            assert np.array_equal(np.asarray(e_n[k]), np.asarray(e_r[k])), k
        for a, b in zip(jax.tree.leaves(c_n), jax.tree.leaves(c_r)):
            assert np.array_equal(np.asarray(a), np.asarray(b))


def test_vector_env_host_adapter_matches_jax_shapes(setup):
    env, *_ = setup
    jv, hv = JaxVectorEnv(env, 3), HostVectorEnv(env, 3)
    s_j, o_j = jv.reset(jax.random.PRNGKey(0))
    s_h, o_h = hv.reset(jax.random.PRNGKey(0))
    assert np.asarray(o_j).shape == np.asarray(o_h).shape
    assert np.array_equal(np.asarray(o_j), np.asarray(o_h))  # same per-slot keys
    acts = np.zeros((3, env.spec.num_agents), np.int32)
    out_j = jv.step_autoreset(s_j, jnp.asarray(acts), jax.random.PRNGKey(1),
                              jax.random.PRNGKey(2))
    out_h = hv.step_autoreset(s_h, acts, jax.random.PRNGKey(1),
                              jax.random.PRNGKey(2))
    for a, b in zip(out_j[1:], out_h[1:]):     # obs, rewards, done, outcome
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_interleaved_collectors_coalesce_into_denser_batches(setup):
    """Two collectors sharing one InfServer, driven in lockstep: each
    step's tickets resolve in ONE grouped forward, so rows per batch
    doubles and batches run halves versus solo collectors."""
    from repro.infserver import InfServer
    env, cfg, theta, phi = setup
    E, T = 4, 5

    def fresh_server():
        srv = InfServer(cfg, env.spec.num_actions, max_batch=256)
        srv.register_model("theta", theta)
        srv.register_model("phi", phi)
        return srv

    # solo: each collector drives its own full segment (old layout)
    solo = fresh_server()
    for i in range(2):
        c = ServedCollector(JaxVectorEnv(env, E, jit=True), unroll_len=T)
        c.collect(solo, "theta", "phi",
                  c.init_carry(jax.random.PRNGKey(10 + i)),
                  jax.random.PRNGKey(20 + i))
    # interleaved: same work, one ticket stream
    shared = fresh_server()
    cols = [ServedCollector(JaxVectorEnv(env, E, jit=True), unroll_len=T)
            for _ in range(2)]
    jobs = [("theta", "phi",
             cols[i].init_carry(jax.random.PRNGKey(10 + i)),
             jax.random.PRNGKey(20 + i)) for i in range(2)]
    outs = collect_interleaved(cols, shared, jobs)
    for carry, traj, episodes in outs:
        assert traj["obs"].shape == (E, T, env.spec.obs_len)
        assert episodes["done"].shape == (T, E)
    st_solo, st_shared = solo.stats(), shared.stats()
    assert st_shared["rows_served"] == st_solo["rows_served"]
    assert st_shared["batches_run"] < st_solo["batches_run"]
    assert st_shared["mean_batch_rows"] > 1.5 * st_solo["mean_batch_rows"]


def test_served_collector_phase_misuse_raises(setup):
    env, cfg, theta, phi = setup
    c = ServedCollector(JaxVectorEnv(env, 2, jit=True), unroll_len=3)
    with pytest.raises(AssertionError):
        c.complete_step(None)                  # never began
    c.begin(c.init_carry(jax.random.PRNGKey(0)), jax.random.PRNGKey(1))
    with pytest.raises(AssertionError):
        c.finish(None)                         # no bootstrap submitted


# -- samplers -----------------------------------------------------------------
def _traj(seed, rows=4, t=8, obs_len=3, done_rows=()):
    """Segment with a controllable per-row terminal pattern."""
    rng = np.random.default_rng(seed)
    done = np.zeros((rows, t), bool)
    for r in done_rows:
        done[r, -1] = True
    return {
        "obs": rng.normal(size=(rows, t, obs_len)).astype(np.float32),
        "actions": rng.integers(0, 5, size=(rows, t)).astype(np.int32),
        "rewards": rng.normal(size=(rows, t)).astype(np.float32),
        "done": done,
    }


def test_uniform_sampler_bit_identical_to_pre_refactor_stream():
    """The uniform slot stream must be exactly the old DataServer's:
    same generator, same integers() calls, same ring mapping — the
    `--sync` oracle's determinism rests on this."""
    seed = 123
    ds = DataServer(seed=seed, blocking=False, capacity_frames=6 * 8,
                    prefetch=False)
    for i in range(4):                         # wraps: 16 rows through 6 slots
        ds.put(_traj(i))
    assert isinstance(ds.sampler, UniformSampler)
    # reference: replay the old _sample_idx against an independent rng
    ref_rng = np.random.default_rng(seed)
    head, size, slots = ds._head, ds._size, ds._row_slots
    for k in (2, 5, 3):
        got = ds.sampler.sample(k)
        ref = (head - size + ref_rng.integers(size, size=k)) % slots
        assert np.array_equal(got, ref)


def test_prioritized_sampler_tianshou_semantics():
    """Pinned to tianshou's PrioritizedReplayBuffer: init at
    max_prio**alpha, IS weights (w/min_prio)**-beta, updates set
    (|p|+eps)**alpha and widen the max/min trackers."""
    alpha, beta = 0.6, 0.4
    ds = DataServer(seed=0, blocking=False, capacity_frames=8 * 8,
                    prefetch=False, sampler="prioritized",
                    sampler_kwargs=dict(alpha=alpha, beta=beta))
    ds.put(_traj(0, rows=4))
    s = ds.sampler
    slots = np.arange(4)
    # init_weight: every fresh row at max_prio ** alpha == 1
    assert np.allclose(np.asarray(s._tree[slots]), 1.0)
    assert np.allclose(s.weights(slots), 1.0)
    # update: |p| + eps, alpha-annealed, trackers widen
    eps = np.finfo(np.float32).eps.item()
    ds.update_priorities(np.array([0, 1]), np.array([4.0, -0.25]))
    assert np.allclose(np.asarray(s._tree[[0, 1]]),
                       [(4.0 + eps) ** alpha, (0.25 + eps) ** alpha])
    assert s._max_prio == pytest.approx(4.0 + eps)
    assert s._min_prio == pytest.approx(0.25 + eps)
    # IS weights: (tree value / min_prio) ** (-beta)
    expect = (np.asarray(s._tree[slots]) / s._min_prio) ** (-beta)
    assert np.allclose(s.weights(slots), expect)
    # proportional sampling: a dominant priority dominates the draw
    ds.update_priorities(np.array([2]), np.array([1e6]))
    drawn = s.sample(512)
    assert (drawn == 2).mean() > 0.95
    # a near-zero priority slot still has eps mass (never starves forever)
    ds.update_priorities(np.array([2]), np.array([0.0]))
    assert float(s._tree[[2]][0]) > 0.0


def test_segment_tree_prefix_sum_exact():
    t = SegmentTree(4)
    t[np.arange(4)] = np.array([1.0, 2.0, 3.0, 4.0])
    assert t.reduce() == 10.0
    # prefix sums: [0,1), [1,3), [3,6), [6,10)
    got = t.get_prefix_sum_idx(np.array([0.0, 0.99, 1.0, 2.99, 3.0, 9.99]))
    assert np.array_equal(got, [0, 0, 1, 1, 2, 3])


def test_update_priorities_drops_stale_generations():
    """A priority update for a slot the ring has overwritten since the
    sample must be dropped, not applied to the unrelated new row."""
    ds = DataServer(seed=0, blocking=False, capacity_frames=4 * 8,
                    prefetch=False, sampler="prioritized")
    ds.put(_traj(0, rows=4))
    ds.sample(2)
    info = ds.last_sample_info()
    assert info["weights"] is not None and len(info["slots"]) == 2
    ds.put(_traj(1, rows=4))                   # overwrites all 4 slots
    n = ds.update_priorities(info["slots"], np.full(2, 9.0),
                             gen=info["gen"])
    assert n == 0                              # all stale -> all dropped
    assert np.allclose(np.asarray(ds.sampler._tree[info["slots"]]), 1.0)
    ds.sample(3)
    info2 = ds.last_sample_info()
    n2 = ds.update_priorities(info2["slots"], np.full(3, 2.0),
                              gen=info2["gen"])
    assert n2 == 3                             # fresh -> applied


def test_episode_sampler_reconstructs_across_ring_wrap():
    """Rows chain into episodes per producer lane; an episode whose rows
    straddle the ring wraparound still reconstructs in temporal order,
    and overwritten episodes vanish instead of serving stale rows."""
    ds = DataServer(seed=0, blocking=False, capacity_frames=6 * 8,
                    prefetch=False, sampler="episode")
    s = ds.sampler
    assert isinstance(s, EpisodeSampler)
    # 3 puts x 2 rows from ONE source; lane 0 finishes at put 1, lane 1 at put 2
    ds.put(_traj(0, rows=2), source="actor0")
    ds.put(_traj(1, rows=2, done_rows=(0,)), source="actor0")
    ds.put(_traj(2, rows=2, done_rows=(1,)), source="actor0")   # wraps: 6 slots
    eps = s.episodes()
    assert len(eps) == 2
    by_len = sorted(eps, key=len)
    # lane 0: rows at slots 0 (put0) and 2 (put1); lane 1: slots 1, 3, 5
    assert np.array_equal(by_len[0], [0, 2])
    assert np.array_equal(by_len[1], [1, 3, 5])
    # the slot-5 row wrapped the ring's write head (head reset to 0):
    # temporal order is preserved by the chain, not by slot order
    assert ds._head == 0 and ds._size == 6
    # sampling returns whole-episode runs
    got = s.sample(5)
    assert len(got) == 5 and set(got) <= {0, 1, 2, 3, 5}
    # overwrite slot 0 -> the [0, 2] episode is invalidated
    ds.put(_traj(3, rows=1, done_rows=(0,)), source="actor1")
    lens = sorted(len(e) for e in s.episodes())
    assert lens == [1, 3]                      # [0,2] gone; new 1-row episode


def test_episode_sampler_falls_back_uniform_before_first_episode():
    ds = DataServer(seed=7, blocking=False, capacity_frames=8 * 8,
                    prefetch=False, sampler="episode")
    ds.put(_traj(0, rows=4))                   # no terminal rows yet
    ref = np.random.default_rng(7)
    got = ds.sampler.sample(3)
    expect = (ds._head - ds._size + ref.integers(ds._size, size=3)) \
        % ds._row_slots
    assert np.array_equal(got, expect)


def test_windowed_throughput_rates():
    """Lifetime rates anchor at the FIRST put (no construction-idle
    skew); windowed rates cover only the interval since the previous
    throughput() call."""
    ds = DataServer(blocking=False, capacity_frames=64 * 8, prefetch=False)
    time.sleep(0.25)                           # idle before any data
    ds.put(_traj(0))
    tp1 = ds.throughput()
    # 32 frames landed "instantly" after first put: construction idle must
    # not be averaged in (the old bug would give ~32/0.25 ~ 128 fps here)
    assert tp1["rfps"] > 1000
    assert tp1["rfps_window"] > 1000
    time.sleep(0.2)                            # idle window, no new frames
    tp2 = ds.throughput()
    assert tp2["rfps_window"] == 0.0           # windowed: sees the idle
    assert tp2["rfps"] > 0.0                   # lifetime: still averaging
    ds.put(_traj(1))
    tp3 = ds.throughput()
    assert tp3["rfps_window"] > 0.0
    assert tp3["rfps"] < tp1["rfps"]           # lifetime decays with idle


def test_priority_updates_over_rpc():
    """DataServerClient round-trips last_sample_info + update_priorities:
    the remote-learner prioritized loop."""
    from repro.distributed.transport import DataServerClient, RpcServer
    ds = DataServer(seed=0, blocking=False, capacity_frames=8 * 8,
                    prefetch=False, sampler="prioritized")
    with RpcServer({"data": ds}) as srv:
        client = DataServerClient(srv.address)
        client.put(_traj(0, rows=4))
        assert client.ready()
        ds.sample(3)
        info = client.last_sample_info()
        assert len(info["slots"]) == 3 and info["weights"] is not None
        # one-way notify: no reply to await — poll for the server-side
        # tree update instead (the learner never consumed the count)
        client.update_priorities(info["slots"], np.full(3, 5.0),
                                 gen=info["gen"])
        eps = np.finfo(np.float32).eps.item()
        deadline = time.time() + 5.0
        while time.time() < deadline:
            if np.allclose(np.asarray(ds.sampler._tree[info["slots"]]),
                           (5.0 + eps) ** 0.6):
                break
            time.sleep(0.01)
        assert np.allclose(np.asarray(ds.sampler._tree[info["slots"]]),
                           (5.0 + eps) ** 0.6)
        client.close()


def test_sampler_threads_through_league_runtime_report():
    """build_runtime(sampler=...) reaches each role's DataServer and the
    telemetry report carries the windowed rates + sampler name."""
    from repro.league import LeagueSpec, build_runtime
    spec = LeagueSpec.from_dict({"roles": [
        {"name": "main", "role": "main", "num_actors": 1}]})
    rt = build_runtime(spec, env_name="rps", num_envs=2, unroll_len=4,
                       sampler="prioritized")
    ds = rt.roles[0].data_server
    assert isinstance(ds.sampler, PrioritizedSampler) and not ds.blocking
    report = rt.report(wall_s=1.0)
    role = report["roles"]["main"]
    assert {"rfps_window", "cfps_window", "sampler"} <= set(role)
    assert role["sampler"] == "prioritized"
