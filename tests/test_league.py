"""League machinery: payoff/Elo, opponent samplers, ModelPool semantics,
HyperMgr PBT, LeagueMgr lifecycle — the paper's §3.2 contracts."""
import collections

import numpy as np
import pytest

from repro.core import (EloMatchGameMgr, ExploiterGameMgr, Hyperparam,
                        HyperMgr, LeagueMgr, MatchResult, ModelKey, ModelPool,
                        PayoffMatrix, PFSPGameMgr, SelfPlayPFSPGameMgr,
                        UniformGameMgr)


def mk(v, agent="main"):
    return ModelKey(agent, v)


def res(a, b, outcome):
    return MatchResult(learner_key=a, opponent_keys=(b,), outcome=outcome)


def test_payoff_counts_and_winrate():
    p = PayoffMatrix()
    a, b = mk(0), mk(1)
    p.add_model(a), p.add_model(b)
    for _ in range(8):
        p.record(res(a, b, +1))
    for _ in range(2):
        p.record(res(a, b, -1))
    assert p.games(a, b) == 10
    # 8 wins / 10 with prior(0.5, 2 games) => (8+1)/12
    assert abs(p.winrate(a, b) - 9 / 12) < 1e-9
    assert abs(p.winrate(a, b) + p.winrate(b, a) - 1.0) < 1e-9


def test_elo_winner_gains():
    p = PayoffMatrix()
    a, b = mk(0), mk(1)
    p.add_model(a), p.add_model(b)
    p.record(res(a, b, +1))
    assert p.elo[a] > 1200.0 > p.elo[b]
    # zero-sum rating update
    assert abs((p.elo[a] - 1200.0) + (p.elo[b] - 1200.0)) < 1e-9


def test_pfsp_prefers_hard_opponents():
    p = PayoffMatrix()
    me, easy, hard = mk(9), mk(0), mk(1)
    for m in (me, easy, hard):
        p.add_model(m)
    for _ in range(20):
        p.record(res(me, easy, +1))   # beat easy always
        p.record(res(me, hard, -1))   # lose to hard always
    gm = PFSPGameMgr(weighting="squared", payoff=p, seed=0)
    picks = collections.Counter(
        gm.get_player(me, [easy, hard]) for _ in range(300))
    assert picks[hard] > 250, picks   # (1-p)^2 heavily favors the hard one


def test_uniform_recent_window():
    gm = UniformGameMgr(recent_n=2, seed=0)
    cands = [mk(i) for i in range(10)]
    for c in cands:
        gm.add_player(c)
    picks = {gm.get_player(mk(99), cands) for _ in range(100)}
    assert picks <= set(cands[-2:])


def test_sp_pfsp_mixture_fraction():
    gm = SelfPlayPFSPGameMgr(self_play_frac=0.35, payoff=PayoffMatrix(), seed=1)
    me = mk(5)
    cands = [mk(i) for i in range(3)]
    for c in cands + [me]:
        gm.add_player(c)
    n = 2000
    self_picks = sum(gm.get_opponent(me, cands) == me for _ in range(n))
    assert 0.28 < self_picks / n < 0.42   # ~35%


def test_exploiter_targets_latest_main():
    gm = ExploiterGameMgr(target_agent_id="main", payoff=PayoffMatrix())
    cands = [mk(0, "main"), mk(1, "main"), mk(0, "exploiter:0")]
    for c in cands:
        gm.add_player(c)
    assert gm.get_opponent(mk(0, "exploiter:0"), cands) == mk(1, "main")


def test_elo_match_prefers_similar_rating():
    p = PayoffMatrix()
    me, near, far = mk(9), mk(0), mk(1)
    for m in (me, near, far):
        p.add_model(m)
    p.elo[me], p.elo[near], p.elo[far] = 1200.0, 1210.0, 2400.0
    gm = EloMatchGameMgr(sigma=100.0, payoff=p, seed=0)
    picks = collections.Counter(gm.get_player(me, [near, far])
                                for _ in range(200))
    assert picks[near] > 190


def test_model_pool_freeze_semantics():
    pool = ModelPool(num_replicas=3)
    k = mk(0)
    pool.push(k, {"w": 1})
    assert pool.pull(k) == {"w": 1}
    pool.freeze(k)
    with pytest.raises(ValueError):
        pool.push(k, {"w": 2})
    assert pool.pull_attr(k)["frozen"]
    # replica reads got load-balanced
    pool2 = ModelPool(num_replicas=4, seed=1)
    pool2.push(k, {})
    for _ in range(200):
        pool2.pull(k)
    assert min(pool2.read_counts) > 10


def test_hyper_mgr_pbt_perturbs_multiplicatively():
    hm = HyperMgr(seed=0, perturb_factor=1.2)
    k = mk(0)
    h0 = hm.register(k)
    h1 = hm.explore(k)
    for f in ("learning_rate", "entropy_coef", "clip_eps"):
        r = getattr(h1, f) / getattr(Hyperparam(), f)
        assert abs(r - 1.2) < 1e-9 or abs(r - 1 / 1.2) < 1e-9
    # exploit copies then perturbs
    strong = mk(1)
    hm.register(strong, Hyperparam(learning_rate=1e-2))
    h2 = hm.exploit_explore(k, strong)
    assert abs(h2.learning_rate - 1e-2 * 1.2) < 1e-12 or \
        abs(h2.learning_rate - 1e-2 / 1.2) < 1e-12


def test_league_lifecycle():
    lg = LeagueMgr()
    k0 = lg.add_learning_agent("main", {"w": 0})
    assert k0 == mk(0)
    t = lg.request_task("main")
    assert t.learner_key == k0
    assert t.opponent_keys[0] in (k0,)       # only the seed exists
    lg.report_result(res(k0, k0, 0))
    k1 = lg.end_learning_period("main", {"w": 1})
    assert k1 == mk(1)
    assert lg.model_pool.pull_attr(k0)["frozen"]
    assert k0 in lg.frozen_pool
    # the new model warm-started from theta
    assert lg.model_pool.pull(k1) == {"w": 1}
    # multi-agent: exploiter joins, payoff shared
    lg.add_learning_agent("exploiter:0", {"w": 9},
                          game_mgr=ExploiterGameMgr(payoff=lg.payoff))
    t2 = lg.request_task("exploiter:0")
    assert t2.opponent_keys[0].agent_id == "main"
