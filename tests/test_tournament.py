"""Tournament/ranking tooling: transitive pools rank correctly; cyclic
pools (rock-paper-scissors models) get uniform Nash weight — the
game-theoretic sanity the league analysis relies on."""
import numpy as np

from repro.core import PayoffMatrix, ModelKey
from repro.core.tournament import league_report, replicator_ranking, round_robin


def mk(i):
    return ModelKey("m", i)


def test_transitive_ranking():
    # model i beats model j iff i > j (deterministic)
    models = [mk(i) for i in range(4)]
    payoff = round_robin(PayoffMatrix(), models,
                         play=lambda a, b, ep: 1 if a.version > b.version else -1,
                         episodes_per_pair=6)
    rep = league_report(payoff)
    assert rep["best_by_elo"] == str(mk(3))
    assert rep["best_by_nash"] == str(mk(3))
    wr = [rep["mean_winrate"][str(m)] for m in models]
    assert wr == sorted(wr), wr   # monotone in strength


def test_cyclic_pool_nash_is_uniform():
    # rock < paper < scissors < rock
    beats = {(0, 2), (1, 0), (2, 1)}
    models = [mk(i) for i in range(3)]

    def play(a, b, ep):
        return 1 if (a.version, b.version) in beats else -1

    payoff = round_robin(PayoffMatrix(), models, play, episodes_per_pair=10)
    nash = replicator_ranking(payoff)
    w = np.array(list(nash.values()))
    np.testing.assert_allclose(w, 1 / 3, atol=0.05)


def test_report_handles_small_pools():
    assert replicator_ranking(PayoffMatrix()) == {}
    p = PayoffMatrix()
    p.add_model(mk(0))
    rep = league_report(p)
    assert rep["best_by_elo"] == str(mk(0))
