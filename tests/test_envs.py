"""Environment invariants: token ranges, zero-sum structure, jit/vmap
compatibility, bomb/blast mechanics, duel frag accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.envs import make_env

KEY = jax.random.PRNGKey(11)


@pytest.mark.parametrize("name", ["rps", "rps_biased", "pommerman_lite", "duel"])
def test_env_protocol(name):
    env = make_env(name)
    spec = env.spec
    state, obs = env.reset(KEY)
    assert obs.shape == (spec.num_agents, spec.obs_len)
    assert obs.dtype == jnp.int32
    assert bool((obs >= 0).all()) and bool((obs < spec.obs_vocab).all())
    acts = jnp.zeros((spec.num_agents,), jnp.int32)
    state, obs, rew, done, info = env.step(state, acts, KEY)
    assert obs.shape == (spec.num_agents, spec.obs_len)
    assert rew.shape == (spec.num_agents,)
    assert done.dtype == jnp.bool_


@pytest.mark.parametrize("name", ["rps", "pommerman_lite"])
def test_env_jit_vmap(name):
    env = make_env(name)
    n = 4
    states, obs = jax.jit(jax.vmap(env.reset))(jax.random.split(KEY, n))
    acts = jnp.zeros((n, env.spec.num_agents), jnp.int32)
    step = jax.jit(jax.vmap(env.step))
    states, obs, rew, done, info = step(states, acts, jax.random.split(KEY, n))
    assert rew.shape == (n, env.spec.num_agents)


def test_rps_zero_sum_and_payoff():
    env = make_env("rps")
    state, _ = env.reset(KEY)
    # paper beats rock
    state, _, rew, _, _ = env.step(state, jnp.array([1, 0]), KEY)
    assert float(rew[0]) == 1.0 and float(rew[1]) == -1.0
    # same action ties
    state, _, rew, _, _ = env.step(state, jnp.array([2, 2]), KEY)
    assert float(rew[0]) == 0.0 and float(rew[1]) == 0.0
    # obs exposes opponent's last move
    _, obs, *_ = env.reset(KEY), None
    state2, obs2 = env.reset(KEY)
    state2, obs2, _, _, _ = env.step(state2, jnp.array([1, 2]), KEY)
    assert int(obs2[0, 0]) == 2 and int(obs2[1, 0]) == 1


def test_rps_episode_ends():
    env = make_env("rps", episode_len=3)
    state, _ = env.reset(KEY)
    for t in range(3):
        state, _, _, done, _ = env.step(state, jnp.array([0, 0]), KEY)
    assert bool(done)


def test_pommerman_bomb_kills_and_team_reward():
    env = make_env("pommerman_lite", wood_prob=0.0, shaping=0.0)
    state, obs = env.reset(KEY)
    # agent 0 drops a bomb at its corner and stays: it should die and team B win
    idle = jnp.zeros((4,), jnp.int32)
    state, obs, rew, done, info = env.step(state, idle.at[0].set(5), KEY)
    assert int(state["ammo"][0]) == 0
    for _ in range(5):
        if bool(done):
            break
        state, obs, rew, done, info = env.step(state, idle, KEY)
    assert not bool(state["alive"][0])          # suicided
    if bool(done):
        # team A lost both? only agent 0 dead; game continues unless...
        pass
    # run to the end with idle actions; eventually tie or a winner
    t = 0
    while not bool(done) and t < 120:
        state, obs, rew, done, info = env.step(state, idle, KEY)
        t += 1
    assert bool(done)
    r = np.asarray(rew)
    assert abs(r[:2].sum() + 0) == abs(r[:2].sum())  # finite
    # zero-sum team terminal reward
    assert abs(r.sum()) < 1e-6


def test_pommerman_movement_blocked_by_rigid():
    env = make_env("pommerman_lite", wood_prob=0.0)
    state, _ = env.reset(KEY)
    # agent 0 at (0,0); rigid walls at odd,odd — (1,1) is rigid. Moving
    # down then right twice should be legal along the corridor.
    a = jnp.zeros((4,), jnp.int32)
    state, *_ = env.step(state, a.at[0].set(2), KEY)   # down -> (1,0)
    assert tuple(np.asarray(state["pos"][0])) == (1, 0)
    state, *_ = env.step(state, a.at[0].set(4), KEY)   # right -> (1,1) rigid!
    assert tuple(np.asarray(state["pos"][0])) == (1, 0)


def test_duel_fire_and_frag():
    env = make_env("duel")
    state, _ = env.reset(KEY)
    # place agent 0 facing east with agent 1 in line
    state["pos"] = jnp.array([[4, 0], [4, 3], [0, 8], [8, 8]])
    state["facing"] = jnp.array([1, 3, 2, 0])    # 0 faces E toward 1
    state, obs, rew, done, info = env.step(
        state, jnp.array([4, 0, 0, 0]), KEY)
    assert int(info["frags"][0]) == 1
    assert float(rew[0]) > 0 and float(rew[1]) < 0
    # victim respawned at a corner
    corners = {(0, 0), (0, 8), (8, 0), (8, 8)}
    assert tuple(np.asarray(state["pos"][1])) in corners
