"""Per-assigned-architecture smoke tests (deliverable f).

Each instantiates the REDUCED same-family variant (<=2 layers, d_model<=256,
<=4 experts) and runs one forward/train step on CPU asserting output shapes
and no NaNs; decode-capable archs also check prefill->decode consistency
against the full forward (the InfServer path equals the Learner path).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.launch.dryrun import ASSIGNED
from repro.models import (decode_step, forward_train, init_params, prefill)
from repro.optim import adamw
from repro.learners.steps import build_seq_train_step, build_mlm_train_step

B, S = 2, 32


def make_batch(cfg, rng):
    if cfg.frontend == "audio":
        return {"frame_embeds": jax.random.normal(rng, (B, S, cfg.d_model)),
                "tokens": None}
    if cfg.frontend == "vision":
        return {"patch_embeds": jax.random.normal(rng, (B, 8, cfg.d_model)),
                "tokens": jax.random.randint(rng, (B, S - 8), 0, cfg.vocab_size)}
    return {"tokens": jax.random.randint(rng, (B, S), 0, cfg.vocab_size)}


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(42)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch, key):
    cfg = get_arch(arch).smoke()
    assert cfg.num_layers <= 2 and cfg.d_model <= 256
    if cfg.moe:
        assert cfg.moe.num_experts <= 4
    params = init_params(key, cfg)
    batch = make_batch(cfg, key)
    logits, values, aux = forward_train(params, cfg, batch)
    T = S if cfg.frontend != "vision" else S  # patches + tokens = S total
    assert logits.shape == (B, T, cfg.vocab_size)
    assert values.shape == (B, T)
    assert bool(jnp.isfinite(logits).all()), arch
    assert bool(jnp.isfinite(values).all()), arch
    assert bool(jnp.isfinite(aux)), arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch, key):
    cfg = get_arch(arch).smoke()
    params = init_params(key, cfg)
    opt = adamw(1e-3, clip_norm=1.0,
                master_fp32=(cfg.param_dtype == "bfloat16"))
    opt_state = opt.init(params)
    if cfg.encoder_only:
        step = build_mlm_train_step(cfg, opt)
        batch = {"frame_embeds": jax.random.normal(key, (B, S, cfg.d_model)),
                 "units": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
                 "mask": jax.random.bernoulli(key, 0.3, (B, S))}
    else:
        step = build_seq_train_step(cfg, opt, remat=True)
        batch = make_batch(cfg, key)
        s_act = batch["tokens"].shape[1]
        batch.update({
            "actions": jax.random.randint(key, (B, s_act), 0, cfg.vocab_size),
            "behavior_logp": -jnp.ones((B, s_act)) * 2.0,
            "behavior_values": jnp.zeros((B, s_act)),
            "rewards": jax.random.normal(key, (B, s_act)) * 0.1,
            "discounts": 0.99 * jnp.ones((B, s_act)),
            "bootstrap_value": jnp.zeros((B,)),
        })
    p2, o2, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"])), (arch, metrics)
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                                        - b.astype(jnp.float32)))),
                     params, p2)
    assert max(jax.tree.leaves(d)) > 0.0, arch


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if not get_arch(a).encoder_only])
def test_prefill_decode_consistency(arch, key):
    """decode(t+1 | prefill(0..t)) == forward_train(0..t+1) at last position.
    fp32 compute so the comparison is exact (bf16 is a dtype policy, not an
    algorithm difference)."""
    cfg = dataclasses.replace(get_arch(arch).smoke(), compute_dtype="float32")
    params = init_params(key, cfg)
    T = 16
    toks = jax.random.randint(key, (B, T + 1), 0, cfg.vocab_size)
    full_logits, full_values, _ = forward_train(params, cfg,
                                                {"tokens": toks})
    pre_logits, pre_values, state = prefill(params, cfg,
                                            {"tokens": toks[:, :T]})
    np.testing.assert_allclose(np.asarray(pre_logits[:, -1]),
                               np.asarray(full_logits[:, T - 1]),
                               rtol=1e-4, atol=1e-4)
    logits1, values1, state = decode_step(params, cfg, toks[:, T:T + 1], state)
    np.testing.assert_allclose(np.asarray(logits1[:, 0]),
                               np.asarray(full_logits[:, T]),
                               rtol=1e-4, atol=1e-4)
    # a second decode step still matches nothing-dropped semantics
    assert int(state["length"][0]) == T + 1


@pytest.mark.parametrize("arch", [a for a in ASSIGNED
                                  if not get_arch(a).encoder_only])
def test_sliding_decode_runs(arch, key):
    """Ring-buffer (sub-quadratic long-context) decode: shapes + finiteness."""
    from repro.models import init_decode_state
    cfg = get_arch(arch).smoke()
    seq = 256   # pretend long context, window=cfg.long_context_window=128
    state = init_decode_state(cfg, B, seq, sliding=True)
    window = cfg.long_context_window if cfg.family != "ssm" else 0
    tok = jax.random.randint(key, (B, 1), 0, cfg.vocab_size)
    logits, values, state2 = decode_step(params=init_params(key, cfg),
                                         cfg=cfg, tokens=tok, state=state,
                                         window=window)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert int(state2["length"][0]) == seq + 1
