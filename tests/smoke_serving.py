"""Serving-gateway SLO smoke: open-loop traffic against a 3-replica
fleet (real processes, real RPC), one replica SIGKILLed mid-run.

Pass criteria (asserted; the CI job fails on a non-zero exit):

  * availability >= 0.95 — answered / attempted across the whole run,
    INCLUDING the kill window (the gateway fails tickets over to the
    survivors, so a single replica death should cost ~nothing)
  * deadline-bucket p99 — the le_2000ms bucket must hold its SLO:
    hit rate >= 0.95 and p99 <= the 2s deadline
  * the gateway noticed: exactly one replica marked dead, failovers > 0
    or the dead replica simply wasn't holding traffic at the kill

Run: PYTHONPATH=src python tests/smoke_serving.py
"""
import sys
import threading
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.core import ModelKey
from repro.models import init_params
from repro.params.manifest import build_manifest
from repro.serving import ServingGateway
from repro.serving.fleet import connect, shutdown, spawn_fleet

REPLICAS = 3
RUN_S = 10.0
KILL_AT_S = 4.0
DEADLINE_S = 2.0
THREADS = 4
REQ_PER_S_PER_THREAD = 8.0
ROWS = 4
OBS_LEN = 2                       # rps observations


def main() -> int:
    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    keys = [ModelKey("main", 0), ModelKey("exploiter", 0)]
    manifest = build_manifest(params, version=0)

    print(f"[smoke] spawning {REPLICAS} replica processes ...", flush=True)
    fleet = spawn_fleet(REPLICAS, arch="tleague-policy-s", env_name="rps",
                        max_batch=64)
    try:
        gw = ServingGateway([connect(r.address) for r in fleet],
                            router="lineage", failover_retries=3,
                            deadline_edges_s=(0.5, DEADLINE_S),
                            max_inflight_rows=8192,
                            pump_interval_s=0.01).start()
        for key in keys:
            rep = gw.rollout(key, params, manifest)
            print(f"[smoke] rollout {key}: shipped_to={rep['shipped_to']} "
                  f"({rep['propagation_ms']:.0f}ms)", flush=True)

        # warm every replica's jit cache across the buckets the traffic
        # can hit (4..32 rows coalesced), so no compile lands inside the
        # measured deadline window
        for h in gw._handles:
            for n_sub in (1, 2, 4, 8):
                ts = [h.replica.submit(np.zeros((ROWS, OBS_LEN), np.int32),
                                       model=keys[0]) for _ in range(n_sub)]
                h.replica.flush()
                for t in ts:
                    h.replica.get(t)
        print("[smoke] fleet warmed; driving open-loop traffic", flush=True)

        stop = threading.Event()
        lock = threading.Lock()
        attempted = [0]
        answered = [0]
        errors = []

        def submitter(i):
            rng = np.random.default_rng(i)
            interval = 1.0 / REQ_PER_S_PER_THREAD
            nxt = time.perf_counter() + rng.uniform(0, interval)
            while not stop.is_set():
                lag = nxt - time.perf_counter()
                if lag > 0:
                    time.sleep(min(lag, 0.05))
                    continue
                nxt += interval
                obs = rng.integers(0, 3, (ROWS, OBS_LEN)).astype(np.int32)
                key = keys[int(rng.integers(len(keys)))]
                with lock:
                    attempted[0] += 1
                try:
                    t = gw.submit(obs, model=key, deadline_s=DEADLINE_S)
                    gw.get(t)
                    with lock:
                        answered[0] += 1
                except Exception as e:            # shed / failover exhausted
                    with lock:
                        errors.append(repr(e))

        threads = [threading.Thread(target=submitter, args=(i,))
                   for i in range(THREADS)]
        t0 = time.perf_counter()
        for t in threads:
            t.start()

        time.sleep(KILL_AT_S)
        victim = max(gw.stats()["replicas"],
                     key=lambda r: r["routed_requests"])["replica"]
        print(f"[smoke] kill -9 replica {victim} "
              f"(pid {fleet[victim].proc.pid})", flush=True)
        fleet[victim].kill()

        time.sleep(RUN_S - KILL_AT_S)
        stop.set()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        gw.stop()

        st = gw.stats()
        availability = answered[0] / max(attempted[0], 1)
        bucket = gw.deadlines.label(DEADLINE_S)
        slo = st["deadlines"].get(bucket, {"hit_rate": 0.0, "p99_ms": 1e9,
                                           "count": 0})
        print(f"[smoke] {attempted[0]} attempted, {answered[0]} answered "
              f"in {wall:.1f}s -> availability {availability:.3f}",
              flush=True)
        print(f"[smoke] {bucket}: count={slo['count']} "
              f"hit_rate={slo['hit_rate']:.3f} p99={slo['p99_ms']:.0f}ms; "
              f"failovers={st['failovers']} died={st['replicas_died']} "
              f"shed={st['shed_requests']}", flush=True)
        if errors:
            print(f"[smoke] {len(errors)} request errors, first: "
                  f"{errors[0]}", flush=True)

        assert availability >= 0.95, \
            f"availability {availability:.3f} < 0.95"
        assert slo["count"] > 0, "no requests recorded in the SLO bucket"
        assert slo["hit_rate"] >= 0.95, \
            f"deadline hit rate {slo['hit_rate']:.3f} < 0.95"
        assert slo["p99_ms"] <= DEADLINE_S * 1e3, \
            f"p99 {slo['p99_ms']:.0f}ms over the {DEADLINE_S * 1e3:.0f}ms SLO"
        assert st["replicas_died"] == 1, \
            f"expected exactly 1 dead replica, saw {st['replicas_died']}"
        assert st["alive_replicas"] == REPLICAS - 1
        print("[smoke] serving smoke OK", flush=True)
        return 0
    finally:
        shutdown(fleet)


if __name__ == "__main__":
    sys.exit(main())
