import jax
import pytest

# Smoke tests and benches must see the real (1-device) CPU platform; only
# dryrun.py forces 512 host devices (and only in its own process).
jax.config.update("jax_enable_x64", False)


@pytest.fixture(scope="session")
def rng():
    return jax.random.PRNGKey(0)
