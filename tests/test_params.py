"""The param plane (ISSUE 5): manifest identity, delta reconstruction,
ModelPool version/manifest semantics under concurrent push + delta pull,
CachedPuller behavior, the InfServer's hash-gated hot-swap, and the
heartbeat liveness primitives."""
import threading
import time

import numpy as np
import pytest

from repro.core.model_pool import ModelPool
from repro.core.types import ModelKey
from repro.distributed.heartbeat import Heartbeat, HeartbeatMonitor
from repro.params import (CachedPuller, NotModified, apply_delta,
                          build_manifest, leaf_hash)


def _params(scale=1.0, n=3):
    rng = np.random.default_rng(0)
    return {f"layer{i}": {"w": (scale * rng.normal(size=(8, 8))).astype(np.float32),
                          "b": np.full((8,), scale, np.float32)}
            for i in range(n)}


# -- manifest ----------------------------------------------------------------
def test_leaf_hash_covers_dtype_shape_and_bytes():
    a = np.arange(6, dtype=np.float32)
    assert leaf_hash(a) == leaf_hash(a.copy())
    assert leaf_hash(a) != leaf_hash(a.astype(np.float64))
    assert leaf_hash(a) != leaf_hash(a.reshape(2, 3))
    b = a.copy(); b[0] += 1
    assert leaf_hash(a) != leaf_hash(b)


def test_manifest_diff_and_tree_hash():
    p = _params()
    m0 = build_manifest(p, 0)
    assert m0.nbytes == sum(x.nbytes for lyr in p.values() for x in lyr.values())
    p2 = {k: dict(v) for k, v in p.items()}
    p2["layer1"]["b"] = p["layer1"]["b"] + 1
    m1 = build_manifest(p2, 1)
    assert m1.tree_hash != m0.tree_hash
    assert m1.changed_paths(m0) == ["['layer1']['b']"]
    # same content, different version: hashes agree, zero changed paths
    m0b = build_manifest(p, 5)
    assert m0b.tree_hash == m0.tree_hash and m0b.changed_paths(m0) == []
    # leaf-set change (new layer): no delta exists
    p3 = dict(p2, layer9={"w": np.zeros((2, 2), np.float32)})
    assert build_manifest(p3, 2).changed_paths(m0) is None


def test_apply_delta_is_functional_and_bit_exact():
    base = _params()
    new_b = base["layer0"]["b"] + 3
    out = apply_delta(base, {"['layer0']['b']": new_b})
    assert out["layer0"]["b"] is new_b
    assert out["layer2"]["w"] is base["layer2"]["w"]   # unchanged leaves shared
    assert np.array_equal(base["layer0"]["b"], np.full((8,), 1, np.float32))
    with pytest.raises(KeyError):
        apply_delta(base, {"['nope']": new_b})


# -- ModelPool versioning ----------------------------------------------------
def test_pool_version_monotonic_and_membership_independent():
    pool = ModelPool()
    k = ModelKey("main", 0)
    pool.push(k, _params())
    assert pool.version(k) == 0 and pool.membership_version == 1
    pool.push(k, _params(2.0))
    assert pool.version(k) == 1 and pool.membership_version == 1  # same key set
    k2 = ModelKey("main", 1)
    pool.push(k2, _params())
    assert pool.version(k2) == 0 and pool.membership_version == 2
    assert pool.pull_attr(k)["version"] == 1


def test_pull_if_changed_protocol():
    pool = ModelPool()
    k = ModelKey("main", 0)
    pool.push(k, _params())
    r = pool.pull_if_changed(k, None)
    assert r.full and r.manifest.version == 0
    assert isinstance(pool.pull_if_changed(k, 0), NotModified)
    pool.push(k, dict(_params(), layer0={"w": _params()["layer0"]["w"],
                                         "b": np.zeros((8,), np.float32)}))
    d = pool.pull_if_changed(k, 0)
    assert not d.full and set(d.leaves) == {"['layer0']['b']"}
    # prehistoric / unknown versions fall back to a full pull
    assert pool.pull_if_changed(k, 999).full
    with pytest.raises(KeyError):
        pool.pull_if_changed(ModelKey("ghost", 0), None)


def test_frozen_key_pulls_are_noops_forever():
    pool = ModelPool()
    k = ModelKey("opp", 0)
    pool.push(k, _params())
    pool.freeze(k)
    v = pool.version(k)
    for _ in range(3):
        assert isinstance(pool.pull_if_changed(k, v), NotModified)
    with pytest.raises(ValueError):
        pool.push(k, _params(2.0))


def test_snapshot_on_pull_applies_to_delta_leaves():
    """The aliasing guard carries over: delta leaves from a
    snapshot_on_pull pool are private copies, so a consumer can never
    corrupt (or be corrupted by) the stored entry."""
    pool = ModelPool(snapshot_on_pull=True)
    k = ModelKey("main", 0)
    p = _params()
    pool.push(k, p)
    v0 = pool.version(k)
    pool.pull_if_changed(k, None)                       # seed the history
    p2 = {kk: dict(vv) for kk, vv in p.items()}
    p2["layer0"]["b"] = p["layer0"]["b"] + 1
    pool.push(k, p2)
    d = pool.pull_if_changed(k, v0)
    leaf = d.leaves["['layer0']['b']"]
    leaf[:] = -99.0                                      # vandalize the copy
    assert np.array_equal(pool.pull(k, copy=False)["layer0"]["b"],
                          p["layer0"]["b"] + 1)
    # copy=False opts out: the live stored leaf comes back
    d2 = pool.pull_if_changed(k, v0, copy=False)
    assert d2.leaves["['layer0']['b']"] is p2["layer0"]["b"]


def test_concurrent_push_and_delta_pull_consistency():
    """Pushers bump versions while pullers sync by version: every puller
    observation must be internally consistent (the received params hash
    to the received manifest) and versions must be monotonic per
    observer."""
    pool = ModelPool(snapshot_on_pull=True)
    k = ModelKey("main", 0)
    pool.push(k, _params(0.0))
    stop = threading.Event()
    errors = []

    def pusher():
        i = 0
        while not stop.is_set():
            i += 1
            p = _params(float(i % 7))
            p["layer1"]["b"] = np.full((8,), i, np.float32)
            pool.push(k, p, step=i)

    def puller():
        try:
            puller = CachedPuller(pool)
            last_v = -1
            for _ in range(50):
                params, man = puller.get_with_manifest(k)
                assert man.version >= last_v, "version went backwards"
                last_v = man.version
                got = build_manifest(params, man.version)
                assert got.tree_hash == man.tree_hash, \
                    "reconstructed params do not hash to their manifest"
        except Exception as e:                       # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=pusher, daemon=True)] \
        + [threading.Thread(target=puller) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads[1:]:
        t.join(timeout=60.0)
    stop.set()
    threads[0].join(timeout=10.0)
    assert not errors, errors[0]
    assert pool.pull_stats["delta"] + pool.pull_stats["noop"] > 0


# -- cross-key content addressing --------------------------------------------
def test_pull_if_changed_cross_key_references_held_leaves():
    """A caller that advertises held content hashes gets hash references
    instead of bytes — even on the would-be-full path for a key it never
    pulled before."""
    pool = ModelPool()
    seed_params = _params()
    k0, k1 = ModelKey("main", 0), ModelKey("exploiter", 0)
    pool.push(k0, seed_params)
    r0 = pool.pull_if_changed(k0, None)
    assert r0.full
    held = set(r0.manifest.leaf_hashes.values())
    # same content under a brand-new key: every leaf rides as a reference
    pool.push(k1, _params())
    d = pool.pull_if_changed(k1, None, have_hashes=held)
    assert not d.full and not d.leaves and len(d.by_hash) == 6
    assert pool.pull_stats["cross_key"] == 1
    # partial overlap: only the novel leaf ships bytes
    p2 = _params()
    p2["layer1"]["b"] = np.full((8,), 42.0, np.float32)
    k2 = ModelKey("exploiter", 1)
    pool.push(k2, p2)
    d2 = pool.pull_if_changed(k2, None, have_hashes=held)
    assert not d2.full and set(d2.leaves) == {"['layer1']['b']"}
    assert len(d2.by_hash) == 5
    # no overlap advertised: plain full answer
    assert pool.pull_if_changed(k2, None, have_hashes={"nope"}).full


def test_exploiter_reset_costs_nothing():
    """The ROADMAP open item, end to end: an exploiter reset-on-freeze
    re-mints the seed pytree under a fresh key; a CachedPuller that ever
    held the seed reconstructs the new key from its hash store with ZERO
    param bytes pulled — and the result is bit-exact."""
    pool = ModelPool()
    seed_params = _params()
    k_seed = ModelKey("exploiter", 0)
    pool.push(k_seed, seed_params)
    pu = CachedPuller(pool)
    pu.get(k_seed)                           # warm: cache now holds the seed
    # lineage advances while training... then reset re-ships the seed
    k_next = ModelKey("exploiter", 1)
    pool.push(k_next, {kk: dict(vv) for kk, vv in seed_params.items()})
    full_before = pool.pull_stats["full"]
    got, man = pu.get_with_manifest(k_next)
    assert pool.pull_stats["cross_key"] == 1
    assert pool.pull_stats["full"] == full_before     # zero bytes shipped
    assert man.version == 0 and man.tree_hash == build_manifest(
        seed_params, 0).tree_hash
    for lyr in seed_params:
        for name in seed_params[lyr]:
            assert np.array_equal(got[lyr][name], seed_params[lyr][name])
    # the reconstructed entry itself re-seeds the hash store: dropping the
    # original key keeps the content addressable
    pu.drop(k_seed)
    k3 = ModelKey("exploiter", 2)
    pool.push(k3, {kk: dict(vv) for kk, vv in seed_params.items()})
    got3, _ = pu.get_with_manifest(k3)
    assert pool.pull_stats["cross_key"] == 2
    assert np.array_equal(got3["layer0"]["w"], seed_params["layer0"]["w"])


def test_cross_key_falls_back_cleanly_on_legacy_pools():
    """Pools without the have_hashes keyword keep working: the puller
    retries without it and never advertises again."""
    class OldPool:
        def __init__(self):
            self._p = ModelPool()
        def pull_if_changed(self, key, have_version=None, copy=None):
            return self._p.pull_if_changed(key, have_version, copy=copy)
        def push(self, *a, **k):
            self._p.push(*a, **k)
        def pull(self, key, copy=None):
            return self._p.pull(key, copy=copy)

    pool = OldPool()
    k0, k1 = ModelKey("m", 0), ModelKey("m", 1)
    pool.push(k0, _params())
    pool.push(k1, _params())
    pu = CachedPuller(pool)
    pu.get(k0)
    got = pu.get(k1)                         # TypeError retry path
    assert not pu._cross_key_supported
    assert np.array_equal(got["layer0"]["w"], _params()["layer0"]["w"])


# -- CachedPuller ------------------------------------------------------------
def test_cached_puller_reuses_and_updates():
    pool = ModelPool(snapshot_on_pull=True)
    k = ModelKey("main", 0)
    pool.push(k, _params())
    pu = CachedPuller(pool)
    a, ma = pu.get_with_manifest(k)
    b, _ = pu.get_with_manifest(k)
    assert a is b                              # NotModified: same object back
    assert pool.pull_stats["noop"] == 1
    pool.push(k, _params(3.0))
    c, mc = pu.get_with_manifest(k)
    assert mc.version == ma.version + 1
    assert np.array_equal(c["layer0"]["w"], _params(3.0)["layer0"]["w"])
    assert pu.manifest(k).version == mc.version


def test_cached_puller_falls_back_without_pull_if_changed():
    class LegacyPool:
        def __init__(self):
            self.pulls = 0
        def pull(self, key):
            self.pulls += 1
            return {"w": np.ones((2,), np.float32)}

    legacy = LegacyPool()
    pu = CachedPuller(legacy)
    p1, m1 = pu.get_with_manifest("k")
    p2, _ = pu.get_with_manifest("k")
    assert m1 is None and legacy.pulls == 2    # no versioning: plain pulls


# -- InfServer hash-gated hot-swap -------------------------------------------
@pytest.fixture(scope="module")
def infserver_setup():
    import jax

    from repro.configs import get_arch
    from repro.infserver import InfServer
    from repro.models import init_params

    cfg = get_arch("tleague-policy-s")
    params = init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def test_infserver_hot_swap_noops_on_matching_hash(infserver_setup):
    from repro.infserver import InfServer
    from repro.params import build_manifest

    cfg, params = infserver_setup
    server = InfServer(cfg, 6, max_batch=16)
    h = build_manifest(params, 0).tree_hash
    server.register_model("theta", params, content_hash=h, version=0)
    hosted = server._models["theta"]
    assert server.swaps == 1
    # identical refresh: gated off — no re-place, registry object untouched
    server.update_params(params, key="theta", content_hash=h, version=0)
    assert server.swap_noops == 1 and server._models["theta"] is hosted
    assert server.has_model("theta", content_hash=h)
    assert not server.has_model("theta", content_hash="deadbeef")
    # a stale straggler (older pool version) must not regress the route
    server.update_params(params, key="theta", content_hash="old", version=-1)
    assert server.swap_stale_drops == 1 and server._models["theta"] is hosted
    # genuinely new content swaps (and updates the hosted hash)
    import jax
    new = jax.tree.map(lambda x: x + 1, params)
    h2 = build_manifest(new, 1).tree_hash
    server.update_params(new, key="theta", content_hash=h2, version=1)
    assert server.swaps == 2 and server._models["theta"] is not hosted
    assert server.stats()["swap_noops"] == 1


def test_infserver_hot_swap_noop_preserves_stack_cache(infserver_setup):
    """The grouped-path stacked-params cache survives a gated refresh —
    the exact waste the hash gate exists to avoid (on the mesh path the
    same gate also skips the re-shard device_put)."""
    from repro.infserver import InfServer
    from repro.params import build_manifest

    cfg, params = infserver_setup
    server = InfServer(cfg, 6, max_batch=64)
    h = build_manifest(params, 0).tree_hash
    server.register_model("theta", params, content_hash=h)
    server.register_model("phi", params, content_hash=h)
    obs = np.zeros((2, 26), np.int32)
    t1, t2 = server.submit(obs, model="theta"), server.submit(obs, model="phi")
    server.flush()
    server.get(t1), server.get(t2)
    assert len(server._stack_cache) == 1
    stacked = next(iter(server._stack_cache.values()))
    server.update_params(params, key="theta", content_hash=h)   # gated off
    assert next(iter(server._stack_cache.values()), None) is stacked
    server.update_params(params, key="theta")                   # ungated swap
    assert not server._stack_cache


# -- heartbeat ---------------------------------------------------------------
def test_heartbeat_beat_and_stall():
    hb = Heartbeat()
    assert hb.ping() == 0
    hb.beat()
    assert hb.ping() == 1 and not hb.stalled(5.0)
    time.sleep(0.05)
    assert hb.stalled(0.01)
    hb.start_beating(0.02)
    time.sleep(0.2)
    hb.stop_beating()
    assert hb.ping() > 1 and not hb.stalled(1.0)


@pytest.mark.timeout(60)
def test_heartbeat_monitor_detects_wedged_coordinator():
    """A server that ANSWERS pings but whose heartbeat stops advancing is
    wedged: the monitor must fire on_dead (the slow-vs-dead distinction —
    pure transport errors could never catch this case)."""
    from repro.distributed.transport import RpcServer

    hb = Heartbeat()
    hb.start_beating(0.02)
    with RpcServer({"ctrl": hb}) as srv:
        died = threading.Event()
        mon = HeartbeatMonitor(srv.address, interval_s=0.05, timeout_s=0.6,
                               on_dead=died.set)
        mon.start()
        time.sleep(0.4)
        assert not mon.dead                   # beating: alive
        hb.stop_beating()                     # wedge: pings answer, no advance
        assert died.wait(timeout=10.0)
        assert mon.dead
        mon.join(timeout=5.0)
