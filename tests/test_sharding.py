"""Sharding rules: every emitted PartitionSpec divides its dim; the spec
tables cover all assigned archs; a tiny pjit train step lowers on a local
mesh (the 512-device production lowering is exercised by dryrun.py)."""
import functools

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.distributed.sharding import (batch_shardings, param_shardings,
                                        state_shardings)
from repro.launch.dryrun import ASSIGNED
from repro.launch.specs import input_specs
from repro.models import init_params


def fake_mesh(shape=(16, 16), axes=("data", "model")):
    """AbstractMesh: lets us build NamedShardings without 256 devices.

    Version-tolerant: newer JAX takes ((name, size), ...) pairs, older JAX
    takes (shape, axis_names).
    """
    try:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))
    except TypeError:
        return jax.sharding.AbstractMesh(shape, axes)


def _check_divisible(shapes, shardings, mesh):
    flat_s, _ = jax.tree_util.tree_flatten(shapes)
    flat_h, _ = jax.tree_util.tree_flatten(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))
    assert len(flat_s) == len(flat_h)
    for leaf, ns in zip(flat_s, flat_h):
        for dim, ax in zip(leaf.shape, ns.spec):
            if ax is None:
                continue
            axes = (ax,) if isinstance(ax, str) else ax
            size = int(np.prod([mesh.shape[a] for a in axes]))
            assert dim % size == 0, (leaf.shape, ns.spec)


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("mesh_shape", [((16, 16), ("data", "model")),
                                        ((2, 16, 16), ("pod", "data", "model"))])
def test_param_shardings_divisible(arch, mesh_shape):
    cfg = get_arch(arch)
    mesh = fake_mesh(*mesh_shape)
    shapes = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                            jax.random.PRNGKey(0))
    shardings = param_shardings(shapes, cfg, mesh)
    _check_divisible(shapes, shardings, mesh)
    # something is actually model-sharded (TP is on)
    specs = [ns.spec for ns in jax.tree.leaves(
        shardings, is_leaf=lambda x: isinstance(x, jax.sharding.NamedSharding))]
    assert any("model" in str(s) for s in specs), arch


@pytest.mark.parametrize("arch", ASSIGNED)
@pytest.mark.parametrize("shape_name", ["train_4k", "prefill_32k",
                                        "decode_32k", "long_500k"])
def test_input_shardings_divisible(arch, shape_name):
    cfg = get_arch(arch)
    mesh = fake_mesh()
    kind, sp = input_specs(cfg, shape_name)
    if kind == "skip":
        pytest.skip("encoder-only: no decode step")
    if kind == "decode":
        _check_divisible(sp["tokens"], batch_shardings(sp["tokens"], mesh), mesh)
        _check_divisible(sp["state"], state_shardings(sp["state"], cfg, mesh), mesh)
    else:
        _check_divisible(sp, batch_shardings(sp, mesh), mesh)


def test_local_mesh_train_step_lowers():
    """A tiny seq train step lowers+compiles under jit with shardings on the
    1-device local mesh (structure check; scale is dryrun's job)."""
    import jax.numpy as jnp
    from repro.launch.steps import make_dryrun_step
    from repro.launch.mesh import make_local_mesh
    import dataclasses
    cfg = dataclasses.replace(get_arch("tleague-policy-m"), max_position=1 << 20)
    mesh = make_local_mesh()
    with mesh:
        # reuse the factory at a tiny shape by monkeypatching the shape table
        from repro.configs.base import INPUT_SHAPES, InputShape
        INPUT_SHAPES["tiny_train"] = InputShape("tiny_train", 64, 4, "train")
        try:
            built = make_dryrun_step(cfg, "tiny_train", mesh)
            compiled = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                               out_shardings=built["out_shardings"]
                               ).lower(*built["args"]).compile()
            assert compiled.cost_analysis() is not None
        finally:
            INPUT_SHAPES.pop("tiny_train")
