"""RL substrate correctness: GAE/lambda-return/V-trace vs naive numpy loops,
PPO loss behavior, optimizer convergence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import adamw, sgd, clip_by_global_norm
from repro.rl import (categorical_entropy, categorical_kl, categorical_logp,
                      gae, lambda_return, ppo_loss, vtrace)
from repro.rl.ppo import PPOConfig

KEY = jax.random.PRNGKey(3)


def naive_gae(r, v, g, boot, lam):
    B, T = r.shape
    adv = np.zeros((B, T))
    for b in range(B):
        a = 0.0
        for t in reversed(range(T)):
            v1 = boot[b] if t == T - 1 else v[b, t + 1]
            delta = r[b, t] + g[b, t] * v1 - v[b, t]
            a = delta + g[b, t] * lam * a
            adv[b, t] = a
    return adv


def test_gae_matches_naive():
    ks = jax.random.split(KEY, 4)
    B, T = 3, 17
    r = jax.random.normal(ks[0], (B, T))
    v = jax.random.normal(ks[1], (B, T))
    g = (jax.random.bernoulli(ks[2], 0.9, (B, T)) * 0.97).astype(jnp.float32)
    boot = jax.random.normal(ks[3], (B,))
    adv, targ = gae(r, v, g, boot, lam=0.8)
    ref = naive_gae(np.asarray(r), np.asarray(v), np.asarray(g),
                    np.asarray(boot), 0.8)
    np.testing.assert_allclose(np.asarray(adv), ref, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(targ), ref + np.asarray(v),
                               rtol=1e-5, atol=1e-5)


def test_lambda_return_limits():
    """lam=1 -> discounted MC return; lam=0 -> one-step TD target."""
    ks = jax.random.split(KEY, 3)
    B, T = 2, 9
    r = jax.random.normal(ks[0], (B, T))
    v = jax.random.normal(ks[1], (B, T))
    g = 0.9 * jnp.ones((B, T))
    boot = jax.random.normal(ks[2], (B,))
    g1 = lambda_return(r, v, g, boot, lam=1.0)
    mc = np.zeros((B, T))
    for b in range(B):
        acc = float(boot[b])
        for t in reversed(range(T)):
            acc = float(r[b, t]) + 0.9 * acc
            mc[b, t] = acc
    np.testing.assert_allclose(np.asarray(g1), mc, rtol=1e-5, atol=1e-5)
    g0 = lambda_return(r, v, g, boot, lam=0.0)
    v1 = jnp.concatenate([v[:, 1:], boot[:, None]], axis=1)
    np.testing.assert_allclose(np.asarray(g0), np.asarray(r + 0.9 * v1),
                               rtol=1e-5, atol=1e-5)


def test_vtrace_on_policy_reduces_to_lambda_return():
    """pi == mu, clips>=1 -> vs == TD(lam=1) targets (IMPALA appendix)."""
    ks = jax.random.split(KEY, 4)
    B, T = 2, 13
    logp = -jnp.abs(jax.random.normal(ks[0], (B, T)))
    r = jax.random.normal(ks[1], (B, T))
    v = jax.random.normal(ks[2], (B, T))
    g = 0.95 * jnp.ones((B, T))
    boot = jax.random.normal(ks[3], (B,))
    vs, _ = vtrace(logp, logp, r, v, g, boot, lam=1.0)
    ref = lambda_return(r, v, g, boot, lam=1.0)
    np.testing.assert_allclose(np.asarray(vs), np.asarray(ref), rtol=1e-4,
                               atol=1e-4)


def test_categorical_helpers():
    logits = jax.random.normal(KEY, (5, 7))
    a = jnp.argmax(logits, -1)
    lp = categorical_logp(logits, a)
    assert bool((lp <= 0).all())
    ent = categorical_entropy(logits)
    assert bool((ent >= 0).all()) and bool((ent <= np.log(7) + 1e-5).all())
    kl = categorical_kl(logits, logits)
    np.testing.assert_allclose(np.asarray(kl), 0.0, atol=1e-6)
    uniform = jnp.zeros((5, 7))
    assert bool((categorical_kl(logits, uniform) >= -1e-6).all())


def _traj(B, T, key):
    ks = jax.random.split(key, 5)
    return {
        "actions": jax.random.randint(ks[0], (B, T), 0, 4),
        "behavior_logp": -1.5 * jnp.ones((B, T)),
        "behavior_values": jax.random.normal(ks[1], (B, T)),
        "rewards": jax.random.normal(ks[2], (B, T)),
        "discounts": 0.99 * jnp.ones((B, T)),
        "bootstrap_value": jax.random.normal(ks[3], (B,)),
    }


def test_ppo_clip_blocks_large_ratio_gradient():
    """Once the ratio leaves the clip range in the advantage direction, the
    policy gradient through those samples must vanish."""
    B, T, A = 2, 8, 4
    traj = _traj(B, T, KEY)
    hp = PPOConfig(clip_eps=0.2, entropy_coef=0.0, value_coef=0.0,
                   normalize_adv=False)

    def pg_only(logits):
        loss, _ = ppo_loss(logits, jnp.zeros((B, T)), traj, hp)
        return loss

    # logits making every ratio huge (logp ~ 0 vs behavior -1.5)
    logits = jnp.zeros((B, T, A)).at[..., 0].set(50.0)
    traj2 = dict(traj, actions=jnp.zeros((B, T), jnp.int32))
    # positive advantages: rewards large positive
    traj2["rewards"] = jnp.ones((B, T)) * 10.0
    g = jax.grad(lambda lg: ppo_loss(lg, jnp.zeros((B, T)), traj2, hp)[0])(logits)
    assert float(jnp.abs(g).max()) < 1e-4   # clipped => no gradient


def test_adamw_converges_quadratic():
    opt = adamw(0.1)
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(jnp.square(p["w"])))(params)
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_master_fp32_accumulates_small_updates():
    """bf16 params alone would lose 1e-3-scale updates; the fp32 master
    must accumulate them."""
    opt = adamw(1e-3, master_fp32=True)
    params = {"w": jnp.full((4,), 100.0, jnp.bfloat16)}
    state = opt.init(params)
    for _ in range(50):
        grads = {"w": jnp.ones((4,), jnp.bfloat16)}
        params, state, _ = opt.update(grads, state, params)
    assert float(state["master"]["w"][0]) < 100.0 - 0.04


def test_clip_by_global_norm():
    g = {"a": jnp.ones((10,)) * 3.0}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - np.sqrt(90.0)) < 1e-4
    from repro.utils import tree_global_norm
    assert abs(float(tree_global_norm(clipped)) - 1.0) < 1e-5
