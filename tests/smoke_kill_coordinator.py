"""CI smoke (ISSUE 5): wedge the coordinator mid-run and assert the
worker processes exit CLEANLY via the heartbeat timeout instead of
hanging.

Not a pytest module (no `test_` prefix — the scenario takes ~30 s of
wall clock and real SIGSTOP semantics): run as
`PYTHONPATH=src python tests/smoke_kill_coordinator.py`.

The scenario SIGSTOPs the coordinator rather than killing it — a
stopped process keeps its sockets open and never sends RST, so the
legacy TransportError path can never fire and only the heartbeat
monitor (`ctrl.ping` stops advancing) can unblock the workers. Workers
run with `--heartbeat-timeout 6`; the driver asserts both exit 0 within
the deadline and that at least one of them says the heartbeat timed
out.
"""
import os
import pathlib
import re
import signal
import subprocess
import sys
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
SPEC = REPO / "examples" / "league_specs" / "main_minimax.json"
ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.pathsep.join(
    p for p in (str(REPO / "src"), os.environ.get("PYTHONPATH")) if p)

COMMON = ["--env", "rps", "--num-envs", "4", "--unroll-len", "8"]


def spawn(args, **kw):
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.train"] + args,
        env=ENV, cwd=REPO, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True, **kw)


def main() -> int:
    coord = spawn(["--role", "coordinator", "--league-spec", str(SPEC),
                   "--bind", "127.0.0.1:0", "--max-seconds", "300"] + COMMON)
    # the coordinator prints its bound address once serving; a drainer
    # thread scans for it (readline can't be bounded by a deadline from
    # this thread) and KEEPS draining afterwards so a filled pipe never
    # blocks coordinator prints mid-scenario
    import threading

    found = threading.Event()
    box = {}

    def drain():
        for line in coord.stdout:
            m = re.search(r"serving league at (\S+)", line)
            if m and not found.is_set():
                box["address"] = m.group(1)
                found.set()

    threading.Thread(target=drain, daemon=True).start()
    assert found.wait(timeout=60), "coordinator never announced its address"
    address = box["address"]
    print(f"[smoke] coordinator at {address} (pid {coord.pid})", flush=True)

    workers = {
        "learner": spawn(["--role", "learner", "--league-role", "main",
                          "--connect", address,
                          "--heartbeat-timeout", "6"] + COMMON),
        "actor": spawn(["--role", "actor", "--league-role", "main",
                        "--connect", address,
                        "--heartbeat-timeout", "6"] + COMMON),
    }
    time.sleep(15)                      # let the league make real progress
    for name, p in workers.items():
        assert p.poll() is None, f"{name} died before the fault injection"

    print(f"[smoke] SIGSTOP coordinator (wedged: sockets open, no RST)",
          flush=True)
    os.kill(coord.pid, signal.SIGSTOP)

    # heartbeat timeout is 6 s; allow generous slack for jit/env teardown
    outs, codes = {}, {}
    join_deadline = time.monotonic() + 120
    try:
        for name, p in workers.items():
            try:
                outs[name], _ = p.communicate(
                    timeout=max(1.0, join_deadline - time.monotonic()))
                codes[name] = p.returncode
            except subprocess.TimeoutExpired:
                p.kill()
                outs[name], _ = p.communicate()
                codes[name] = "HUNG"
    finally:
        os.kill(coord.pid, signal.SIGCONT)
        coord.terminate()
        try:
            coord.wait(timeout=30)
        except subprocess.TimeoutExpired:
            coord.kill()

    ok = True
    for name in workers:
        print(f"[smoke] {name}: exit={codes[name]}", flush=True)
        tail = "\n".join(outs[name].splitlines()[-10:])
        print(f"--- {name} output tail ---\n{tail}", flush=True)
        if codes[name] != 0:
            ok = False
    if not any("heartbeat timed out" in outs[n] for n in workers):
        print("[smoke] FAIL: no worker reported a heartbeat timeout",
              flush=True)
        ok = False
    print(f"[smoke] {'PASS' if ok else 'FAIL'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
