"""Remaining integration paths: the V-trace learner (the paper's second
proxy-RL) end-to-end through actor segments, and the serving driver."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import LeagueMgr
from repro.envs import make_env
from repro.learners import Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw


def test_vtrace_learner_end_to_end():
    cfg = get_arch("tleague-policy-s")
    env = make_env("rps")
    params = init_params(jax.random.PRNGKey(1), cfg)
    league = LeagueMgr()
    league.add_learning_agent("main", params)
    actor = Actor(env, cfg, league, num_envs=4, unroll_len=8, seed=2)
    opt = adamw(3e-4, clip_norm=1.0)
    step = build_env_train_step(cfg, env.spec.num_actions, opt, loss="vtrace")
    learner = Learner(league, step, opt, params)
    for _ in range(2):
        traj, _ = actor.run_segment()
        learner.data_server.put(traj)
        m = learner.learn()
    assert np.isfinite(float(m["loss"]))
    assert np.isfinite(float(m["pg_loss"]))
    assert float(m["entropy"]) > 0


def test_serve_driver_smoke():
    from repro.launch.serve import serve
    out = serve("tleague-policy-s", smoke=True, batch=2, prompt_len=16,
                new_tokens=3, verbose=False)
    assert len(out) == 3
    for t in out:
        assert t.shape == (2, 1)
        assert 0 <= int(t[0, 0]) < 512
