"""The rebuilt league data plane (§3.2 hot paths): continuous-batching
InfServer multi-model routing, ring-buffer DataServer wraparound accounting,
and the vectorized PayoffMatrix vs a straight reimplementation of the seed
per-pair-loop semantics."""
import threading

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import MatchResult, ModelKey, PayoffMatrix
from repro.infserver import InfServer, Ticket
from repro.learners import DataServer
from repro.models import init_params


# ---------------------------------------------------------------------------
# InfServer: multi-model routing
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    cfg = get_arch("tleague-policy-s")
    theta = init_params(jax.random.PRNGKey(0), cfg)
    phi = init_params(jax.random.PRNGKey(1), cfg)      # distinct weights
    return cfg, theta, phi


def test_multi_model_routing_returns_correct_params(served):
    from repro.actors.policy import make_obs_policy
    cfg, theta, phi = served
    num_actions, obs_len = 6, 26
    server = InfServer(cfg, num_actions, max_batch=64)
    k_t, k_p = ModelKey("main", 3), ModelKey("main", 0)
    server.register_model(k_t, theta)
    server.register_model(k_p, phi)

    rng = np.random.default_rng(0)
    obs_a = rng.integers(0, 16, (3, obs_len)).astype(np.int32)
    obs_b = rng.integers(0, 16, (5, obs_len)).astype(np.int32)
    t1 = server.submit(obs_a, model=k_t)
    t2 = server.submit(obs_b, model=k_p)
    t3 = server.submit(obs_a, model=k_p)
    assert isinstance(t1, Ticket) and not t1.done()
    server.flush()                                     # one grouped forward
    assert server.batches_run == 1 and server.last_batch_models == 2

    # values are rng-free, so they pin which params served each ticket
    policy = make_obs_policy(cfg, num_actions)
    v_theta = np.asarray(policy.logits_values(theta, obs_a)[1])
    v_phi_b = np.asarray(policy.logits_values(phi, obs_b)[1])
    v_phi_a = np.asarray(policy.logits_values(phi, obs_a)[1])
    np.testing.assert_allclose(t1.result()[2], v_theta, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(t2.result()[2], v_phi_b, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(t3.result()[2], v_phi_a, rtol=1e-4, atol=1e-5)
    assert not np.allclose(v_theta, v_phi_a)           # routes are distinct
    st = server.stats()
    assert 0 < st["occupancy"] <= 1.0 and st["models_hosted"] == 2


def test_hot_swap_changes_route_without_new_model(served):
    cfg, theta, phi = served
    server = InfServer(cfg, 6, theta, max_batch=16)
    obs = np.zeros((2, 26), np.int32)
    v_before = server.get(server.submit(obs))[2]
    server.update_params(phi)                          # hot-swap default θ
    v_after = server.get(server.submit(obs))[2]
    assert not np.allclose(v_before, v_after)
    assert server.stats()["models_hosted"] == 1


def test_full_queue_triggers_flush(served):
    cfg, theta, _ = served
    server = InfServer(cfg, 6, theta, max_batch=4)
    obs = np.zeros((2, 26), np.int32)
    t1 = server.submit(obs)
    assert server.queue_depth == 2 and not t1.done()
    t2 = server.submit(obs)                            # 4 rows -> auto-flush
    assert server.queue_depth == 0 and t1.done() and t2.done()


# ---------------------------------------------------------------------------
# DataServer: ring-buffer wraparound + rfps/cfps accounting
# ---------------------------------------------------------------------------
def _traj(seed, rows=4, t=8, obs_len=3):
    rng = np.random.default_rng(seed)
    return {
        "obs": rng.integers(0, 9, (rows, t, obs_len)).astype(np.int32),
        "actions": rng.integers(0, 6, (rows, t)).astype(np.int32),
        "behavior_logp": rng.normal(size=(rows, t)).astype(np.float32),
        "behavior_values": rng.normal(size=(rows, t)).astype(np.float32),
        "rewards": rng.normal(size=(rows, t)).astype(np.float32),
        "done": rng.integers(0, 2, (rows, t)).astype(bool),
        "bootstrap_value": rng.normal(size=(rows,)).astype(np.float32),
    }


def test_ring_wraparound_preserves_accounting_and_content():
    ds = DataServer(capacity_frames=6 * 8, blocking=True)   # 6 row slots
    n_puts = 5
    for i in range(n_puts):
        ds.put(_traj(i))
        got = ds.sample()                 # blocking: the segment just put
        want = _traj(i)
        for k in want:
            np.testing.assert_array_equal(np.asarray(got[k]), want[k], err_msg=k)
    # 5 puts x 4 rows into 6 slots: wrapped, live size capped at capacity
    assert ds.num_rows == 6 and ds.size_frames == 48
    assert ds.frames_received == n_puts * 4 * 8
    assert ds.frames_consumed == n_puts * 4 * 8
    tp = ds.throughput()
    assert abs(tp["repeat_ratio"] - 1.0) < 1e-9
    assert tp["rfps"] > 0 and tp["cfps"] > 0


def test_blocking_semantics_and_uniform_gather():
    ds = DataServer(capacity_frames=64 * 8, blocking=True)
    ds.put(_traj(0))
    assert ds.ready()
    ds.sample()
    assert not ds.ready()                 # on-policy: wait for fresh frames
    ds.put(_traj(1))
    assert ds.ready()

    ds2 = DataServer(capacity_frames=64 * 8, blocking=False, seed=3)
    for i in range(4):
        ds2.put(_traj(i))
    mb = ds2.sample(batch_rows=10)        # vectorized gather across segments
    assert np.asarray(mb["actions"]).shape == (10, 8)
    assert np.asarray(mb["obs"]).shape == (10, 8, 3)
    assert ds2.frames_consumed == 10 * 8


def test_sample_to_device_matches_host_sample_and_prefetches():
    """The pipelined device feed returns the same minibatch the host path
    would, as device arrays, and the blocking-mode prefetch staged at `put`
    is actually used."""
    ds = DataServer(capacity_frames=64 * 8, blocking=True, prefetch=True)
    for i in range(3):
        ds.put(_traj(i))
        got = ds.sample_to_device()           # staged by the put above
        want = _traj(i)
        for k in want:
            leaf = got[k]
            assert isinstance(leaf, jax.Array), k
            np.testing.assert_array_equal(np.asarray(leaf), want[k], err_msg=k)
    assert ds.prefetch_hits == 3 and ds.prefetch_misses == 0
    assert ds.frames_consumed == 3 * 4 * 8    # accounting identical to sample()
    assert not ds.ready()                     # on-policy semantics preserved


def test_sample_to_device_staleness_and_uniform_prefetch():
    # blocking: two puts before a sample -> the first staged batch is stale
    ds = DataServer(capacity_frames=64 * 8, blocking=True, prefetch=True)
    ds.put(_traj(0))
    ds.put(_traj(1))
    got = ds.sample_to_device()               # must be the NEWEST segment
    np.testing.assert_array_equal(np.asarray(got["actions"]),
                                  _traj(1)["actions"])

    # uniform mode: staging happens after a sample; a put in between
    # invalidates it (rows may have been overwritten)
    ds2 = DataServer(capacity_frames=64 * 8, blocking=False, seed=5,
                     prefetch=True)
    for i in range(3):
        ds2.put(_traj(i))
    a = ds2.sample_to_device(batch_rows=6)    # miss (nothing staged yet)
    b = ds2.sample_to_device(batch_rows=6)    # hit (staged after a)
    ds2.put(_traj(9))
    c = ds2.sample_to_device(batch_rows=6)    # stale -> miss, fresh gather
    assert ds2.prefetch_hits == 1 and ds2.prefetch_misses == 2
    for mb in (a, b, c):
        assert np.asarray(mb["actions"]).shape == (6, 8)

    # host sample() on a prefetch server stays numpy and unaffected
    ds3 = DataServer(capacity_frames=64 * 8, blocking=True, prefetch=True)
    ds3.put(_traj(0))
    assert isinstance(ds3.sample()["actions"], np.ndarray)


def test_explicit_batch_rows_never_served_from_onpolicy_stage():
    """A batch staged for the on-policy newest-segment request (put in
    blocking mode) must not answer an explicit uniform batch_rows request
    of the same size — the row distributions differ."""
    ds = DataServer(capacity_frames=64 * 8, blocking=True, prefetch=True,
                    seed=11)
    for i in range(8):
        ds.put(_traj(i))                       # stages newest segment (4 rows)
    got = ds.sample_to_device(batch_rows=4)    # uniform request, same size
    assert ds.prefetch_hits == 0 and ds.prefetch_misses == 1
    # must follow the same rng stream as the host sample() path would
    ref = DataServer(capacity_frames=64 * 8, blocking=True, prefetch=False,
                     seed=11)
    for i in range(8):
        ref.put(_traj(i))
    want = ref.sample(batch_rows=4)
    np.testing.assert_array_equal(np.asarray(got["actions"]),
                                  np.asarray(want["actions"]))


def test_served_flag_league_training_smoke():
    """launch/train.py --served: all actors share one InfServer and the
    run produces loss rows (and never loss=nan placeholder rows)."""
    from repro.launch.train import run_league_training
    league, agents, history = run_league_training(
        env_name="rps", periods=1, steps_per_period=2, num_envs=4,
        unroll_len=8, served=True, verbose=False)
    assert len(league.league_state()["frozen_pool"]) >= 1
    assert all(("loss" in r) != ("skipped" in r) for r in history)
    losses = [r["loss"] for r in history if "loss" in r]
    assert losses and all(np.isfinite(losses))


def test_structure_change_is_rejected():
    ds = DataServer(capacity_frames=64)
    ds.put(_traj(0))
    with pytest.raises(AssertionError):
        bad = _traj(1)
        del bad["rewards"]
        ds.put(bad)


# ---------------------------------------------------------------------------
# PayoffMatrix: vectorized == seed per-pair-loop implementation
# ---------------------------------------------------------------------------
class _SeedPayoff:
    """The seed implementation's exact semantics (dict-of-dicts loops),
    kept here as the oracle for the vectorized rewrite."""

    def __init__(self, elo_k=16.0, init_elo=1200.0):
        self.models, self._index = [], {}
        self._wins = np.zeros((0, 0)); self._ties = np.zeros((0, 0))
        self._losses = np.zeros((0, 0))
        self.elo, self.elo_k, self.init_elo = {}, elo_k, init_elo

    def add_model(self, key):
        if key in self._index:
            return
        self._index[key] = len(self.models)
        self.models.append(key)
        n = len(self.models)
        for name in ("_wins", "_ties", "_losses"):
            m = getattr(self, name)
            g = np.zeros((n, n)); g[:m.shape[0], :m.shape[1]] = m
            setattr(self, name, g)
        self.elo[key] = self.init_elo

    def record(self, r):
        i = self._index[r.learner_key]
        for opp in r.opponent_keys:
            j = self._index[opp]
            if r.outcome > 0:
                self._wins[i, j] += 1; self._losses[j, i] += 1
            elif r.outcome < 0:
                self._losses[i, j] += 1; self._wins[j, i] += 1
            else:
                self._ties[i, j] += 1; self._ties[j, i] += 1
            ra, rb = self.elo[r.learner_key], self.elo[opp]
            ea = 1.0 / (1.0 + 10 ** ((rb - ra) / 400.0))
            sa = 0.5 + 0.5 * r.outcome
            self.elo[r.learner_key] = ra + self.elo_k * (sa - ea)
            self.elo[opp] = rb + self.elo_k * ((1.0 - sa) - (1.0 - ea))

    def games(self, a, b):
        i, j = self._index[a], self._index[b]
        return self._wins[i, j] + self._ties[i, j] + self._losses[i, j]

    def winrate(self, a, b, prior=0.5, prior_games=2.0):
        i, j = self._index[a], self._index[b]
        w = self._wins[i, j] + 0.5 * self._ties[i, j] + prior * prior_games
        return float(w / (self.games(a, b) + prior_games))

    def matrix(self):
        n = len(self.models)
        out = np.full((n, n), 0.5)
        for i, a in enumerate(self.models):
            for j, b in enumerate(self.models):
                if i != j and self.games(a, b) > 0:
                    out[i, j] = self.winrate(a, b)
        return out


def _match_log(n_models=50, n_matches=5000, seed=7):
    rng = np.random.default_rng(seed)
    keys = [ModelKey("m", v) for v in range(n_models)]
    log = []
    for _ in range(n_matches):
        i, j = rng.choice(n_models, 2, replace=False)
        log.append(MatchResult(learner_key=keys[i], opponent_keys=(keys[j],),
                               outcome=int(rng.choice([-1, 0, 1]))))
    return keys, log


def test_vectorized_payoff_matches_seed_on_replay():
    """Acceptance: numerically identical on a 50-model, 5k-match replay."""
    keys, log = _match_log()
    ref, vec = _SeedPayoff(), PayoffMatrix()
    for k in keys:
        ref.add_model(k)
        vec.add_model(k)
    for r in log:
        ref.record(r)
    vec.record_many(log)                  # batched flood ingest

    np.testing.assert_array_equal(vec.wins, ref._wins)
    np.testing.assert_array_equal(vec.ties, ref._ties)
    np.testing.assert_array_equal(vec.losses, ref._losses)
    np.testing.assert_allclose(vec.matrix(), ref.matrix(), rtol=0, atol=1e-12)
    for k in keys:
        assert abs(vec.elo[k] - ref.elo[k]) < 1e-9
    a = keys[0]
    np.testing.assert_allclose(
        vec.winrates_vs(a, keys[1:]),
        np.array([ref.winrate(a, o) for o in keys[1:]]), atol=1e-12)
    assert vec.games(keys[0], keys[1]) == ref.games(keys[0], keys[1])


def test_record_one_by_one_equals_record_many():
    keys, log = _match_log(n_models=8, n_matches=300, seed=11)
    p1, p2 = PayoffMatrix(), PayoffMatrix()
    for k in keys:
        p1.add_model(k)
        p2.add_model(k)
    for r in log:
        p1.record(r)
    p2.record_many(log)
    np.testing.assert_array_equal(p1.wins, p2.wins)
    np.testing.assert_allclose(p1.matrix(), p2.matrix(), atol=0)
    for k in keys:
        assert p1.elo[k] == p2.elo[k]


def test_geometric_growth_preserves_counts():
    p = PayoffMatrix()
    keys = [ModelKey("g", v) for v in range(65)]       # forces several growths
    p.add_model(keys[0]); p.add_model(keys[1])
    p.record(MatchResult(learner_key=keys[0], opponent_keys=(keys[1],),
                         outcome=+1))
    for k in keys[2:]:
        p.add_model(k)
    assert p._cap >= 65 and len(p) == 65
    assert p.games(keys[0], keys[1]) == 1
    assert p.winrate(keys[0], keys[1]) == (1 + 1) / 3  # (1 + 0.5*2)/(1+2)
    m = p.matrix()
    assert m.shape == (65, 65) and m[0, 1] == (1 + 1) / 3 and m[2, 3] == 0.5


# ---------------------------------------------------------------------------
# Served actor path: InfServer-backed rollout equals the local-mode contract
# ---------------------------------------------------------------------------
def test_served_actor_matches_local_structure(served):
    from repro.actors import Actor
    from repro.core import LeagueMgr
    from repro.envs import make_env
    cfg, theta, _ = served
    env = make_env("rps")
    league = LeagueMgr()
    league.add_learning_agent("main", theta)
    server = InfServer(cfg, env.spec.num_actions, max_batch=64)
    actor = Actor(env, cfg, league, num_envs=4, unroll_len=8, seed=1,
                  inf_server=server)
    local = Actor(env, cfg, league, num_envs=4, unroll_len=8, seed=1)
    traj_s, _ = actor.run_segment()
    traj_l, _ = local.run_segment()
    assert set(traj_s) == set(traj_l)
    for k in traj_l:
        assert np.asarray(traj_s[k]).shape == np.asarray(traj_l[k]).shape, k
    assert server.requests_served > 0 and server.batches_run > 0
    assert np.isfinite(np.asarray(traj_s["behavior_logp"])).all()


# ---------------------------------------------------------------------------
# Concurrency: ticket-TTL expiry and serving-scope thread isolation
# ---------------------------------------------------------------------------
def test_ticket_ttl_expires_abandoned_results_under_concurrency(served):
    """Crashed actors leak resolved tickets; a live fleet of submitters
    must not let those results accumulate. Half the workers abandon every
    other ticket (submit, never get); the TTL sweep inside flush() must
    reclaim exactly those, while every collected ticket resolves clean."""
    cfg, theta, _ = served
    ttl = 16          # wide enough that a descheduled collector never
    server = InfServer(cfg, 6, theta, max_batch=8,   # loses its result
                       ticket_ttl_flushes=ttl)
    obs_len, iters = 26, 20
    errors: list = []
    abandoned = [0, 0, 0, 0]

    def worker(i, abandons):
        rng = np.random.default_rng(i)
        try:
            for j in range(iters):
                obs = rng.integers(0, 16, (2, obs_len)).astype(np.int32)
                t = server.submit(obs)
                if abandons and j % 2 == 0:
                    abandoned[i] += 1            # crashed actor: no get()
                    continue
                a, logp, v = server.get(t)
                if a.shape != (2,) or not np.isfinite(v).all():
                    errors.append(f"worker {i} iter {j}: bad result")
        except Exception as e:                    # pragma: no cover - failure path
            errors.append(f"worker {i}: {e!r}")

    threads = [threading.Thread(target=worker, args=(i, i % 2 == 0))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors, errors

    n_abandoned = sum(abandoned)
    assert n_abandoned == 2 * (iters // 2)
    # push the flush counter past the TTL window of the last abandoned
    # result; every one of them must be swept, every collected ticket
    # already popped its retention entry on get
    driver = np.zeros((2, obs_len), np.int32)
    for _ in range(ttl + 2):
        server.get(server.submit(driver))
    st = server.stats()
    assert server.tickets_expired == n_abandoned
    assert st["results_held"] == 0
    assert st["rows_served"] == 2 * (4 * iters + ttl + 2)


def test_serving_scope_is_thread_local_and_env_gated(monkeypatch):
    """dispatch.serving() marks inference traces for the bf16 forward;
    the scope must never bleed into a learner thread tracing concurrently
    or survive scope exit, and unknown modes must be inert."""
    from repro.kernels import dispatch

    monkeypatch.setenv("REPRO_KERNELS_INFER", "bf16")
    assert dispatch.infer_mode() is None          # no scope: flag is inert
    seen: dict = {}
    inside, release = threading.Event(), threading.Event()

    def server_thread():
        with dispatch.serving():
            seen["in_scope"] = dispatch.infer_mode()
            inside.set()
            release.wait(5)                       # hold the scope open ...
        seen["after_scope"] = dispatch.infer_mode()

    def learner_thread():
        inside.wait(5)                            # ... while a learner traces
        seen["other_thread"] = dispatch.infer_mode()
        release.set()

    threads = [threading.Thread(target=server_thread),
               threading.Thread(target=learner_thread)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert seen["in_scope"] == "bf16"
    assert seen["other_thread"] is None           # thread-local, no bleed
    assert seen["after_scope"] is None
    assert dispatch.infer_mode() is None          # main thread untouched

    # many threads toggling scopes concurrently: each sees exactly its own
    mismatches: list = []

    def toggler(i):
        for _ in range(200):
            with dispatch.serving():
                if dispatch.infer_mode() != "bf16":
                    mismatches.append((i, "in"))
            if dispatch.infer_mode() is not None:
                mismatches.append((i, "out"))

    threads = [threading.Thread(target=toggler, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not mismatches

    monkeypatch.setenv("REPRO_KERNELS_INFER", "fp4")   # not a known mode
    with dispatch.serving():
        assert dispatch.infer_mode() is None
