"""Docs stay truthful (ISSUE 4 acceptance): `docs/architecture.md`
exists and every `repro.*` module it names resolves to an importable
module, and every relative markdown link in README/docs/ points at a
file that exists. This is also exactly what the CI docs job runs."""
import importlib
import pathlib
import re

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

# dotted module references like `repro.core.league_mgr` (inside backticks
# or table cells); a trailing .py/function suffix is stripped
_MODULE_RE = re.compile(r"`(repro(?:\.[a-z_0-9]+)+)`")
_LINK_RE = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def test_architecture_doc_exists():
    assert (REPO / "docs" / "architecture.md").is_file()
    assert (REPO / "docs" / "benchmarks.md").is_file()


def test_architecture_map_modules_resolve():
    text = (REPO / "docs" / "architecture.md").read_text()
    names = sorted(set(_MODULE_RE.findall(text)))
    assert names, "the architecture map should name repro modules"
    for name in names:
        importlib.import_module(name)


@pytest.mark.parametrize("doc", DOC_FILES, ids=lambda p: p.name)
def test_markdown_links_resolve(doc):
    text = doc.read_text()
    for target in _LINK_RE.findall(text):
        if "://" in target or target.startswith("mailto:"):
            continue                       # external links: not checked offline
        resolved = (doc.parent / target).resolve()
        assert resolved.exists(), f"{doc.name}: broken link -> {target}"


def test_readme_names_every_bench_file():
    """Every BENCH_*.json at the repo root is documented in README and in
    docs/benchmarks.md."""
    readme = (REPO / "README.md").read_text()
    schema_doc = (REPO / "docs" / "benchmarks.md").read_text()
    for bench in sorted(REPO.glob("BENCH_*.json")):
        assert bench.name in readme, f"README does not mention {bench.name}"
        assert bench.name in schema_doc, (
            f"docs/benchmarks.md does not document {bench.name}")
