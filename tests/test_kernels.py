"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flash_attention, reverse_discounted_scan, rmsnorm
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref
from repro.rl.returns import gae
from repro.rl.vtrace import vtrace

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,KV,Tq,Tk,d", [
    (1, 4, 2, 128, 128, 64),
    (2, 8, 8, 64, 64, 32),      # MHA (KV == H)
    (1, 4, 1, 256, 256, 64),    # MQA
    (2, 6, 2, 96, 160, 64),     # ragged: padding path, cross lengths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, KV, Tq, Tk, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Tq, d), dtype)
    k = jax.random.normal(ks[1], (B, KV, Tk, d), dtype)
    v = jax.random.normal(ks[2], (B, KV, Tk, d), dtype)
    causal = Tq == Tk
    o = flash_attention(q, k, v, d ** -0.5, causal, 0, 0.0, 64, 64, True)
    r = attention_ref(q, k, v, scale=d ** -0.5, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,cap", [(0, 0.0), (32, 0.0), (0, 50.0),
                                        (64, 30.0)])
def test_flash_attention_window_softcap(window, cap):
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 2, 4, 2, 128, 64
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    o = flash_attention(q, k, v, d ** -0.5, True, window, cap, 64, 64, True)
    r = attention_ref(q, k, v, scale=d ** -0.5, causal=True, window=window,
                      cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_grad():
    """custom_vjp backward (recompute through ref) matches ref autodiff."""
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 1, 2, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))

    f_k = lambda q, k, v: jnp.sum(jnp.square(
        flash_attention(q, k, v, d ** -0.5, True, 0, 0.0, 32, 32, True)))
    f_r = lambda q, k, v: jnp.sum(jnp.square(
        attention_ref(q, k, v, scale=d ** -0.5, causal=True)))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("B,T", [(1, 7), (8, 64), (13, 100), (32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reverse_scan_shapes(B, T, dtype):
    ks = jax.random.split(KEY, 3)
    deltas = jax.random.normal(ks[0], (B, T), dtype)
    decays = (jax.random.uniform(ks[1], (B, T)) * 0.99).astype(dtype)
    init = jax.random.normal(ks[2], (B,))
    y = reverse_discounted_scan(deltas, decays, init, interpret=True)
    r = reverse_discounted_scan_ref(deltas, decays, init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-3,
                               atol=1e-3)


def test_scan_kernel_equals_gae():
    """The kernel primitive computes GAE exactly: adv = scan(deltas, g*lam)."""
    ks = jax.random.split(KEY, 4)
    B, T = 4, 37
    rewards = jax.random.normal(ks[0], (B, T))
    values = jax.random.normal(ks[1], (B, T))
    discounts = (jax.random.bernoulli(ks[2], 0.95, (B, T)) * 0.99).astype(jnp.float32)
    boot = jax.random.normal(ks[3], (B,))
    adv, _ = gae(rewards, values, discounts, boot, lam=0.9)
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    deltas = rewards + discounts * v_tp1 - values
    y = reverse_discounted_scan(deltas, discounts * 0.9, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(adv), rtol=1e-5,
                               atol=1e-5)


def test_scan_kernel_equals_vtrace():
    """vs - v == scan(rho*delta, gamma*c) — the V-trace recursion."""
    ks = jax.random.split(KEY, 6)
    B, T = 3, 21
    b_logp = -jnp.abs(jax.random.normal(ks[0], (B, T)))
    t_logp = -jnp.abs(jax.random.normal(ks[1], (B, T)))
    rewards = jax.random.normal(ks[2], (B, T))
    values = jax.random.normal(ks[3], (B, T))
    discounts = 0.99 * jnp.ones((B, T))
    boot = jax.random.normal(ks[4], (B,))
    vs, _ = vtrace(b_logp, t_logp, rewards, values, discounts, boot)
    rho = jnp.minimum(1.0, jnp.exp(t_logp - b_logp))
    c = jnp.minimum(1.0, jnp.exp(t_logp - b_logp))
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    deltas = rho * (rewards + discounts * v_tp1 - values)
    acc = reverse_discounted_scan(deltas, discounts * c, interpret=True)
    np.testing.assert_allclose(np.asarray(values + acc), np.asarray(vs),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 128), (2, 3, 256), (1, 7, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    y = rmsnorm(x, w, interpret=True)
    r = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))
