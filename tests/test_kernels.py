"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU), plus the dispatch layer
that routes models/ and rl/ through them."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (dispatch, flash_attention, reverse_discounted_scan,
                           rmsnorm)
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref
from repro.rl.returns import discounted_return, gae, lambda_return
from repro.rl.vtrace import vtrace

KEY = jax.random.PRNGKey(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("B,H,KV,Tq,Tk,d", [
    (1, 4, 2, 128, 128, 64),
    (2, 8, 8, 64, 64, 32),      # MHA (KV == H)
    (1, 4, 1, 256, 256, 64),    # MQA
    (2, 6, 2, 96, 160, 64),     # ragged: padding path, cross lengths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, KV, Tq, Tk, d, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, H, Tq, d), dtype)
    k = jax.random.normal(ks[1], (B, KV, Tk, d), dtype)
    v = jax.random.normal(ks[2], (B, KV, Tk, d), dtype)
    causal = Tq == Tk
    o = flash_attention(q, k, v, d ** -0.5, causal, 0, 0.0, 64, 64, True)
    r = attention_ref(q, k, v, scale=d ** -0.5, causal=causal)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,cap", [(0, 0.0), (32, 0.0), (0, 50.0),
                                        (64, 30.0)])
def test_flash_attention_window_softcap(window, cap):
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 2, 4, 2, 128, 64
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    o = flash_attention(q, k, v, d ** -0.5, True, window, cap, 64, 64, True)
    r = attention_ref(q, k, v, scale=d ** -0.5, causal=True, window=window,
                      cap=cap)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_flash_attention_grad():
    """custom_vjp backward (recompute through ref) matches ref autodiff."""
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 1, 2, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))

    f_k = lambda q, k, v: jnp.sum(jnp.square(
        flash_attention(q, k, v, d ** -0.5, True, 0, 0.0, 32, 32, True)))
    f_r = lambda q, k, v: jnp.sum(jnp.square(
        attention_ref(q, k, v, scale=d ** -0.5, causal=True)))
    gk = jax.grad(f_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(f_r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4,
                                   atol=1e-4)


@pytest.mark.parametrize("B,T", [(1, 7), (8, 64), (13, 100), (32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_reverse_scan_shapes(B, T, dtype):
    ks = jax.random.split(KEY, 3)
    deltas = jax.random.normal(ks[0], (B, T), dtype)
    decays = (jax.random.uniform(ks[1], (B, T)) * 0.99).astype(dtype)
    init = jax.random.normal(ks[2], (B,))
    y = reverse_discounted_scan(deltas, decays, init, interpret=True)
    r = reverse_discounted_scan_ref(deltas, decays, init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=1e-3,
                               atol=1e-3)


def test_scan_kernel_equals_gae():
    """The kernel primitive computes GAE exactly: adv = scan(deltas, g*lam)."""
    ks = jax.random.split(KEY, 4)
    B, T = 4, 37
    rewards = jax.random.normal(ks[0], (B, T))
    values = jax.random.normal(ks[1], (B, T))
    discounts = (jax.random.bernoulli(ks[2], 0.95, (B, T)) * 0.99).astype(jnp.float32)
    boot = jax.random.normal(ks[3], (B,))
    adv, _ = gae(rewards, values, discounts, boot, lam=0.9)
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    deltas = rewards + discounts * v_tp1 - values
    y = reverse_discounted_scan(deltas, discounts * 0.9, interpret=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(adv), rtol=1e-5,
                               atol=1e-5)


def test_scan_kernel_equals_vtrace():
    """vs - v == scan(rho*delta, gamma*c) — the V-trace recursion."""
    ks = jax.random.split(KEY, 6)
    B, T = 3, 21
    b_logp = -jnp.abs(jax.random.normal(ks[0], (B, T)))
    t_logp = -jnp.abs(jax.random.normal(ks[1], (B, T)))
    rewards = jax.random.normal(ks[2], (B, T))
    values = jax.random.normal(ks[3], (B, T))
    discounts = 0.99 * jnp.ones((B, T))
    boot = jax.random.normal(ks[4], (B,))
    vs, _ = vtrace(b_logp, t_logp, rewards, values, discounts, boot)
    rho = jnp.minimum(1.0, jnp.exp(t_logp - b_logp))
    c = jnp.minimum(1.0, jnp.exp(t_logp - b_logp))
    v_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
    deltas = rho * (rewards + discounts * v_tp1 - values)
    acc = reverse_discounted_scan(deltas, discounts * c, interpret=True)
    np.testing.assert_allclose(np.asarray(values + acc), np.asarray(vs),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("shape", [(4, 128), (2, 3, 256), (1, 7, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_shapes(shape, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], shape, dtype)
    w = jax.random.normal(ks[1], (shape[-1],), jnp.float32)
    y = rmsnorm(x, w, interpret=True)
    r = rmsnorm_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


# ---------------------------------------------------------------------------
# Dispatch layer: the routing models/ and rl/ actually use
# ---------------------------------------------------------------------------
def test_dispatch_mode_resolution():
    assert dispatch.resolve() in ("compiled", "interpret", "fast", "reference")
    with dispatch.force("reference"):
        assert dispatch.resolve() == "reference" and not dispatch.use_pallas()
        with dispatch.force("interpret"):
            assert dispatch.resolve() == "interpret" and dispatch.use_pallas()
        assert dispatch.resolve() == "reference"   # nesting restores
    with dispatch.force("auto"):
        on_accel = jax.default_backend() in ("tpu", "gpu")
        # auto routes CPU hosts to the fast tier, never the O(T^2) oracle
        assert dispatch.resolve() == ("compiled" if on_accel else "fast")
        assert dispatch.use_pallas() == on_accel


def test_dispatch_block_selection_is_shape_aware():
    assert dispatch.rmsnorm_block(4096, 128) > dispatch.rmsnorm_block(16, 128)
    assert dispatch.rmsnorm_block(16, 128) >= 8
    bq, bk = dispatch.attention_blocks(1, 1, 64, jnp.float32)
    assert bq == 8 and bk == 8                      # T=1 floors, not 128
    bq16, _ = dispatch.attention_blocks(256, 256, 64, jnp.bfloat16)
    assert bq16 >= 16                               # bf16 sublane floor
    assert dispatch.scan_block(8192, 16) > dispatch.scan_block(8, 16)


@pytest.mark.parametrize("B,T", [(13, 100), (1, 1), (5, 1), (32, 7)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_scan_odd_shapes(B, T, dtype):
    """B not divisible by the block, T=1 degenerate unrolls."""
    ks = jax.random.split(KEY, 3)
    deltas = jax.random.normal(ks[0], (B, T), dtype)
    decays = (jax.random.uniform(ks[1], (B, T)) * 0.99).astype(dtype)
    init = jax.random.normal(ks[2], (B,))
    with dispatch.force("interpret"):
        y = dispatch.reverse_scan(deltas, decays, init)
    with dispatch.force("reference"):
        r = dispatch.reverse_scan(deltas, decays, init)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=tol, atol=tol)


@pytest.mark.parametrize("B,T,d", [(13, 64, 384), (3, 1, 128), (1, 5, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_rmsnorm_odd_shapes(B, T, d, dtype):
    ks = jax.random.split(KEY, 2)
    x = jax.random.normal(ks[0], (B, T, d), dtype)
    w = jax.random.normal(ks[1], (d,), jnp.float32)
    with dispatch.force("interpret"):
        y = dispatch.rmsnorm(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(rmsnorm_ref(x, w), np.float32),
                               **_tol(dtype))


@pytest.mark.parametrize("Tq,Tk,window,cap", [
    (1, 96, 0, 0.0),        # single-query (decode-like) row
    (96, 96, 32, 0.0),      # sliding window
    (96, 96, 0, 30.0),      # gemma2 softcap
    (100, 100, 24, 50.0),   # both, T not a block multiple
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dispatch_attention_variants(Tq, Tk, window, cap, dtype):
    ks = jax.random.split(KEY, 3)
    B, H, KV, d = 2, 4, 2, 32
    q = jax.random.normal(ks[0], (B, H, Tq, d), dtype)
    k = jax.random.normal(ks[1], (B, KV, Tk, d), dtype)
    v = jax.random.normal(ks[2], (B, KV, Tk, d), dtype)
    causal = Tq == Tk
    with dispatch.force("interpret"):
        o = dispatch.attention(q, k, v, scale=d ** -0.5, causal=causal,
                               window=window, cap=cap)
    r = attention_ref(q, k, v, scale=d ** -0.5, causal=causal, window=window,
                      cap=cap)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), **_tol(dtype))


def test_returns_identical_through_either_path():
    """gae / lambda_return / discounted_return / V-trace produce the same
    targets whether routed to the fused kernel or the lax.scan reference
    (ISSUE 2 acceptance)."""
    ks = jax.random.split(KEY, 6)
    B, T = 13, 21                       # B not divisible by the scan block
    r = jax.random.normal(ks[0], (B, T))
    v = jax.random.normal(ks[1], (B, T))
    g = (jax.random.bernoulli(ks[2], 0.93, (B, T)) * 0.99).astype(jnp.float32)
    boot = jax.random.normal(ks[3], (B,))
    blp = -jnp.abs(jax.random.normal(ks[4], (B, T)))
    tlp = -jnp.abs(jax.random.normal(ks[5], (B, T)))
    outs = {}
    for m in ("reference", "interpret"):
        with dispatch.force(m):
            adv, targ = gae(r, v, g, boot, lam=0.9)
            vs, pg = vtrace(blp, tlp, r, v, g, boot, lam=0.95, clip_rho=2.0)
            outs[m] = (adv, targ, lambda_return(r, v, g, boot, lam=0.7),
                       discounted_return(r, g, boot), vs, pg)
    for a, b in zip(outs["reference"], outs["interpret"]):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


def test_dispatch_grad_flows_through_kernel_path():
    """rmsnorm + fused attention sit in the train step's grad path: the
    custom_vjp recompute-backward must match reference autodiff."""
    from repro.models import layers as L
    from repro.models.attention import chunked_attend
    ks = jax.random.split(KEY, 4)
    x = jax.random.normal(ks[0], (3, 5, 64))
    p = {"scale": 1.0 + 0.1 * jax.random.normal(ks[1], (64,))}
    f = lambda x: jnp.sum(jnp.square(L.rmsnorm(p, x)))
    with dispatch.force("interpret"):
        gk = jax.grad(f)(x)
    with dispatch.force("reference"):
        gr = jax.grad(f)(x)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-5, atol=1e-5)

    B, T, H, KV, hd = 2, 32, 4, 2, 16
    q = jax.random.normal(ks[2], (B, T, H, hd))
    kv = jax.random.normal(ks[3], (B, T, KV, hd))
    pos = jnp.broadcast_to(jnp.arange(T), (B, T))
    fa = lambda q: jnp.sum(jnp.square(chunked_attend(
        q, kv, kv, pos, pos, causal=True, window=8, cap=20.0, scale=0.25)))
    with dispatch.force("interpret"):
        gk = jax.grad(fa)(q)
    with dispatch.force("reference"):
        gr = jax.grad(fa)(q)
    np.testing.assert_allclose(np.asarray(gk), np.asarray(gr),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# The Pallas backward kernels (dq/dk/dv recompute tiling)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("B,H,KV,T,d", [
    (1, 3, 1, 37, 16),       # odd T (padding path), odd head count
    (2, 8, 2, 100, 24),      # G=4 GQA groups, T % block != 0
    (1, 4, 4, 52, 16),       # MHA
    (1, 6, 3, 33, 8),        # G=2, tiny d
])
def test_flash_bwd_parity_shapes(B, H, KV, T, d):
    """The kernel backward matches oracle autodiff across odd shapes and
    GQA group counts (window+softcap active so every masking branch and
    the tanh chain rule are exercised)."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    g = jax.random.normal(ks[3], (B, H, T, d))
    f_k = lambda q, k, v: flash_attention(
        q, k, v, d ** -0.5, True, 16, 30.0, 32, 32, True)
    f_r = lambda q, k, v: attention_ref(
        q, k, v, scale=d ** -0.5, causal=True, window=16, cap=30.0)
    _, vjp_k = jax.vjp(f_k, q, k, v)
    _, vjp_r = jax.vjp(f_r, q, k, v)
    for a, b in zip(vjp_k(g), vjp_r(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("window,cap", [(0, 0.0), (16, 0.0), (0, 25.0),
                                        (24, 40.0)])
def test_flash_bwd_parity_window_softcap(window, cap):
    ks = jax.random.split(KEY, 4)
    B, H, KV, T, d = 2, 4, 2, 96, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    g = jax.random.normal(ks[3], (B, H, T, d))
    f_k = lambda q, k, v: flash_attention(
        q, k, v, d ** -0.5, True, window, cap, 32, 32, True)
    f_r = lambda q, k, v: attention_ref(
        q, k, v, scale=d ** -0.5, causal=True, window=window, cap=cap)
    _, vjp_k = jax.vjp(f_k, q, k, v)
    _, vjp_r = jax.vjp(f_r, q, k, v)
    for a, b in zip(vjp_k(g), vjp_r(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_flash_bwd_parity_bf16():
    """bf16 primals: cotangents keep the primal dtype and track the oracle
    at bf16 resolution."""
    ks = jax.random.split(KEY, 4)
    B, H, KV, T, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, T, d), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, KV, T, d), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, KV, T, d), jnp.bfloat16)
    g = jax.random.normal(ks[3], (B, H, T, d), jnp.bfloat16)
    f_k = lambda q, k, v: flash_attention(
        q, k, v, d ** -0.5, True, 16, 30.0, 32, 32, True)
    f_r = lambda q, k, v: attention_ref(
        q, k, v, scale=d ** -0.5, causal=True, window=16, cap=30.0)
    _, vjp_k = jax.vjp(f_k, q, k, v)
    _, vjp_r = jax.vjp(f_r, q, k, v)
    for a, b in zip(vjp_k(g), vjp_r(g)):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(jnp.bfloat16))


@pytest.mark.parametrize("causal,window,cap", [
    (True, 0, 0.0), (True, 16, 25.0), (False, 0, 30.0), (True, 48, 0.0),
])
def test_flash_bwd_interpret_bitwise_vs_mirror(causal, window, cap):
    """Bit-audit: the interpret-mode backward kernels and the blockwise jnp
    mirror (`attention_ref_bwd`, which executes the kernels' `_tile_grads`
    helper tile-by-tile) produce IDENTICAL bits — same primitives, same
    accumulation order, same dead-tile skips."""
    from repro.kernels.flash_attention.kernel import (
        flash_attention_bwd_dkv, flash_attention_bwd_dq,
        flash_attention_bwd_preprocess, flash_attention_fwd)
    from repro.kernels.flash_attention.ref import attention_ref_bwd
    ks = jax.random.split(KEY, 4)
    B, H, KV, T, d = 2, 4, 2, 64, 16
    bq, bk = 32, 16
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    g = jax.random.normal(ks[3], (B, H, T, d))
    scale = d ** -0.5
    o, lse = flash_attention_fwd(q, k, v, scale=scale, causal=causal,
                                 window=window, cap=cap, block_q=bq,
                                 block_k=bk, kv_len=T, interpret=True)
    delta = flash_attention_bwd_preprocess(o, g, block_q=bq, interpret=True)
    kw = dict(scale=scale, causal=causal, window=window, cap=cap,
              block_q=bq, block_k=bk, kv_len=T, interpret=True)
    dq = flash_attention_bwd_dq(q, k, v, g, lse, delta, **kw)
    dkh, dvh = flash_attention_bwd_dkv(q, k, v, g, lse, delta, **kw)
    mq, mk, mv = attention_ref_bwd(q, k, v, o, lse, g, scale=scale,
                                   causal=causal, window=window, cap=cap,
                                   block_q=bq, block_k=bk, kv_len=T)
    assert np.array_equal(np.asarray(dq), np.asarray(mq))
    assert np.array_equal(np.asarray(dkh), np.asarray(mk))
    assert np.array_equal(np.asarray(dvh), np.asarray(mv))


def test_attention_bwd_blocks_budget():
    """Backward blocks come from a halved budget: never larger than the
    forward's, floors respected, and the key block shrinks once the
    dq/dkv working set (2d + 2*bq fp32 per k-row) gets big."""
    fq, fk = dispatch.attention_blocks(4096, 4096, 128, jnp.float32)
    bq, bk = dispatch.attention_bwd_blocks(4096, 4096, 128, jnp.float32)
    assert bq <= fq and bk <= fk
    # at common head dims the 128 cap binds both; at a stress dim the
    # doubled working set (dk+dv accumulators, p AND ds tiles) bites
    fq, fk = dispatch.attention_blocks(4096, 4096, 1024, jnp.float32)
    bq, bk = dispatch.attention_bwd_blocks(4096, 4096, 1024, jnp.float32)
    assert bk < fk
    bq1, bk1 = dispatch.attention_bwd_blocks(1, 1, 64, jnp.float32)
    assert bq1 == 8 and bk1 == 8
    bq16, _ = dispatch.attention_bwd_blocks(256, 256, 64, jnp.bfloat16)
    assert bq16 >= 16                    # bf16 sublane floor


def test_fast_tier_chunked_matches_oracle():
    """The CPU fast tier (chunked, windowed key slices) is numerically the
    oracle, forward and backward."""
    from repro.kernels.flash_attention.ref import attention_ref_chunked
    ks = jax.random.split(KEY, 4)
    B, H, KV, T, d = 1, 4, 2, 256, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    g = jax.random.normal(ks[3], (B, H, T, d))
    f_c = lambda q, k, v: attention_ref_chunked(
        q, k, v, scale=d ** -0.5, causal=True, window=48, cap=30.0, block_q=64)
    f_r = lambda q, k, v: attention_ref(
        q, k, v, scale=d ** -0.5, causal=True, window=48, cap=30.0)
    np.testing.assert_allclose(np.asarray(f_c(q, k, v)),
                               np.asarray(f_r(q, k, v)), rtol=2e-5, atol=2e-5)
    _, vjp_c = jax.vjp(f_c, q, k, v)
    _, vjp_r = jax.vjp(f_r, q, k, v)
    for a, b in zip(vjp_c(g), vjp_r(g)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_reverse_scan_closed_form_grads():
    """The scan's closed-form VJP (same kernel on flipped arrays) matches
    autodiff through the lax.scan reference, on both the kernel and fast
    tiers, with cotangent dtypes tracking the primals."""
    from repro.kernels.vtrace_scan.ops import reverse_discounted_scan_fast
    ks = jax.random.split(KEY, 4)
    for B, T, dt in [(8, 64, jnp.float32), (5, 33, jnp.float32),
                     (4, 40, jnp.bfloat16)]:
        deltas = jax.random.normal(ks[0], (B, T), dt)
        decays = (jax.random.uniform(ks[1], (B, T)) * 0.95).astype(dt)
        init = jax.random.normal(ks[2], (B,))
        g = jax.random.normal(ks[3], (B, T))
        loss_ref = lambda d, c, i: jnp.sum(
            reverse_discounted_scan_ref(d, c, i) * g)
        gr = jax.grad(loss_ref, argnums=(0, 1, 2))(deltas, decays, init)
        for fn in (lambda d, c, i: jnp.sum(
                       reverse_discounted_scan(d, c, i, interpret=True) * g),
                   lambda d, c, i: jnp.sum(
                       reverse_discounted_scan_fast(d, c, i) * g)):
            gk = jax.grad(fn, argnums=(0, 1, 2))(deltas, decays, init)
            tol = 2e-2 if dt == jnp.bfloat16 else 1e-5
            for a, b in zip(gk, gr):
                assert a.dtype == b.dtype
                np.testing.assert_allclose(np.asarray(a, np.float32),
                                           np.asarray(b, np.float32),
                                           rtol=tol, atol=tol)


def test_dispatch_stats_counter():
    """Every dispatch resolution is counted with its tier and block
    detail; reset clears."""
    dispatch.stats_reset()
    x = jax.random.normal(KEY, (4, 3, 128))
    w = jnp.ones((128,))
    with dispatch.force("reference"):
        dispatch.rmsnorm(x, w)
    with dispatch.force("interpret"):
        dispatch.rmsnorm(x, w)
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (1, 2, 16, 16))
    kv = jax.random.normal(ks[1], (1, 2, 16, 16))
    with dispatch.force("auto"):
        dispatch.attention(q, kv, kv, scale=0.25)
        dispatch.reverse_scan(jnp.ones((2, 8)), 0.9 * jnp.ones((2, 8)))
    s = dispatch.stats()
    assert s.get("rmsnorm|reference") == 1
    assert any(k.startswith("rmsnorm|interpret|br=") for k in s)
    on_accel = jax.default_backend() in ("tpu", "gpu")
    if not on_accel:
        assert s.get("attention|fast") == 1
        assert s.get("reverse_scan|fast") == 1
    assert dispatch.stats(reset=True) == s
    assert dispatch.stats() == {}


def test_infer_mode_is_serving_scoped(monkeypatch):
    """REPRO_KERNELS_INFER only applies inside dispatch.serving() — a
    learner trace outside the scope never sees it."""
    monkeypatch.setenv("REPRO_KERNELS_INFER", "bf16")
    assert dispatch.infer_mode() is None
    with dispatch.serving():
        assert dispatch.infer_mode() == "bf16"
        with dispatch.serving():
            assert dispatch.infer_mode() == "bf16"
        assert dispatch.infer_mode() == "bf16"     # nesting restores
    assert dispatch.infer_mode() is None
    monkeypatch.setenv("REPRO_KERNELS_INFER", "nonsense")
    with dispatch.serving():
        assert dispatch.infer_mode() is None


def test_infer_bf16_fast_tier_output(monkeypatch):
    """The bf16 inference path returns the caller's dtype and stays close
    to the fp32 forward (input-rounding emulation on CPU)."""
    monkeypatch.setenv("REPRO_KERNELS_INFER", "bf16")
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    with dispatch.force("auto"):
        o_train = dispatch.attention(q, k, v, scale=d ** -0.5, causal=True)
        with dispatch.serving():
            o_serve = dispatch.attention(q, k, v, scale=d ** -0.5, causal=True)
    assert o_serve.dtype == q.dtype
    assert not np.array_equal(np.asarray(o_serve), np.asarray(o_train))
    np.testing.assert_allclose(np.asarray(o_serve), np.asarray(o_train),
                               rtol=3e-2, atol=3e-2)


def test_infer_bf16_mixed_kernel_path(monkeypatch):
    """The kernel tier's mixed mode (bf16 matmul inputs, fp32 accumulate)
    tracks the fp32 kernel at bf16 resolution."""
    monkeypatch.setenv("REPRO_KERNELS_INFER", "bf16")
    ks = jax.random.split(KEY, 3)
    B, H, KV, T, d = 1, 4, 2, 64, 32
    q = jax.random.normal(ks[0], (B, H, T, d))
    k = jax.random.normal(ks[1], (B, KV, T, d))
    v = jax.random.normal(ks[2], (B, KV, T, d))
    with dispatch.force("interpret"):
        o32 = dispatch.attention(q, k, v, scale=d ** -0.5, causal=True)
        with dispatch.serving():
            o16 = dispatch.attention(q, k, v, scale=d ** -0.5, causal=True)
    assert o16.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(o16, np.float32),
                               np.asarray(o32, np.float32),
                               rtol=3e-2, atol=3e-2)


def test_dispatch_inside_jit_is_mode_stable():
    """Dispatch decisions are trace-time static: a jitted function captures
    the mode active when traced, and re-tracing under another mode agrees."""
    x = jax.random.normal(KEY, (4, 3, 128))
    w = jnp.ones((128,))
    with dispatch.force("interpret"):
        y_i = jax.jit(lambda x: dispatch.rmsnorm(x, w))(x)
    with dispatch.force("reference"):
        y_r = jax.jit(lambda x: dispatch.rmsnorm(x, w))(x)
    np.testing.assert_allclose(np.asarray(y_i), np.asarray(y_r),
                               rtol=1e-6, atol=1e-6)
