"""The serving-gateway plane: routing determinism, lineage affinity,
occupancy spill, typed admission shed, probe-gated fleet rollout, and
in-proc vs RPC parity (both replica-level and gateway-level).

Routing/admission semantics are pinned against `FakeReplica` stubs (the
router must not care what a replica is); parity and rollout run against
real `InfServer`s and the real RPC wire."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import ModelKey
from repro.infserver import InfServer
from repro.models import init_params
from repro.params.manifest import build_manifest
from repro.serving import (AdmissionRejected, DeadlineBuckets,
                           GatewayBackend, LineageRouter, ServingGateway,
                           lineage_of, make_router)


class FakeReplica:
    """Protocol-complete stand-in: records every routed submit, resolves
    instantly with zeros. Lets the routing tests control load purely via
    fetched/unfetched tickets."""

    def __init__(self):
        self.models = {}
        self.hashes = {}
        self.submits = []            # (model, rows) in arrival order
        self.flushes = 0
        self.register_calls = 0
        self._next = 0

    def submit(self, obs, model=None):
        obs = np.asarray(obs)
        self.submits.append((model, obs.shape[0]))
        tid = self._next
        self._next += 1
        return (tid, obs.shape[0])

    def get(self, ticket):
        _, rows = ticket
        z = np.zeros(rows, np.float32)
        return z, z, z

    def flush(self):
        self.flushes += 1

    def register_model(self, key, params, content_hash=None, version=None):
        self.register_calls += 1
        self.models[key] = params
        self.hashes[key] = content_hash

    def ensure_model(self, key, params, content_hash=None):
        self.models.setdefault(key, params)

    def has_model(self, key, content_hash=None):
        return key in self.models and (content_hash is None
                                       or self.hashes.get(key) == content_hash)

    def telemetry(self):
        return {"queue_depth": 0, "mean_batch_latency_ms": 0.0}


def _routed(gateway):
    """Per-replica routed request counts from gateway stats."""
    return [r["routed_requests"] for r in gateway.stats()["replicas"]]


OBS = np.zeros((4, 8), np.int32)


# ---------------------------------------------------------------------------
# routing
# ---------------------------------------------------------------------------
def test_seeded_routing_determinism():
    """The same request sequence routes identically on two fresh
    gateways — no wall-clock, rng or id-order leakage in the router."""
    lineages = ["main", "exploiter", "league", "main", "main", "exploiter",
                "pfsp", "league", "main", "pfsp"]

    def run():
        fakes = [FakeReplica() for _ in range(4)]
        gw = ServingGateway(fakes, router="lineage", max_inflight_rows=10_000)
        for i, lin in enumerate(lineages * 5):
            gw.submit(OBS, model=ModelKey(lin, i % 3))   # no gets: load builds
        return [f.submits for f in fakes]

    assert run() == run()


def test_lineage_affinity_routes_to_home():
    """Quiet fleet: every version of a lineage lands on the lineage's
    home replica, and distinct lineages use distinct homes."""
    fakes = [FakeReplica() for _ in range(4)]
    router = LineageRouter()
    gw = ServingGateway(fakes, router=router)
    lineages = ["main", "exploiter", "league", "pfsp", "mirror"]
    for lin in lineages:
        for v in range(3):
            t = gw.submit(OBS, model=ModelKey(lin, v))
            gw.get(t)                          # drain: keep the fleet quiet
    homes = {lin: router.home_index(ModelKey(lin, 0), 4) for lin in lineages}
    for i, f in enumerate(fakes):
        for model, _ in f.submits:
            assert homes[model.agent_id] == i, \
                f"{model} routed to {i}, home {homes[model.agent_id]}"
    assert len(set(homes.values())) >= 2       # the hash actually spreads
    assert router.spills == 0
    assert router.affinity_hits == len(lineages) * 3


def test_lineage_of_falls_back_to_str():
    assert lineage_of(ModelKey("main", 7)) == "main"
    assert lineage_of("teacher") == "teacher"


def test_occupancy_spill_under_slow_replica():
    """A home replica whose outstanding rows pile up (a slow replica in
    closed-loop terms) sheds its lineage's overflow to the least-loaded
    replica; the spill is counted."""
    fakes = [FakeReplica() for _ in range(2)]
    router = make_router("lineage", spill_min_rows=16, spill_factor=1.5)
    gw = ServingGateway(fakes, router=router, max_inflight_rows=10_000)
    key = ModelKey("main", 0)
    home = router.home_index(key, 2)
    other = 1 - home
    tickets = [gw.submit(OBS, model=key) for _ in range(20)]  # never fetched
    assert router.spills > 0
    assert len(fakes[other].submits) > 0        # overflow went to the spare
    # the home kept the pre-spill traffic
    assert len(fakes[home].submits) >= len(fakes[other].submits)
    # draining the home restores affinity
    for t in tickets:
        gw.get(t)
    before = len(fakes[home].submits)
    gw.get(gw.submit(OBS, model=key))
    assert len(fakes[home].submits) == before + 1


def test_telemetry_queue_depth_feeds_router_load():
    """Replica-reported queue depth (the `InfServer.stats()` signal over
    the seam) biases routing even when the gateway's own ledger is
    empty."""
    fakes = [FakeReplica() for _ in range(2)]

    deep = {"queue_depth": 500, "mean_batch_latency_ms": 40.0}
    fakes[0].telemetry = lambda: deep
    gw = ServingGateway(fakes, router="least_loaded")
    gw.refresh_telemetry()
    for _ in range(5):
        gw.get(gw.submit(OBS))
    assert len(fakes[1].submits) == 5 and len(fakes[0].submits) == 0


# ---------------------------------------------------------------------------
# admission control
# ---------------------------------------------------------------------------
def test_admission_shed_is_typed_and_recovers():
    fakes = [FakeReplica() for _ in range(2)]
    gw = ServingGateway(fakes, router="least_loaded", max_inflight_rows=32)
    held = [gw.submit(OBS) for _ in range(8)]          # 32 rows outstanding
    with pytest.raises(AdmissionRejected) as ei:
        gw.submit(OBS)
    e = ei.value
    assert e.reason == "overload" and e.limit == 32
    assert e.inflight_rows == 32 and e.rows == 4
    assert e.retry_after_s >= 0
    st = gw.stats()
    assert st["shed_requests"] == 1 and st["shed_rows"] == 4
    for t in held:                                     # drain ...
        gw.get(t)
    gw.get(gw.submit(OBS))                             # ... and recover
    assert gw.stats()["shed_requests"] == 1


def test_all_dead_fleet_sheds_with_no_replicas():
    fakes = [FakeReplica() for _ in range(2)]
    gw = ServingGateway(fakes)
    gw.mark_dead(0)
    gw.mark_dead(1)
    with pytest.raises(AdmissionRejected) as ei:
        gw.submit(OBS)
    assert ei.value.reason == "no_replicas"


# ---------------------------------------------------------------------------
# SLO deadline buckets
# ---------------------------------------------------------------------------
def test_deadline_buckets_label_and_hit_accounting():
    b = DeadlineBuckets(edges_s=(0.01, 0.05))
    assert b.label(0.004) == "le_10ms"
    assert b.label(0.05) == "le_50ms"
    assert b.label(0.2) == "le_inf" and b.label(None) == "le_inf"
    assert b.record(0.01, 0.005) is True
    assert b.record(0.01, 0.02) is False
    snap = b.snapshot()["le_10ms"]
    assert snap["count"] == 2 and snap["met"] == 1
    assert snap["hit_rate"] == 0.5 and snap["p99_ms"] >= snap["p50_ms"]


def test_pump_flushes_replica_with_due_deadline():
    fakes = [FakeReplica() for _ in range(2)]
    gw = ServingGateway(fakes, router="least_loaded")
    gw.submit(OBS, deadline_s=0.01)
    target = max(range(2), key=lambda i: len(fakes[i].submits))
    assert gw.pump(now=time.perf_counter() + 10.0) == 1
    assert fakes[target].flushes == 1
    assert gw.pump(now=time.perf_counter() + 10.0) == 0   # ledger cleared


def test_no_deadline_request_never_pumps():
    fakes = [FakeReplica()]
    gw = ServingGateway(fakes)
    gw.submit(OBS)                                     # no deadline
    assert gw.pump(now=time.perf_counter() + 100.0) == 0


# ---------------------------------------------------------------------------
# fleet rollout (param plane)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def served():
    cfg = get_arch("tleague-policy-s")
    return cfg, init_params(jax.random.PRNGKey(0), cfg)


def test_fleet_rollout_ships_zero_bytes_to_hosting_replicas(served):
    cfg, params = served
    key = ModelKey("frozen", 3)
    manifest = build_manifest(params, version=3)
    replicas = [InfServer(cfg, 6, max_batch=16, seed=i) for i in range(3)]
    # replica 0 already hosts the exact content (e.g. it was the league's
    # co-located server before joining the fleet)
    replicas[0].register_model(key, params, content_hash=manifest.tree_hash,
                               version=3)
    gw = ServingGateway(replicas)
    cold = gw.rollout(key, params, manifest)
    assert cold["shipped_to"] == 2 and cold["already_hosted"] == 1
    assert cold["bytes_shipped"] == 2 * manifest.nbytes
    assert [p["shipped"] for p in cold["replicas"]] == [False, True, True]
    warm = gw.rollout(key, params, manifest)
    assert warm["bytes_shipped"] == 0 and warm["already_hosted"] == 3
    assert gw.stats()["rollout_noops"] == 4            # 1 cold + 3 warm
    # every replica now actually serves the route
    for r in replicas:
        assert r.has_model(key, manifest.tree_hash)


def test_rollout_from_pool_delta_path(served):
    """The frozen-model propagation path: pool manifest + one pull, then
    the probe-gated fleet install."""
    from repro.core.model_pool import ModelPool

    cfg, params = served
    pool = ModelPool()
    key = ModelKey("main", 1)
    pool.push(key, params)
    replicas = [InfServer(cfg, 6, max_batch=16, seed=i) for i in range(2)]
    gw = ServingGateway(replicas)
    report = gw.rollout_from_pool(pool, key)
    assert report["shipped_to"] == 2
    man = pool.manifest(key)
    for r in replicas:
        assert r.has_model(key, man.tree_hash)
    assert gw.rollout_from_pool(pool, key)["bytes_shipped"] == 0


# ---------------------------------------------------------------------------
# stats across the RPC seam + parity
# ---------------------------------------------------------------------------
def test_stats_and_telemetry_cross_rpc_seam(served):
    """Satellite fix: the router's occupancy/latency signal must survive
    the wire — full `stats()` and the cheap `telemetry()` probe."""
    from repro.distributed.transport import InfServerBackend, RpcServer
    from repro.serving.fleet import connect

    cfg, params = served
    server = InfServer(cfg, 6, params, max_batch=16)
    rpc = RpcServer({"inf": InfServerBackend(server)}).start()
    try:
        client = connect(rpc.address)
        client.get(client.submit(np.zeros((2, 26), np.int32)))
        st = client.stats()
        assert st["rows_served"] == 2 and st["batches_run"] == 1
        assert 0 < st["occupancy"] <= 1.0
        assert st["mean_batch_latency_ms"] > 0
        assert isinstance(st["dispatch"], dict)        # survives msgpack
        tel = client.telemetry()
        assert tel["rows_served"] == 2 and tel["queue_depth"] == 0
        assert set(tel) <= set(st)        # the probe is a strict subset
        # deadline_s rides along harmlessly to a single (non-gateway)
        # server: accepted and ignored, not a server-side TypeError
        a, _, _ = client.get(client.submit(np.zeros((2, 26), np.int32),
                                           deadline_s=0.5))
        assert a.shape == (2,)
    finally:
        rpc.close()


def _drive_sequence(gw, keys, obs_seq):
    outs = []
    for obs, key in zip(obs_seq, keys):
        t = gw.submit(obs, model=key)
        outs.append(gw.get(t))
    return outs


def test_inproc_vs_rpc_gateway_parity(served):
    """The SAME gateway + request sequence over in-process replicas and
    over RPC replica clients must route identically and return
    bit-matching values (values are rng-free; actions match because the
    flush composition — and so the rng consumption — matches)."""
    from repro.distributed.transport import InfServerBackend, RpcServer
    from repro.serving.fleet import connect

    cfg, params = served
    key_a, key_b = ModelKey("main", 0), ModelKey("exploiter", 0)
    rng = np.random.default_rng(0)
    obs_seq = [rng.integers(0, 16, (3, 26)).astype(np.int32)
               for _ in range(8)]
    keys = [key_a, key_b] * 4

    def build(remote):
        servers = [InfServer(cfg, 6, max_batch=64, seed=i) for i in range(2)]
        rpcs = []
        if remote:
            rpcs = [RpcServer({"inf": InfServerBackend(s)}).start()
                    for s in servers]
            reps = [connect(r.address) for r in rpcs]
        else:
            reps = servers
        gw = ServingGateway(reps, router="lineage")
        for k in (key_a, key_b):
            gw.register_model(k, params)
        return gw, rpcs

    gw_local, _ = build(remote=False)
    gw_rpc, rpcs = build(remote=True)
    try:
        local = _drive_sequence(gw_local, keys, obs_seq)
        rpc = _drive_sequence(gw_rpc, keys, obs_seq)
        assert _routed(gw_local) == _routed(gw_rpc)
        for (a1, l1, v1), (a2, l2, v2) in zip(local, rpc):
            np.testing.assert_array_equal(a1, a2)
            np.testing.assert_allclose(l1, l2, rtol=1e-6)
            np.testing.assert_allclose(v1, v2, rtol=1e-6)
    finally:
        for r in rpcs:
            r.close()


def test_gateway_behind_rpc_serves_infserver_protocol(served):
    """GatewayBackend: a plain InfServerClient pointed at a gateway
    address serves against the whole fleet, deadline tag included."""
    from repro.distributed.transport import (InfServerClient, RpcClient,
                                             RpcServer)

    cfg, params = served
    replicas = [InfServer(cfg, 6, params, max_batch=16, seed=i)
                for i in range(2)]
    gw = ServingGateway(replicas)
    rpc = RpcServer({"inf": GatewayBackend(gw)}).start()
    try:
        client = InfServerClient(RpcClient(rpc.address))
        t = client.submit(np.zeros((2, 26), np.int32), deadline_s=5.0)
        a, logp, v = client.get(t)
        assert a.shape == (2,) and v.shape == (2,)
        assert client.telemetry()["alive_replicas"] == 2
        assert gw.stats()["requests"] == 1
        assert gw.deadlines.snapshot()                 # deadline recorded
    finally:
        rpc.close()


def test_submit_side_failover_repoints_ticket_and_keeps_deadline():
    """A replica that dies DURING the submit call: the returned ticket
    must point at the replica that actually holds the rows (get/release
    target `gt.handle`), the fleet ledger must balance — rows acquired
    on the survivor, zero on the corpse — and the request's deadline
    must survive the hop so the pump can still cut a batch for it."""
    from repro.distributed.transport import TransportError

    class DyingReplica(FakeReplica):
        def submit(self, obs, model=None):
            raise TransportError("connection reset by peer")

    dying, live = DyingReplica(), FakeReplica()
    gw = ServingGateway([dying, live], router="least_loaded")
    t = gw.submit(OBS, deadline_s=0.05)       # least-loaded tie -> index 0
    assert t.handle.index == 1                # repointed to the survivor
    assert gw.failovers == 1 and gw.alive_replicas == 1
    assert gw.inflight_rows == OBS.shape[0]   # ledgered exactly once
    per = {r["replica"]: r for r in gw.stats()["replicas"]}
    assert per[0]["inflight_rows"] == 0
    assert per[1]["inflight_rows"] == OBS.shape[0]
    # the deadline followed the request: the pump flushes the survivor
    assert gw.pump(now=time.perf_counter() + 10.0) == 1
    assert live.flushes == 1
    gw.get(t)
    assert gw.inflight_rows == 0              # nothing leaked


def test_get_exhaustion_releases_ledger_on_alive_replica():
    """RemoteError exhaustion — the replica is ALIVE but lost the ticket
    and the failover budget is spent — must release the gid's rows and
    pending deadline on the way out: an alive replica is never swept by
    `_mark_dead`, so a leak here would erode the admission cap forever
    and make the pump flush the replica on every tick."""
    from repro.distributed.transport import RemoteError

    class AmnesiacReplica(FakeReplica):
        def get(self, ticket):
            raise RemoteError("KeyError: unknown ticket")

    gw = ServingGateway([AmnesiacReplica()], failover_retries=0)
    t = gw.submit(OBS, deadline_s=0.05)
    with pytest.raises(RemoteError):
        gw.get(t)
    assert gw.inflight_rows == 0
    assert gw.pump(now=time.perf_counter() + 10.0) == 0  # no stale deadline
    assert gw.alive_replicas == 1


def test_failover_resubmits_to_survivor(served):
    """A replica death between submit and get: the retained obs rows are
    resubmitted to a survivor and the request still answers."""
    from repro.distributed.transport import InfServerBackend, RpcServer
    from repro.serving.fleet import connect

    cfg, params = served
    servers = [InfServer(cfg, 6, params, max_batch=16, seed=i)
               for i in range(2)]
    rpcs = [RpcServer({"inf": InfServerBackend(s)}).start() for s in servers]
    try:
        gw = ServingGateway([connect(r.address) for r in rpcs],
                            router="round_robin")
        t1 = gw.submit(np.zeros((2, 26), np.int32))
        victim = t1.handle.index
        rpcs[victim].close()                           # hard death
        a, logp, v = gw.get(t1)                        # fails over
        assert a.shape == (2,)
        assert gw.failovers >= 1 and gw.alive_replicas == 1
        assert gw.stats()["replicas_died"] == 1
    finally:
        for r in rpcs:
            try:
                r.close()
            except Exception:
                pass
