"""Robustness plane (ISSUE 7): task leases + generation guard, ModelPool
read replicas with version-coherent installs, retrying/failing-over seam
clients (idempotent vs RetryableError), seeded fault injection, the
heartbeat slow-vs-dead discrimination that feeds the lease reaper, and
the InfServer's dead-owner ticket expiry."""
import threading
import time

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.core import LeagueMgr, MatchResult, ModelKey
from repro.core.model_pool import ModelPool, ModelPoolReplica
from repro.distributed import transport as tp
from repro.distributed.heartbeat import BeatRegistry, Heartbeat, HeartbeatMonitor
from repro.infserver import InfServer
from repro.models import init_params
from repro.params.cache import CachedPuller


@pytest.fixture(scope="module")
def cfg():
    return get_arch("tleague-policy-s")


@pytest.fixture(scope="module")
def params(cfg):
    return init_params(jax.random.PRNGKey(0), cfg)


def _small_params(seed=0):
    rng = np.random.default_rng(seed)
    return {"w": rng.normal(size=(16, 16)).astype(np.float32),
            "b": rng.normal(size=(16,)).astype(np.float32)}


def _league(ttl=30.0):
    lg = LeagueMgr(lease_ttl_s=ttl)
    lg.add_learning_agent("main", _small_params())
    return lg


def _result(task, outcome=1.0):
    return MatchResult(learner_key=task.learner_key,
                       opponent_keys=task.opponent_keys, outcome=outcome,
                       episode_len=1, task_id=task.task_id)


# -- task leases --------------------------------------------------------------
class TestLeases:
    def test_issue_complete_release(self):
        lg = _league()
        t1 = lg.request_task("main", actor_id="a0")
        assert lg.lease_state()["outstanding"] == 1
        lg.report_result(_result(t1))
        s = lg.lease_state()
        assert s["completed"] == 1 and s["outstanding"] == 0
        # an actor's next request releases its previous (unreported) lease
        lg.request_task("main", actor_id="a0")
        lg.request_task("main", actor_id="a0")
        s = lg.lease_state()
        assert s["released"] == 1 and s["outstanding"] == 1

    def test_reap_reissue_and_generation_guard(self):
        lg = _league(ttl=0.01)
        t1 = lg.request_task("main", actor_id="dead")
        reaped = lg.reap_leases(now=time.monotonic() + 1.0)
        assert [l.task_id for l in reaped] == [t1.task_id]
        # the reissued task carries the SAME match under a NEW task_id
        t2 = lg.request_task("main", actor_id="spare")
        assert t2.task_id != t1.task_id
        assert t2.opponent_keys == t1.opponent_keys
        assert lg.lease_state()["reissued"] == 1
        # late result from the presumed-dead actor: dropped, payoff untouched
        pair = (t1.learner_key, t1.opponent_keys[0])
        games_before = lg.payoff.games(*pair)
        lg.report_result(_result(t1))
        assert lg.lease_state()["dropped_results"] == 1
        assert lg.payoff.games(*pair) == games_before
        # the new generation's result is accepted normally
        lg.report_result(_result(t2))
        assert lg.lease_state()["completed"] == 1

    def test_dead_actor_reaped_before_deadline(self):
        lg = _league(ttl=60.0)
        lg.request_task("main", actor_id="gone")
        assert lg.reap_leases(dead_actors=["gone"])
        assert lg.lease_state()["reaped"] == 1

    def test_touch_extends_deadline(self):
        lg = _league(ttl=0.05)
        lg.request_task("main", actor_id="slow")
        future = time.monotonic() + 1.0
        lg.touch_actor("slow", now=future)
        assert lg.reap_leases(now=future + 0.04) == []   # extended past TTL
        assert lg.reap_leases(now=future + 0.06)         # but not forever

    def test_reissue_skips_stale_learner_key(self):
        lg = _league(ttl=0.01)
        t1 = lg.request_task("main", actor_id="dead")
        lg.reap_leases(now=time.monotonic() + 1.0)
        lg.end_learning_period("main", _small_params(1))  # lineage froze
        t2 = lg.request_task("main", actor_id="spare")
        # the queued template quoted the pre-freeze learner key: skipped
        assert t2.learner_key != t1.learner_key
        assert lg.lease_state()["reissued"] == 0
        assert lg.lease_state()["reissue_queued"] == 0

    def test_legacy_mode_keeps_no_lease_state(self):
        lg = LeagueMgr()                                  # lease_ttl_s=None
        lg.add_learning_agent("main", _small_params())
        t = lg.request_task("main", actor_id="a0")
        assert lg.lease_state()["issued"] == 0
        assert lg.reap_leases() == []
        lg.report_result(_result(t))                      # accepted, no guard
        assert lg.lease_state()["dropped_results"] == 0


# -- ModelPool replicas -------------------------------------------------------
class TestReplica:
    def test_install_refuses_non_monotonic(self):
        src, dst = ModelPool(), ModelPool()
        key = ModelKey("m", 0)
        src.push(key, _small_params())
        src.push(key, _small_params(1))
        v, man = src.version(key), src.manifest(key)
        assert dst.install(key, src.pull(key), v, manifest=man)
        assert dst.version(key) == v
        assert not dst.install(key, src.pull(key), v, manifest=man)
        assert not dst.install(key, src.pull(key), v - 1)    # can't regress
        assert dst.version(key) == v
        with pytest.raises(AssertionError):                  # incoherent pair
            dst.install(key, src.pull(key), v + 1, manifest=man)

    def test_sync_version_coherent_and_frozen_mirrored(self):
        primary = ModelPool()
        key = ModelKey("m", 0)
        primary.push(key, _small_params())
        rep = ModelPoolReplica(primary, sync_interval_s=0.01)
        rep.sync_once()
        assert rep.version(key) == primary.version(key)
        # a consumer that cached from the PRIMARY gets a coherent delta here
        assert rep.manifest(key).tree_hash == primary.manifest(key).tree_hash
        primary.push(key, _small_params(1))
        primary.freeze(key)
        rep.sync_once()
        assert rep.version(key) == primary.version(key)
        assert rep.pull_attr(key)["frozen"]
        assert rep.sync_stats["frozen_mirrored"] == 1
        np.testing.assert_array_equal(rep.pull(key)["w"],
                                      primary.pull(key)["w"])

    def test_replica_refuses_writes(self):
        rep = ModelPoolReplica(ModelPool())
        with pytest.raises(ValueError, match="read replica"):
            rep.push(ModelKey("m", 0), _small_params())
        with pytest.raises(ValueError, match="read replica"):
            rep.freeze(ModelKey("m", 0))

    def test_follow_thread_tracks_primary(self):
        primary = ModelPool()
        key = ModelKey("m", 0)
        primary.push(key, _small_params())
        rep = ModelPoolReplica(primary, sync_interval_s=0.01).start_following()
        try:
            deadline = time.monotonic() + 5.0
            while key not in rep and time.monotonic() < deadline:
                time.sleep(0.01)
            assert key in rep
            primary.push(key, _small_params(2))
            while rep.version(key) < primary.version(key) \
                    and time.monotonic() < deadline:
                time.sleep(0.01)
            assert rep.version(key) == primary.version(key)
        finally:
            rep.stop()

    def test_cached_puller_ignores_lagging_replica_answer(self):
        pool = ModelPool()
        key = ModelKey("m", 0)
        pool.push(key, _small_params())
        pool.push(key, _small_params(1))

        class Lagging:
            """Answers like a replica stuck at version 0."""
            def __init__(self, fresh, stale):
                self.fresh, self.stale, self.calls = fresh, stale, 0

            def pull_if_changed(self, k, have_version=None, copy=None,
                                have_hashes=None):
                self.calls += 1
                src = self.fresh if self.calls == 1 else self.stale
                return src.pull_if_changed(k, None)   # always a full answer

        stale_pool = ModelPool()
        stale_pool.push(key, _small_params())         # version 0 content
        puller = CachedPuller(Lagging(pool, stale_pool))
        p1, m1 = puller.get_with_manifest(key)
        p2, m2 = puller.get_with_manifest(key)        # lagging answer arrives
        assert m2.version == m1.version               # kept the newer cache
        assert puller.stale_answers == 1
        np.testing.assert_array_equal(p2["w"], p1["w"])


# -- retrying seam clients ----------------------------------------------------
class TestRetry:
    FAST = tp.RetryPolicy(base_s=0.02, cap_s=0.1, deadline_s=5.0)

    def test_retry_policy_jitter_and_deadline(self):
        import random
        pol = tp.RetryPolicy(base_s=0.1, cap_s=0.8, max_attempts=6,
                             deadline_s=None)
        ds = list(pol.delays(random.Random(0)))
        assert len(ds) == 5
        for i, d in enumerate(ds):
            nominal = min(0.8, 0.1 * 2 ** i)
            assert 0.5 * nominal <= d <= 1.5 * nominal
        # a spent deadline stops yielding
        spent = tp.RetryPolicy(base_s=0.01, deadline_s=0.0)
        assert list(spent.delays(random.Random(0))) == []

    def test_endpoint_list_parsing_and_rotation(self):
        c = tp.RpcClient("a:1, b:2,c:3", connect_retries=1)
        assert c.endpoints == ("a:1", "b:2", "c:3")
        assert c.address == "a:1"
        c._rotate()
        assert c.address == "b:2"

    def test_idempotent_retry_survives_server_restart(self):
        pool = ModelPool()
        key = ModelKey("m", 0)
        pool.push(key, _small_params())
        srv = tp.RpcServer({"pool": pool}).start()
        host, port = tp.parse_addr(srv.address)
        client = tp.RpcClient(srv.address, retry=self.FAST, seed=0)
        try:
            assert client.call("pool.version", key, idempotent=True) == 0
            srv.close()
            box = {}

            def restart():
                time.sleep(0.3)
                box["srv"] = tp.RpcServer({"pool": pool}, host=host,
                                          port=port).start()

            threading.Thread(target=restart, daemon=True).start()
            # retried under backoff until the server is back
            assert client.call("pool.version", key, idempotent=True) == 0
        finally:
            client.close()
            box.get("srv", srv).close()

    def test_nonidempotent_failure_raises_retryable(self):
        # a drop_reply fault is the genuine ambiguity: the push DID
        # dispatch server-side but the reply was lost — the transport
        # must surface RetryableError, never silently resend
        pool = ModelPool()
        plan = tp.FaultPlan([tp.FaultRule("pool.push", "drop_reply",
                                          max_times=1)])
        srv = tp.RpcServer({"pool": pool}, fault_plan=plan).start()
        client = tp.RpcClient(srv.address, retry=self.FAST, seed=0)
        try:
            client.call("pool.keys", idempotent=True)     # connection is live
            with pytest.raises(tp.RetryableError):
                client.call("pool.push", ModelKey("m", 0), _small_params())
            assert ModelKey("m", 0) in pool.keys()        # it DID execute
        finally:
            client.close()
            srv.close()

    def test_nonidempotent_on_proactively_dead_conn_is_not_ambiguous(self):
        # the pipelined reader notices a dead server BEFORE the next call,
        # so a push that never reached the wire exhausts with a plain
        # TransportError — retryable-by-construction, not RetryableError
        pool = ModelPool()
        srv = tp.RpcServer({"pool": pool}).start()
        client = tp.RpcClient(srv.address, retry=self.FAST, seed=0)
        try:
            client.call("pool.keys", idempotent=True)     # connection is live
            srv.close()
            time.sleep(0.2)                # let the reader observe the close
            with pytest.raises(tp.TransportError):
                client.call("pool.push", ModelKey("m", 0), _small_params())
        finally:
            client.close()

    def test_unreachable_idempotent_exhausts_with_transport_error(self):
        client = tp.RpcClient("127.0.0.1:1",
                              retry=tp.RetryPolicy(base_s=0.01, cap_s=0.02,
                                                   max_attempts=3,
                                                   deadline_s=0.2))
        with pytest.raises(tp.TransportError) as ei:
            client.call("pool.keys", idempotent=True)
        assert not isinstance(ei.value, tp.RetryableError)

    def test_abort_poisons_retries(self):
        client = tp.RpcClient("127.0.0.1:1", retry=self.FAST)
        client.abort()
        t0 = time.monotonic()
        with pytest.raises(tp.TransportError):
            client.call("pool.keys", idempotent=True)
        assert time.monotonic() - t0 < 1.0                # no backoff fight

    def test_pool_client_fails_over_to_replica(self):
        key = ModelKey("m", 0)
        primary = ModelPool()
        primary.push(key, _small_params())
        rep = ModelPoolReplica(primary)
        rep.sync_once()
        srv_p = tp.RpcServer({"pool": primary}).start()
        srv_r = tp.RpcServer({"pool": rep}).start()
        client = tp.ModelPoolClient(tp.RpcClient(
            [srv_p.address, srv_r.address], retry=self.FAST, seed=0))
        try:
            np.testing.assert_array_equal(client.pull(key)["w"],
                                          primary.pull(key)["w"])
            srv_p.close()                                  # kill the primary
            client.clear_cache()                           # force a real pull
            np.testing.assert_array_equal(client.pull(key)["w"],
                                          primary.pull(key)["w"])
        finally:
            client.close()
            srv_p.close()
            srv_r.close()

    def test_replica_keyerror_read_falls_back_to_primary(self):
        key = ModelKey("fresh", 0)
        primary = ModelPool()
        primary.push(key, _small_params())
        lagging = ModelPool()                  # replica that hasn't synced
        srv_p = tp.RpcServer({"pool": primary}).start()
        srv_r = tp.RpcServer({"pool": lagging}).start()
        client = tp.ModelPoolClient(
            tp.RpcClient(srv_r.address, retry=self.FAST),
            write_client=srv_p.address)
        try:
            # the replica answers RemoteError(KeyError) — a live server, so
            # no failover — and the read retries against the pinned primary
            assert client.version(key) == 0
            np.testing.assert_array_equal(client.pull(key)["w"],
                                          primary.pull(key)["w"])
        finally:
            client.close()
            srv_p.close()
            srv_r.close()


# -- fault injection ----------------------------------------------------------
class TestFaultPlan:
    def test_json_roundtrip_and_env(self, monkeypatch):
        plan = tp.FaultPlan([tp.FaultRule("pool.*", "drop", p=0.5,
                                          max_times=3)], seed=7)
        back = tp.FaultPlan.from_json(plan.to_json())
        assert back.seed == 7 and back.rules[0].match == "pool.*"
        assert back.rules[0].p == 0.5 and back.rules[0].max_times == 3
        monkeypatch.setenv("REPRO_FAULT_PLAN", plan.to_json())
        assert tp.FaultPlan.from_env().seed == 7
        monkeypatch.delenv("REPRO_FAULT_PLAN")
        assert tp.FaultPlan.from_env() is None

    def test_seeded_decisions_are_deterministic(self):
        def draws(seed):
            plan = tp.FaultPlan([tp.FaultRule("*", "drop", p=0.5)], seed=seed)
            return [plan.decide("x.y") is not None for _ in range(32)]
        assert draws(3) == draws(3)
        assert draws(3) != draws(4)

    def test_unknown_kind_rejected(self):
        with pytest.raises(AssertionError):
            tp.FaultRule("*", "explode")

    @pytest.mark.parametrize("kind", ["drop", "drop_reply", "close_mid_chunk"])
    def test_idempotent_call_rides_through_fault(self, kind):
        pool = ModelPool()
        key = ModelKey("m", 0)
        # big enough that the reply streams (close_mid_chunk cuts a blob)
        pool.push(key, {"w": np.arange(128 * 1024, dtype=np.float32)})
        plan = tp.FaultPlan([tp.FaultRule("pool.pull*", kind, max_times=1)])
        srv = tp.RpcServer({"pool": pool}, fault_plan=plan).start()
        client = tp.ModelPoolClient(tp.RpcClient(
            srv.address, retry=tp.RetryPolicy(base_s=0.02, cap_s=0.1,
                                              deadline_s=5.0), seed=0))
        try:
            np.testing.assert_array_equal(client.pull(key)["w"],
                                          pool.pull(key)["w"])
            assert plan.stats()[f"pool.pull*:{kind}"] == 1
        finally:
            client.close()
            srv.close()

    def test_delay_fault_adds_latency(self):
        hb = Heartbeat()
        plan = tp.FaultPlan([tp.FaultRule("ctrl.ping", "delay", delay_s=0.2,
                                          max_times=1)])
        srv = tp.RpcServer({"ctrl": hb}, fault_plan=plan).start()
        client = tp.RpcClient(srv.address)
        try:
            t0 = time.monotonic()
            client.call("ctrl.ping")
            assert time.monotonic() - t0 >= 0.15
            t0 = time.monotonic()
            client.call("ctrl.ping")                      # rule exhausted
            assert time.monotonic() - t0 < 0.15
        finally:
            client.close()
            srv.close()


# -- heartbeat: slow vs dead --------------------------------------------------
class TestSlowVsDead:
    def test_beat_registry_split(self):
        reg = BeatRegistry()
        reg.beat("fast")
        reg.beat("slow")
        alive, stale = reg.split(stale_s=10.0)
        assert sorted(alive) == ["fast", "slow"] and stale == []
        time.sleep(0.05)
        reg.beat("fast")
        alive, stale = reg.split(stale_s=0.04)
        assert alive == ["fast"] and stale == ["slow"]
        reg.beat("slow")                                  # woke back up
        alive, _ = reg.split(stale_s=0.04)
        assert sorted(alive) == ["fast", "slow"]
        reg.forget("slow")
        assert len(reg) == 1

    def test_stalled_worker_is_not_declared_dead_early(self):
        """A SIGSTOP shorter than the stale threshold must NOT reap — the
        reaper's in-process form: the worker misses beats for 0.1 s under
        a 10 s threshold and stays in the alive set, lease intact."""
        lg = _league(ttl=10.0)
        reg = BeatRegistry()
        lg.request_task("main", actor_id="stalled")
        reg.beat("stalled")
        time.sleep(0.1)                                   # the brief stall
        alive, stale = reg.split(stale_s=10.0)
        assert alive == ["stalled"] and stale == []
        for a in alive:
            lg.touch_actor(a)
        assert lg.reap_leases(dead_actors=stale) == []
        assert lg.lease_state()["outstanding"] == 1

    def test_lease_reaped_during_long_stall_stays_reaped(self):
        """The SIGCONT side: an actor that resumes AFTER its lease was
        reaped gets its late result dropped, and the re-issued generation
        (handed to another actor during the stall) wins."""
        lg = _league(ttl=10.0)
        reg = BeatRegistry()
        t1 = lg.request_task("main", actor_id="stalled")
        reg.beat("stalled")
        time.sleep(0.06)
        alive, stale = reg.split(stale_s=0.05)            # stall > threshold
        assert stale == ["stalled"]
        assert lg.reap_leases(dead_actors=stale)
        t2 = lg.request_task("main", actor_id="spare")    # re-issued match
        reg.beat("stalled")                               # SIGCONT: resumes
        lg.report_result(_result(t1))                     # late result
        assert lg.lease_state()["dropped_results"] == 1
        lg.report_result(_result(t2))
        assert lg.lease_state()["completed"] == 1

    def test_monitor_tolerates_slow_beats(self):
        """HeartbeatMonitor: a peer whose counter still advances — however
        slowly — is never declared dead; one that stops advancing is."""
        hb = Heartbeat()
        hb.beat()
        srv = tp.RpcServer({"ctrl": hb}).start()
        died = threading.Event()
        mon = HeartbeatMonitor(srv.address, interval_s=0.05, timeout_s=0.6,
                               on_dead=died.set)
        mon.start()
        try:
            for _ in range(4):                            # slow but alive
                time.sleep(0.3)
                hb.beat()
            assert not mon.dead
            assert died.wait(timeout=5.0)                 # beats stopped
            assert mon.dead
        finally:
            mon.stop()
            srv.close()


# -- InfServer ticket expiry --------------------------------------------------
class TestTicketExpiry:
    def test_abandoned_results_expire(self, cfg, params):
        srv = InfServer(cfg, 6, params, max_batch=64, ticket_ttl_flushes=2)
        obs = np.zeros((1, 26), np.int32)
        dead = srv.submit(obs)
        srv.flush()                                       # resolved, unclaimed
        assert srv.stats()["results_held"] == 1
        for _ in range(2):                                # owner misses 2 flushes
            srv.get(srv.submit(obs))
        st = srv.stats()
        assert st["tickets_expired"] == 1
        assert st["results_held"] == 0                    # occupancy recovered
        with pytest.raises(KeyError):
            srv.get(dead)

    def test_collected_and_discarded_tickets_never_expire(self, cfg, params):
        srv = InfServer(cfg, 6, params, max_batch=64, ticket_ttl_flushes=1)
        obs = np.zeros((1, 26), np.int32)
        t = srv.submit(obs)
        srv.get(t)                                        # collected promptly
        junk = srv.submit(obs)
        srv.discard(junk)                                 # politely dropped
        for _ in range(3):
            srv.get(srv.submit(obs))
        assert srv.stats()["tickets_expired"] == 0


# -- launch surface -----------------------------------------------------------
class TestLaunchSurface:
    def test_k8s_renders_replica_fleet_and_endpoints(self):
        from repro.launch.k8s import render
        out = render(pool_replicas=2, signature="sig")
        assert "sig-pool-replica" in out
        assert '"--role", "pool-replica"' in out
        assert "replicas: 2" in out
        # actors read replica-first, learners coordinator-first
        assert '"--pool-endpoints", "sig-pool-replica:9008,sig-coordinator:9003"' in out
        assert '"--pool-endpoints", "sig-coordinator:9003,sig-pool-replica:9008"' in out
        assert "repro.dev/in-process-restart-budget" in out
        assert "repro.dev/rpc-retry-backoff" in out
        legacy = render(pool_replicas=0)
        assert "pool-replica" not in legacy

    def test_restart_budget_annotation_matches_code(self):
        from repro.launch.distributed import DEFAULT_ACTOR_RESTARTS
        from repro.launch.k8s import render
        assert (f'repro.dev/in-process-restart-budget: '
                f'"{DEFAULT_ACTOR_RESTARTS}"') in render()
