"""CI shm smoke (ISSUE 10): the same-host shared-memory fast path must
not leak segments or wedge the server when the PRODUCER is SIGKILLed
mid-stream.

Not a pytest module (no `test_` prefix — real kill -9 semantics across
processes): run as `PYTHONPATH=src python tests/smoke_shm.py`.

The scenario:
  1. Parent serves an echo backend over `RpcServer` (shm enabled).
  2. A child process connects, negotiates the shm ring (same host, same
     boot id) and streams large frames through it in a tight loop,
     printing the negotiated segment name.
  3. Parent kill -9s the child mid-stream. The server must shrug the
     dead connection off, the child's /dev/shm segment must disappear
     within ~10 s (the resource tracker reaps it), and a FRESH client
     must negotiate its own ring and round-trip bit-exact.
"""
import os
import pathlib
import re
import signal
import subprocess
import sys
import threading
import time

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

import numpy as np  # noqa: E402

from repro.distributed import transport as tp  # noqa: E402

ENV = dict(os.environ)
ENV["PYTHONPATH"] = os.pathsep.join(
    p for p in (str(REPO / "src"), os.environ.get("PYTHONPATH")) if p)

CHILD = r"""
import sys, time
import numpy as np
from repro.distributed import transport as tp

c = tp.RpcClient(sys.argv[1])
blob = np.arange(96 * 1024, dtype=np.float32)          # 384 KiB
c.call("b.echo", blob)                                 # negotiate first
st = c.transport_stats()
name = c._conn.shm.name if (c._conn and c._conn.shm) else ""
print(f"SHM name={name} proto={st['proto']}", flush=True)
i = 0
while True:                                            # stream until killed
    c.call("b.echo", blob + i)
    i += 1
"""


class _Echo:
    def __init__(self):
        self.frames = 0
        self._lock = threading.Lock()

    def echo(self, x):
        with self._lock:
            self.frames += 1
        return x


def main() -> int:
    backend = _Echo()
    ok = True
    with tp.RpcServer({"b": backend}) as srv:
        child = subprocess.Popen(
            [sys.executable, "-c", CHILD, srv.address], env=ENV, cwd=REPO,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
        try:
            line = child.stdout.readline()
            m = re.search(r"SHM name=(\S*) proto=(\d+)", line)
            assert m, f"child never negotiated: {line!r}"
            name, proto = m.group(1), int(m.group(2))
            print(f"[shm] child pid={child.pid} ring={name!r} proto={proto}",
                  flush=True)
            if not name or proto < 2:
                print("[shm] FAIL: child did not negotiate the shm ring",
                      flush=True)
                return 1
            assert os.path.exists(f"/dev/shm/{name}"), "ring segment missing"

            deadline = time.monotonic() + 30.0
            while backend.frames < 50 and time.monotonic() < deadline:
                time.sleep(0.05)
            assert backend.frames >= 50, "child never streamed frames"
            print(f"[shm] {backend.frames} frames through the ring; "
                  "SIGKILL the producer mid-stream", flush=True)
            os.kill(child.pid, signal.SIGKILL)
            child.wait(timeout=10)
        finally:
            if child.poll() is None:
                child.kill()
                child.wait()

        # the dead producer's segment is reaped (resource tracker), not
        # leaked into /dev/shm for the life of the host
        deadline = time.monotonic() + 10.0
        while os.path.exists(f"/dev/shm/{name}"):
            if time.monotonic() > deadline:
                print(f"[shm] FAIL: segment {name} leaked after kill -9",
                      flush=True)
                ok = False
                break
            time.sleep(0.2)
        else:
            print("[shm] dead producer's segment reaped", flush=True)

        # the server survived: a fresh client negotiates ITS OWN ring and
        # round-trips bit-exact
        before = backend.frames
        c = tp.RpcClient(srv.address)
        try:
            blob = np.arange(96 * 1024, dtype=np.float32) * 2.0
            out = c.call("b.echo", blob)
            np.testing.assert_array_equal(out, blob)
            st = c.transport_stats()
            print(f"[shm] fresh client after kill: proto={st['proto']} "
                  f"shm={st['shm']} blobs={st['shm_blobs']}", flush=True)
            if st["proto"] < 2 or not st["shm"] or st["shm_blobs"] < 1:
                print("[shm] FAIL: fresh client did not take the fast path",
                      flush=True)
                ok = False
            if backend.frames <= before:
                print("[shm] FAIL: server stopped serving", flush=True)
                ok = False
        finally:
            c.close()

    print(f"[shm] {'PASS' if ok else 'FAIL'}", flush=True)
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
