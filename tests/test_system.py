"""End-to-end behaviour of the TLeague reproduction: the full
Actor-Learner-LeagueMgr-ModelPool loop trains, freezes, and the league
bookkeeping matches the paper's lifecycle; the InfServer batches correctly;
throughput telemetry (rfps/cfps) is live."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import LeagueMgr, SelfPlayPFSPGameMgr
from repro.envs import make_env
from repro.infserver import InfServer
from repro.learners import DataServer, Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw


@pytest.fixture(scope="module")
def setup():
    cfg = get_arch("tleague-policy-s")
    env = make_env("rps")
    params = init_params(jax.random.PRNGKey(0), cfg)
    league = LeagueMgr()
    league.add_learning_agent("main", params,
                              game_mgr=SelfPlayPFSPGameMgr(payoff=None))
    actor = Actor(env, cfg, league, num_envs=4, unroll_len=8, seed=1)
    opt = adamw(3e-4, clip_norm=1.0)
    step = build_env_train_step(cfg, env.spec.num_actions, opt)
    learner = Learner(league, step, opt, params)
    return cfg, env, league, actor, learner


def test_end_to_end_league_training(setup):
    cfg, env, league, actor, learner = setup
    losses = []
    for _ in range(3):
        traj, task = actor.run_segment()
        assert traj["obs"].shape == (4, 8, env.spec.obs_len)
        assert traj["actions"].shape == (4, 8)
        assert bool(jnp.isfinite(traj["behavior_logp"]).all())
        learner.data_server.put(traj)
        m = learner.learn()
        losses.append(float(m["loss"]))
    assert all(np.isfinite(losses))
    # episode outcomes were reported (rps episodes end every 8 steps)
    assert len(league._results) > 0
    tp = learner.data_server.throughput()
    assert tp["rfps"] > 0 and tp["cfps"] > 0

    # learning-period end: pool grows, model frozen, lineage advances
    old = learner.current_key
    new = learner.end_learning_period()
    assert new.version == old.version + 1
    assert league.model_pool.pull_attr(old)["frozen"]
    assert old in league.frozen_pool
    # next tasks may sample the frozen opponent
    traj, task = actor.run_segment()
    assert task.learner_key == new


def test_infserver_batches_and_matches_local(setup):
    cfg, env, league, actor, learner = setup
    params = league.model_pool.pull(learner.current_key)
    server = InfServer(cfg, env.spec.num_actions, params, max_batch=8)
    obs = np.zeros((3, env.spec.obs_len), np.int32)
    t1 = server.submit(obs)
    t2 = server.submit(obs)
    a1, logp1, v1 = server.get(t1)
    a2, logp2, v2 = server.get(t2)
    assert a1.shape == (3,) and v2.shape == (3,)
    assert server.batches_run >= 1
    # identical observations get identical values (batch invariance)
    np.testing.assert_allclose(v1, v2, rtol=1e-5)


def test_multi_agent_league_with_exploiter():
    from repro.launch.train import run_league_training
    league, agents, history = run_league_training(
        env_name="rps", arch="tleague-policy-s", periods=1,
        steps_per_period=2, num_envs=4, unroll_len=8, num_exploiters=1,
        verbose=False)
    st = league.league_state()
    assert "main" in st["agents"] and "exploiter:0" in st["agents"]
    assert len(st["frozen_pool"]) >= 2          # both lineages froze
    assert st["num_results"] > 0
