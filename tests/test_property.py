"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import MatchResult, ModelKey, PayoffMatrix
from repro.kernels import reverse_discounted_scan
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref
from repro.models import moe as M
from repro.rl.returns import gae, lambda_return

SET = dict(max_examples=25, deadline=None)


@given(st.integers(1, 6), st.integers(1, 24), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_reverse_scan_matches_ref(B, T, seed):
    k = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(k, 3)
    deltas = jax.random.normal(k1, (B, T))
    decays = jax.random.uniform(k2, (B, T))
    init = jax.random.normal(k3, (B,))
    y = reverse_discounted_scan(deltas, decays, init, interpret=True)
    r = reverse_discounted_scan_ref(deltas, decays, init)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r),
                               rtol=1e-4, atol=1e-4)


@given(st.integers(1, 4), st.integers(2, 16), st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 1.0))
@settings(**SET)
def test_gae_telescopes_to_lambda_return(B, T, seed, lam):
    """advantage + value == lambda-return targets (algebraic identity)."""
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    r = jax.random.normal(ks[0], (B, T))
    v = jax.random.normal(ks[1], (B, T))
    g = jax.random.uniform(ks[2], (B, T)) * 0.99
    boot = jax.random.normal(ks[3], (B,))
    adv, targ = gae(r, v, g, boot, lam=lam)
    ref = lambda_return(r, v, g, boot, lam=lam)
    np.testing.assert_allclose(np.asarray(targ), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


@given(st.lists(st.sampled_from([+1, -1, 0]), min_size=1, max_size=60))
@settings(**SET)
def test_payoff_invariants(outcomes):
    """winrate(a,b)+winrate(b,a)==1, Elo total conserved, counts add up."""
    p = PayoffMatrix()
    a, b = ModelKey("m", 0), ModelKey("m", 1)
    p.add_model(a), p.add_model(b)
    for o in outcomes:
        p.record(MatchResult(learner_key=a, opponent_keys=(b,), outcome=o))
    assert abs(p.winrate(a, b) + p.winrate(b, a) - 1.0) < 1e-9
    assert 0.0 <= p.winrate(a, b) <= 1.0
    assert abs((p.elo[a] - 1200) + (p.elo[b] - 1200)) < 1e-6
    assert p.games(a, b) == len(outcomes)


@given(st.integers(4, 64), st.integers(2, 16), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_moe_routing_invariants(N, E, k, seed):
    """Every kept slot is unique; weights renormalize to 1; per-expert load
    never exceeds capacity."""
    k = min(k, E)
    C = max(2, int(N * k * 1.25 / E))
    gates = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(seed), (N, E)))
    slot, weight, keep, counts = M.route_topk(gates, k, C)
    slot_np, keep_np = np.asarray(slot), np.asarray(keep)
    kept = slot_np[keep_np]
    assert len(np.unique(kept)) == len(kept)          # no slot collisions
    assert kept.max(initial=-1) < E * C
    w = np.asarray(weight)
    np.testing.assert_allclose(w.sum(-1), 1.0, rtol=1e-4, atol=1e-4)
    # per-expert kept load <= capacity
    experts = kept // C
    _, load = np.unique(experts, return_counts=True)
    assert (load <= C).all()
    assert int(np.asarray(counts).sum()) == N * k


@given(st.sampled_from(["uniform", "prioritized", "episode"]),
       st.lists(st.tuples(st.integers(1, 5), st.booleans()),
                min_size=1, max_size=12),
       st.integers(1, 16), st.integers(0, 2 ** 31 - 1))
@settings(**SET)
def test_sampler_ring_wraparound_invariants(sampler, puts, k, seed):
    """Variable-row segments wrapping a small ring, under every sampler:
    every sampled slot addresses a live row (one some `put` actually
    wrote), uniform reproduces the pre-refactor rng stream exactly, and
    episode chains never reference overwritten slots."""
    from repro.learners import DataServer
    t = 4
    ds = DataServer(seed=seed, blocking=False, prefetch=False,
                    capacity_frames=7 * t, sampler=sampler)
    written = set()
    for i, (rows, terminal) in enumerate(puts):
        rows = min(rows, 7)                    # a segment must fit the ring
        done = np.zeros((rows, t), bool)
        if terminal:
            done[:, -1] = True
        ds.put({"actions": np.full((rows, t), i, np.int32), "done": done},
               source="p")
        written.update(np.asarray(ds._last_rows).tolist())
    ref_rng = np.random.default_rng(seed)
    idx = ds.sampler.sample(k)
    assert idx.shape == (k,)
    assert set(idx.tolist()) <= written        # only rows a put wrote
    live = set(((ds._head - ds._size + np.arange(ds._size))
                % ds._row_slots).tolist())
    assert set(idx.tolist()) <= live           # ... that are still live
    if sampler == "uniform":
        ref = (ds._head - ds._size + ref_rng.integers(ds._size, size=k)) \
            % ds._row_slots
        assert np.array_equal(idx, ref)        # bit-identical slot stream
    if sampler == "episode":
        for ep in ds.sampler.episodes():
            assert set(ep.tolist()) <= live    # no stale boundaries


@given(st.integers(0, 2 ** 31 - 1))
@settings(max_examples=10, deadline=None)
def test_moe_apply_capacity_drop_keeps_finite(seed):
    from repro.configs import get_arch
    cfg = get_arch("qwen3-moe-235b-a22b").smoke()
    import dataclasses
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))  # force drops
    params = M.init_moe(jax.random.PRNGKey(seed), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (2, 16, cfg.d_model))
    y, aux = M.moe_apply(params, cfg, x)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all()) and bool(jnp.isfinite(aux))
