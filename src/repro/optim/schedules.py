"""Learning-rate schedules (step -> lr), jit-safe."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr):
    return lambda step: jnp.float32(lr)


def linear(lr0, lr1, steps):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / steps, 0.0, 1.0)
        return jnp.float32(lr0) * (1 - t) + jnp.float32(lr1) * t
    return fn


def linear_warmup_cosine(peak, warmup_steps, total_steps, floor=0.0):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / jnp.maximum(warmup_steps, 1)
        t = jnp.clip((s - warmup_steps) / jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = floor + (peak - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup_steps, warm, cos)
    return fn
