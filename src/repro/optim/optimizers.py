"""Pure-pytree optimizers (no optax in env): AdamW, SGD, global-norm clip.

`adamw(..., master_fp32=True)` keeps fp32 master params + moments inside the
optimizer state while model params stay bf16 — the TPU dtype policy for the
>=100B-param assigned archs (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.utils.pytree import tree_global_norm


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple]   # (grads, state, params) -> (new_params, state, metrics)


def clip_by_global_norm(grads, max_norm):
    norm = tree_global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def sgd(lr: float | Callable, momentum: float = 0.0, clip_norm: float = 0.0):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) if momentum else None
        return {"step": jnp.zeros((), jnp.int32), "mu": mu}

    def update(grads, state, params):
        gnorm = tree_global_norm(grads)
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state["mu"], grads)
            upd = mu
        else:
            mu = None
            upd = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        new_params = jax.tree.map(
            lambda p, u: (p.astype(jnp.float32) - lr_t * u).astype(p.dtype), params, upd)
        return new_params, {"step": step, "mu": mu}, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


def adamw(lr: float | Callable, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
          clip_norm: float = 0.0, master_fp32: bool = False):
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        state = {
            "step": jnp.zeros((), jnp.int32),
            "mu": jax.tree.map(zeros32, params),
            "nu": jax.tree.map(zeros32, params),
        }
        if master_fp32:
            state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
        return state

    def update(grads, state, params):
        gnorm = tree_global_norm(grads)
        if clip_norm:
            grads, _ = clip_by_global_norm(grads, clip_norm)
        step = state["step"] + 1
        lr_t = lr_fn(step)
        g32 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["mu"], g32)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(g), state["nu"], g32)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        base = state.get("master", params)

        def upd(p, m, n):
            u = (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return p.astype(jnp.float32) - lr_t * u

        new_base = jax.tree.map(upd, base, mu, nu)
        new_state = {"step": step, "mu": mu, "nu": nu}
        if master_fp32:
            new_state["master"] = new_base
        new_params = jax.tree.map(lambda b, p: b.astype(p.dtype), new_base, params)
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)
