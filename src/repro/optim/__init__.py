from repro.optim.optimizers import adamw, sgd, clip_by_global_norm, Optimizer
from repro.optim.schedules import constant, linear_warmup_cosine, linear
