"""Rollout builders: thin wrappers over the collector plane (§3.2).

Historically this module held two full drivers — a jitted scan
(`build_rollout`) and a SEED-style ticket loop (`build_served_rollout`)
— that duplicated env stepping, acting, and segment assembly. Both are
now one-line compositions of `repro.envs.vector` (slot-vectorized env)
and `repro.actors.collector` (acting + assembly); the public signatures
and the `(carry, traj, episodes)` contract are unchanged, and the jitted
path is bit-identical to the pre-collector implementation (same rng
split order, same scan body — asserted by tests/test_collector.py).
"""
from __future__ import annotations

from typing import Sequence

from repro.actors.collector import JitCollector, ServedCollector
from repro.envs.base import MultiAgentEnv
from repro.envs.vector import JaxVectorEnv


def build_rollout(env: MultiAgentEnv, cfg, *, num_envs: int, unroll_len: int,
                  learner_slots: Sequence[int] | None = None, jit: bool = True):
    """Local-params rollout: `rollout(theta, phi, carry, rng) -> (carry,
    traj, episodes)`, one jitted scan over `unroll_len` steps with
    auto-reset — the TPU-native ("Anakin") adaptation of TLeague's CPU
    actor fleet."""
    venv = JaxVectorEnv(env, num_envs, jit=False)
    col = JitCollector(venv, cfg, unroll_len=unroll_len,
                       learner_slots=learner_slots, jit=jit)
    return col.collect, col.init_carry


def build_served_rollout(env: MultiAgentEnv, *, num_envs: int, unroll_len: int,
                         learner_slots: Sequence[int] | None = None):
    """SEED-style rollout: env stepping stays jitted on the Actor, but every
    policy forward is routed through a central InfServer via ticket futures
    (§3.2) — the learner θ and the opponent φ ride the same grouped batch.

    Returns (rollout, init_carry); `rollout(server, theta_key, phi_key,
    carry, rng)` matches `build_rollout`'s (carry, traj, episodes) contract
    so the Learner-side data path is identical for both actor modes.
    """
    venv = JaxVectorEnv(env, num_envs, jit=True)
    col = ServedCollector(venv, unroll_len=unroll_len,
                          learner_slots=learner_slots)
    return col.collect, col.init_carry
