"""Jitted vectorized rollout: the Actor's Env-Agt interaction loop (§3.2).

One call steps `num_envs` environments for `unroll_len` steps (the paper's
trajectory segment length L, eq. 1) with the learning agent on
`learner_slots` and the sampled opponent phi on the rest. Auto-resets on
done; emits the learner-side trajectory segment plus episode outcomes for
LeagueMgr reporting. Pure function of (theta, phi, carry, rng) — the
TPU-native ("Anakin") adaptation of TLeague's CPU actor fleet; the same
function also serves host-CPU actors feeding a device learner.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.policy import make_obs_policy
from repro.envs.base import MultiAgentEnv


def build_rollout(env: MultiAgentEnv, cfg, *, num_envs: int, unroll_len: int,
                  learner_slots: Sequence[int] | None = None, jit: bool = True):
    spec = env.spec
    learner_slots = tuple(learner_slots if learner_slots is not None
                          else range(spec.team_size))
    opp_slots = tuple(i for i in range(spec.num_agents) if i not in learner_slots)
    policy = make_obs_policy(cfg, spec.num_actions)
    n_l = len(learner_slots)

    v_reset = jax.vmap(env.reset)
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0))

    def init_carry(rng):
        states, obs = v_reset(jax.random.split(rng, num_envs))
        return states, obs

    def _act(params, rng, obs_slots):
        """obs_slots: (E, k, L) -> actions/logp/values (E, k)."""
        E, k, L0 = obs_slots.shape
        a, logp, v = policy.act(params, rng, obs_slots.reshape(E * k, L0))
        return (a.reshape(E, k), logp.reshape(E, k), v.reshape(E, k))

    def rollout(learner_params, opponent_params, carry, rng):
        def step_fn(c, rng_t):
            states, obs = c
            r_l, r_o, r_env, r_reset = jax.random.split(rng_t, 4)
            acts = jnp.zeros((num_envs, spec.num_agents), jnp.int32)
            a_l, logp_l, v_l = _act(learner_params, r_l, obs[:, list(learner_slots)])
            acts = acts.at[:, list(learner_slots)].set(a_l)
            if opp_slots:
                a_o, _, _ = _act(opponent_params, r_o, obs[:, list(opp_slots)])
                acts = acts.at[:, list(opp_slots)].set(a_o)

            states2, obs2, rewards, done, info = v_step(states, acts,
                                                        jax.random.split(r_env, num_envs))
            # auto-reset finished envs (fresh keys: r_env was consumed by v_step)
            states3, obs3 = v_reset(jax.random.split(r_reset, num_envs))
            sel = lambda a, b: jnp.where(
                done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
            states_n = jax.tree.map(sel, states3, states2)
            obs_n = jax.tree.map(sel, obs3, obs2)

            rec = {
                "obs": obs[:, list(learner_slots)],            # (E, k, L)
                "actions": a_l,
                "behavior_logp": logp_l,
                "behavior_values": v_l,
                "rewards": rewards[:, list(learner_slots)],
                "done": done,
                "outcome": info.get("outcome", jnp.zeros((num_envs,), jnp.int32)),
            }
            return (states_n, obs_n), rec

        ks = jax.random.split(rng, unroll_len + 1)
        carry, recs = jax.lax.scan(step_fn, carry, ks[:-1])
        # bootstrap value of the final observation (fresh subkey, not the
        # segment rng already split for the scan)
        _, final_obs = carry
        _, _, v_boot = _act(learner_params, ks[-1], final_obs[:, list(learner_slots)])

        # reshape (T, E, k, ...) -> (E*k, T, ...)
        def to_bt(x):
            x = jnp.moveaxis(x, 0, 1)                          # (E, T, k, ...)
            if x.ndim >= 3 and x.shape[2] == n_l:
                x = jnp.moveaxis(x, 2, 1)                      # (E, k, T, ...)
                return x.reshape((num_envs * n_l, unroll_len) + x.shape[3:])
            return x

        done_bt = jnp.repeat(jnp.moveaxis(recs["done"], 0, 1), n_l, axis=0)  # (E*k, T)
        traj = {
            "obs": to_bt(recs["obs"]),
            "actions": to_bt(recs["actions"]),
            "behavior_logp": to_bt(recs["behavior_logp"]),
            "behavior_values": to_bt(recs["behavior_values"]),
            "rewards": to_bt(recs["rewards"]),
            "done": done_bt,
            "bootstrap_value": v_boot.reshape(num_envs * n_l),
        }
        episodes = {"done": recs["done"], "outcome": recs["outcome"]}  # (T, E)
        return carry, traj, episodes

    if jit:
        rollout = jax.jit(rollout)
    return rollout, init_carry


def build_served_rollout(env: MultiAgentEnv, *, num_envs: int, unroll_len: int,
                         learner_slots: Sequence[int] | None = None):
    """SEED-style rollout: env stepping stays jitted on the Actor, but every
    policy forward is routed through a central InfServer via ticket futures
    (§3.2) — the learner θ and the opponent φ ride the same grouped batch.

    Returns (rollout, init_carry); `rollout(server, theta_key, phi_key,
    carry, rng)` matches `build_rollout`'s (carry, traj, episodes) contract
    so the Learner-side data path is identical for both actor modes.
    """
    spec = env.spec
    learner_slots = tuple(learner_slots if learner_slots is not None
                          else range(spec.team_size))
    opp_slots = tuple(i for i in range(spec.num_agents) if i not in learner_slots)
    n_l, n_o = len(learner_slots), len(opp_slots)
    E = num_envs

    v_reset = jax.jit(jax.vmap(env.reset))
    v_step = jax.jit(jax.vmap(env.step, in_axes=(0, 0, 0)))

    @jax.jit
    def _autoreset(done, reset_state, reset_obs, state, obs):
        sel = lambda a, b: jnp.where(
            done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
        return (jax.tree.map(sel, reset_state, state),
                jax.tree.map(sel, reset_obs, obs))

    def init_carry(rng):
        return v_reset(jax.random.split(rng, num_envs))

    def rollout(server, theta_key, phi_key, carry, rng):
        states, obs = carry
        recs = []
        for t in range(unroll_len):
            r_env, r_reset = jax.random.split(jax.random.fold_in(rng, t))
            obs_np = np.asarray(obs)
            tkt_l = server.submit(
                obs_np[:, list(learner_slots)].reshape(E * n_l, -1),
                model=theta_key)
            tkt_o = None
            if opp_slots:
                tkt_o = server.submit(
                    obs_np[:, list(opp_slots)].reshape(E * n_o, -1),
                    model=phi_key)
            server.flush()                     # θ and φ share one forward
            a_l, logp_l, v_l = (x.reshape(E, n_l) for x in server.get(tkt_l))
            acts = np.zeros((E, spec.num_agents), np.int32)
            acts[:, list(learner_slots)] = a_l
            if tkt_o is not None:
                acts[:, list(opp_slots)] = server.get(tkt_o)[0].reshape(E, n_o)

            states2, obs2, rewards, done, info = v_step(
                states, jnp.asarray(acts), jax.random.split(r_env, num_envs))
            states3, obs3 = v_reset(jax.random.split(r_reset, num_envs))
            states, obs = _autoreset(done, states3, obs3, states2, obs2)
            rewards = np.asarray(rewards)
            recs.append({
                "obs": obs_np[:, list(learner_slots)],
                "actions": a_l,
                "behavior_logp": logp_l,
                "behavior_values": v_l,
                "rewards": rewards[:, list(learner_slots)],
                "done": np.asarray(done),
                "outcome": np.asarray(info.get(
                    "outcome", jnp.zeros((num_envs,), jnp.int32))),
            })

        final_obs = np.asarray(obs)
        tkt = server.submit(final_obs[:, list(learner_slots)].reshape(E * n_l, -1),
                            model=theta_key)
        server.flush()
        v_boot = server.get(tkt)[2]

        def to_bt(name):
            x = np.stack([r[name] for r in recs], axis=1)   # (E, T, k, ...)
            if x.ndim >= 3 and x.shape[2] == n_l:
                x = np.moveaxis(x, 2, 1)                     # (E, k, T, ...)
                return x.reshape((E * n_l, unroll_len) + x.shape[3:])
            return x

        done_te = np.stack([r["done"] for r in recs], axis=0)     # (T, E)
        traj = {
            "obs": to_bt("obs"),
            "actions": to_bt("actions"),
            "behavior_logp": to_bt("behavior_logp"),
            "behavior_values": to_bt("behavior_values"),
            "rewards": to_bt("rewards"),
            "done": np.repeat(done_te.T, n_l, axis=0),            # (E*k, T)
            "bootstrap_value": v_boot.reshape(E * n_l),
        }
        episodes = {"done": done_te,
                    "outcome": np.stack([r["outcome"] for r in recs], axis=0)}
        return (states, obs), traj, episodes

    return rollout, init_carry
