"""Jitted vectorized rollout: the Actor's Env-Agt interaction loop (§3.2).

One call steps `num_envs` environments for `unroll_len` steps (the paper's
trajectory segment length L, eq. 1) with the learning agent on
`learner_slots` and the sampled opponent phi on the rest. Auto-resets on
done; emits the learner-side trajectory segment plus episode outcomes for
LeagueMgr reporting. Pure function of (theta, phi, carry, rng) — the
TPU-native ("Anakin") adaptation of TLeague's CPU actor fleet; the same
function also serves host-CPU actors feeding a device learner.
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.actors.policy import make_obs_policy
from repro.envs.base import MultiAgentEnv


def build_rollout(env: MultiAgentEnv, cfg, *, num_envs: int, unroll_len: int,
                  learner_slots: Sequence[int] | None = None, jit: bool = True):
    spec = env.spec
    learner_slots = tuple(learner_slots if learner_slots is not None
                          else range(spec.team_size))
    opp_slots = tuple(i for i in range(spec.num_agents) if i not in learner_slots)
    policy = make_obs_policy(cfg, spec.num_actions)
    n_l = len(learner_slots)

    v_reset = jax.vmap(env.reset)
    v_step = jax.vmap(env.step, in_axes=(0, 0, 0))

    def init_carry(rng):
        states, obs = v_reset(jax.random.split(rng, num_envs))
        return states, obs

    def _act(params, rng, obs_slots):
        """obs_slots: (E, k, L) -> actions/logp/values (E, k)."""
        E, k, L0 = obs_slots.shape
        a, logp, v = policy.act(params, rng, obs_slots.reshape(E * k, L0))
        return (a.reshape(E, k), logp.reshape(E, k), v.reshape(E, k))

    def rollout(learner_params, opponent_params, carry, rng):
        def step_fn(c, rng_t):
            states, obs = c
            r_l, r_o, r_env = jax.random.split(rng_t, 3)
            acts = jnp.zeros((num_envs, spec.num_agents), jnp.int32)
            a_l, logp_l, v_l = _act(learner_params, r_l, obs[:, list(learner_slots)])
            acts = acts.at[:, list(learner_slots)].set(a_l)
            if opp_slots:
                a_o, _, _ = _act(opponent_params, r_o, obs[:, list(opp_slots)])
                acts = acts.at[:, list(opp_slots)].set(a_o)

            states2, obs2, rewards, done, info = v_step(states, acts,
                                                        jax.random.split(r_env, num_envs))
            # auto-reset finished envs
            states3, obs3 = v_reset(jax.random.split(r_env, num_envs))
            sel = lambda a, b: jnp.where(
                done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
            states_n = jax.tree.map(sel, states3, states2)
            obs_n = jax.tree.map(sel, obs3, obs2)

            rec = {
                "obs": obs[:, list(learner_slots)],            # (E, k, L)
                "actions": a_l,
                "behavior_logp": logp_l,
                "behavior_values": v_l,
                "rewards": rewards[:, list(learner_slots)],
                "done": done,
                "outcome": info.get("outcome", jnp.zeros((num_envs,), jnp.int32)),
            }
            return (states_n, obs_n), rec

        carry, recs = jax.lax.scan(step_fn, carry, jax.random.split(rng, unroll_len))
        # bootstrap value of the final observation
        _, final_obs = carry
        _, _, v_boot = _act(learner_params, rng, final_obs[:, list(learner_slots)])

        # reshape (T, E, k, ...) -> (E*k, T, ...)
        def to_bt(x):
            x = jnp.moveaxis(x, 0, 1)                          # (E, T, k, ...)
            if x.ndim >= 3 and x.shape[2] == n_l:
                x = jnp.moveaxis(x, 2, 1)                      # (E, k, T, ...)
                return x.reshape((num_envs * n_l, unroll_len) + x.shape[3:])
            return x

        done_bt = jnp.repeat(jnp.moveaxis(recs["done"], 0, 1), n_l, axis=0)  # (E*k, T)
        traj = {
            "obs": to_bt(recs["obs"]),
            "actions": to_bt(recs["actions"]),
            "behavior_logp": to_bt(recs["behavior_logp"]),
            "behavior_values": to_bt(recs["behavior_values"]),
            "rewards": to_bt(recs["rewards"]),
            "done": done_bt,
            "bootstrap_value": v_boot.reshape(num_envs * n_l),
        }
        episodes = {"done": recs["done"], "outcome": recs["outcome"]}  # (T, E)
        return carry, traj, episodes

    if jit:
        rollout = jax.jit(rollout)
    return rollout, init_carry
