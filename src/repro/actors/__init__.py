from repro.actors.policy import make_obs_policy
from repro.actors.collector import (JitCollector, ServedCollector,
                                    collect_interleaved)
from repro.actors.rollout import build_rollout, build_served_rollout
from repro.actors.actor import Actor
