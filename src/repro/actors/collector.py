"""Collector: owns N VectorEnv slots, drives acting, assembles segments.

The collector plane splits what `build_rollout`/`build_served_rollout`
used to fuse: the `VectorEnv` steps slots, the Collector decides *where
actions come from* (local params vs. InfServer tickets) and emits the
`(carry, traj, episodes)` segment contract everything downstream
(`Actor`, `ActorWorker`, the `--sync` oracle, `DataServer`) already
speaks.

* **JitCollector** — local-params acting compiled into one scan. The
  step body is the exact sequence the old `build_rollout` traced
  (identical rng split order, identical autoreset select), so its
  output is bit-identical to the pre-collector driver.
* **ServedCollector** — SEED-style acting through an InfServer ticket
  stream. Exposed as a *phase-split* machine (`begin` /
  `submit_step` / `complete_step` / `submit_bootstrap` / `finish`) so
  many collectors can interleave their submits into one server and
  coalesce into dense batches; `collect(...)` runs the phases
  back-to-back for the solo case. With ``coalesce=True`` (default) the
  collector never calls `server.flush()` — the first `get()` of an
  unresolved ticket flushes *everything pending on the server*, so
  whoever reads first drains every collector's tickets in one grouped
  forward. ``coalesce=False`` restores the old eager per-step flush.

`collect_interleaved` drives K collectors over one server in lockstep
(step t of every collector submits before any of them completes), which
is both the throughput layout and the deterministic harness the
coalescing benchmark uses.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.policy import make_obs_policy
from repro.envs.vector import VectorEnv


def _resolve_slots(spec, learner_slots):
    learner_slots = tuple(learner_slots if learner_slots is not None
                          else range(spec.team_size))
    opp_slots = tuple(i for i in range(spec.num_agents)
                      if i not in learner_slots)
    return learner_slots, opp_slots


class JitCollector:
    """Local-params collector: one jitted scan over `unroll_len` steps.

    ``collect(learner_params, opponent_params, carry, rng)`` is a pure
    function with `build_rollout`'s exact signature and rng discipline —
    `Actor` uses it unchanged via the `build_rollout` wrapper.
    """

    def __init__(self, venv: VectorEnv, cfg, *, unroll_len: int,
                 learner_slots: Sequence[int] | None = None, jit: bool = True):
        assert venv.jittable, "JitCollector needs a jittable VectorEnv " \
            "(use ServedCollector / HostVectorEnv for host-loop envs)"
        spec = venv.spec
        self.venv = venv
        self.unroll_len = unroll_len
        self.learner_slots, self.opp_slots = _resolve_slots(spec, learner_slots)
        policy = make_obs_policy(cfg, spec.num_actions)
        n_l = len(self.learner_slots)
        E = venv.num_envs
        learner_slots, opp_slots = self.learner_slots, self.opp_slots

        def _act(params, rng, obs_slots):
            E_, k, L0 = obs_slots.shape
            a, logp, v = policy.act(params, rng, obs_slots.reshape(E_ * k, L0))
            return (a.reshape(E_, k), logp.reshape(E_, k), v.reshape(E_, k))

        def collect(learner_params, opponent_params, carry, rng):
            def step_fn(c, rng_t):
                states, obs = c
                r_l, r_o, r_env, r_reset = jax.random.split(rng_t, 4)
                acts = jnp.zeros((E, spec.num_agents), jnp.int32)
                a_l, logp_l, v_l = _act(learner_params, r_l,
                                        obs[:, list(learner_slots)])
                acts = acts.at[:, list(learner_slots)].set(a_l)
                if opp_slots:
                    a_o, _, _ = _act(opponent_params, r_o,
                                     obs[:, list(opp_slots)])
                    acts = acts.at[:, list(opp_slots)].set(a_o)

                states2, obs2, rewards, done, info = venv.step(states, acts,
                                                               r_env)
                # auto-reset finished slots (fresh keys: r_env was consumed)
                states3, obs3 = venv.reset(r_reset)
                states_n, obs_n = venv.autoreset(done, states3, obs3,
                                                 states2, obs2)
                rec = {
                    "obs": obs[:, list(learner_slots)],        # (E, k, L)
                    "actions": a_l,
                    "behavior_logp": logp_l,
                    "behavior_values": v_l,
                    "rewards": rewards[:, list(learner_slots)],
                    "done": done,
                    "outcome": info.get("outcome",
                                        jnp.zeros((E,), jnp.int32)),
                }
                return (states_n, obs_n), rec

            ks = jax.random.split(rng, unroll_len + 1)
            carry, recs = jax.lax.scan(step_fn, carry, ks[:-1])
            # bootstrap value of the final observation (fresh subkey, not
            # the segment rng already split for the scan)
            _, final_obs = carry
            _, _, v_boot = _act(learner_params, ks[-1],
                                final_obs[:, list(learner_slots)])

            # reshape (T, E, k, ...) -> (E*k, T, ...)
            def to_bt(x):
                x = jnp.moveaxis(x, 0, 1)                      # (E, T, k, ...)
                if x.ndim >= 3 and x.shape[2] == n_l:
                    x = jnp.moveaxis(x, 2, 1)                  # (E, k, T, ...)
                    return x.reshape((E * n_l, unroll_len) + x.shape[3:])
                return x

            done_bt = jnp.repeat(jnp.moveaxis(recs["done"], 0, 1), n_l,
                                 axis=0)                       # (E*k, T)
            traj = {
                "obs": to_bt(recs["obs"]),
                "actions": to_bt(recs["actions"]),
                "behavior_logp": to_bt(recs["behavior_logp"]),
                "behavior_values": to_bt(recs["behavior_values"]),
                "rewards": to_bt(recs["rewards"]),
                "done": done_bt,
                "bootstrap_value": v_boot.reshape(E * n_l),
            }
            episodes = {"done": recs["done"], "outcome": recs["outcome"]}
            return carry, traj, episodes

        self.collect = jax.jit(collect) if jit else collect

    def init_carry(self, rng):
        return self.venv.reset(rng)


class ServedCollector:
    """Ticket-stream collector: policy forwards go through an InfServer.

    Phase-split per step so K collectors can interleave on one server:

        c.begin(carry, rng)
        for t in range(unroll_len):
            c.submit_step(server, theta_key, phi_key)   # enqueue tickets
            c.complete_step(server)                     # resolve + step env
        c.submit_bootstrap(server, theta_key)
        carry, traj, episodes = c.finish(server)

    `complete_step`'s first `server.get()` flushes every pending ticket
    on the server — including other collectors' — so interleaved drivers
    get one dense grouped forward per step instead of one per collector.
    """

    def __init__(self, venv: VectorEnv, *, unroll_len: int,
                 learner_slots: Sequence[int] | None = None,
                 coalesce: bool = True):
        spec = venv.spec
        self.venv = venv
        self.unroll_len = unroll_len
        self.coalesce = coalesce
        self.learner_slots, self.opp_slots = _resolve_slots(spec, learner_slots)
        self.n_l, self.n_o = len(self.learner_slots), len(self.opp_slots)
        self._phase = "idle"

    # -- phase machine ------------------------------------------------------
    def begin(self, carry, rng):
        assert self._phase in ("idle",), f"begin() in phase {self._phase}"
        self._states, self._obs = carry
        self._rng = rng
        self._t = 0
        self._recs = []
        self._pending = None
        self._phase = "submit"

    def submit_step(self, server, theta_key, phi_key):
        assert self._phase == "submit", f"submit_step() in phase {self._phase}"
        E, n_l, n_o = self.venv.num_envs, self.n_l, self.n_o
        obs_np = np.asarray(self._obs)
        # pipelined submits when the server speaks them (InfServerClient
        # over the v2 transport): both slot groups' rows go on the wire
        # back to back with no ack round trip in between — across many
        # collectors this is the 64-actor submit storm overlapping
        sub = getattr(server, "submit_async", None) or server.submit
        tkt_l = sub(
            obs_np[:, list(self.learner_slots)].reshape(E * n_l, -1),
            model=theta_key)
        tkt_o = None
        if self.opp_slots:
            tkt_o = sub(
                obs_np[:, list(self.opp_slots)].reshape(E * n_o, -1),
                model=phi_key)
        if not self.coalesce:
            server.flush()                     # eager: θ and φ share one forward
        self._pending = (obs_np, tkt_l, tkt_o)
        self._phase = "complete"

    def complete_step(self, server):
        assert self._phase == "complete", \
            f"complete_step() in phase {self._phase}"
        E, n_l, n_o = self.venv.num_envs, self.n_l, self.n_o
        spec = self.venv.spec
        obs_np, tkt_l, tkt_o = self._pending
        self._pending = None
        # get() self-flushes anything still pending on the server — in the
        # interleaved layout this is the single grouped forward per step
        a_l, logp_l, v_l = (x.reshape(E, n_l) for x in server.get(tkt_l))
        acts = np.zeros((E, spec.num_agents), np.int32)
        acts[:, list(self.learner_slots)] = a_l
        if tkt_o is not None:
            acts[:, list(self.opp_slots)] = \
                server.get(tkt_o)[0].reshape(E, n_o)

        r_env, r_reset = jax.random.split(jax.random.fold_in(self._rng,
                                                             self._t))
        self._states, self._obs, rewards, done, outcome = \
            self.venv.step_autoreset(self._states, jnp.asarray(acts),
                                     r_env, r_reset)
        rewards = np.asarray(rewards)
        self._recs.append({
            "obs": obs_np[:, list(self.learner_slots)],
            "actions": a_l,
            "behavior_logp": logp_l,
            "behavior_values": v_l,
            "rewards": rewards[:, list(self.learner_slots)],
            "done": np.asarray(done),
            "outcome": np.asarray(outcome),
        })
        self._t += 1
        self._phase = "submit" if self._t < self.unroll_len else "bootstrap"

    def submit_bootstrap(self, server, theta_key):
        assert self._phase == "bootstrap", \
            f"submit_bootstrap() in phase {self._phase}"
        E, n_l = self.venv.num_envs, self.n_l
        final_obs = np.asarray(self._obs)
        sub = getattr(server, "submit_async", None) or server.submit
        self._boot_tkt = sub(
            final_obs[:, list(self.learner_slots)].reshape(E * n_l, -1),
            model=theta_key)
        if not self.coalesce:
            server.flush()
        self._phase = "finish"

    def finish(self, server):
        assert self._phase == "finish", f"finish() in phase {self._phase}"
        E, n_l = self.venv.num_envs, self.n_l
        T = self.unroll_len
        v_boot = server.get(self._boot_tkt)[2]
        recs = self._recs

        def to_bt(name):
            x = np.stack([r[name] for r in recs], axis=1)   # (E, T, k, ...)
            if x.ndim >= 3 and x.shape[2] == n_l:
                x = np.moveaxis(x, 2, 1)                     # (E, k, T, ...)
                return x.reshape((E * n_l, T) + x.shape[3:])
            return x

        done_te = np.stack([r["done"] for r in recs], axis=0)     # (T, E)
        traj = {
            "obs": to_bt("obs"),
            "actions": to_bt("actions"),
            "behavior_logp": to_bt("behavior_logp"),
            "behavior_values": to_bt("behavior_values"),
            "rewards": to_bt("rewards"),
            "done": np.repeat(done_te.T, n_l, axis=0),            # (E*k, T)
            "bootstrap_value": v_boot.reshape(E * n_l),
        }
        episodes = {"done": done_te,
                    "outcome": np.stack([r["outcome"] for r in recs], axis=0)}
        self._recs, self._boot_tkt = [], None
        self._phase = "idle"
        return (self._states, self._obs), traj, episodes

    # -- solo driver --------------------------------------------------------
    def collect(self, server, theta_key, phi_key, carry, rng):
        """`build_served_rollout`-compatible: run all phases back-to-back."""
        self.begin(carry, rng)
        for _ in range(self.unroll_len):
            self.submit_step(server, theta_key, phi_key)
            self.complete_step(server)
        self.submit_bootstrap(server, theta_key)
        return self.finish(server)

    def init_carry(self, rng):
        return self.venv.reset(rng)


def collect_interleaved(collectors: Sequence[ServedCollector], server,
                        jobs: Sequence[Tuple]) -> list:
    """Drive K ServedCollectors over one shared server in lockstep.

    ``jobs[i] = (theta_key, phi_key, carry, rng)`` for ``collectors[i]``.
    Every collector submits its step-t tickets before any of them
    completes, so each step runs as one dense grouped forward over all
    K collectors' slots. All collectors must share one `unroll_len`.
    Returns ``[(carry, traj, episodes), ...]`` in collector order.
    """
    assert len(collectors) == len(jobs) and collectors
    T = collectors[0].unroll_len
    assert all(c.unroll_len == T for c in collectors), \
        "interleaved collectors must share unroll_len"
    for c, (theta, phi, carry, rng) in zip(collectors, jobs):
        c.begin(carry, rng)
    for _ in range(T):
        for c, (theta, phi, _, _) in zip(collectors, jobs):
            c.submit_step(server, theta, phi)
        for c in collectors:
            c.complete_step(server)
    for c, (theta, _, _, _) in zip(collectors, jobs):
        c.submit_bootstrap(server, theta)
    return [c.finish(server) for c in collectors]
