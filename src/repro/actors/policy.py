"""Agt: the policy wrapper an Actor embeds (§3.2).

Observations are token sequences; any assigned backbone consumes them and
the action head is the (masked) LM head at the last position, the value the
scalar head there — one policy interface for all ten architectures.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import forward_train
from repro.rl.distributions import categorical_logp


class ObsPolicy(NamedTuple):
    logits_values: callable   # (params, obs (B,L)) -> (logits (B,A), values (B,))
    act: callable             # (params, rng, obs) -> (action, logp, value)


def make_obs_policy(cfg, num_actions: int) -> ObsPolicy:
    assert num_actions <= cfg.vocab_size

    def logits_values(params, obs):
        logits, values, _ = forward_train(params, cfg, {"tokens": obs})
        return logits[:, -1, :num_actions], values[:, -1]

    def act(params, rng, obs):
        lg, v = logits_values(params, obs)
        a = jax.random.categorical(rng, lg, axis=-1)
        logp = categorical_logp(lg, a)
        return a, logp, v

    return ObsPolicy(logits_values, act)
