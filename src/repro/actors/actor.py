"""Actor: the data-producing module (§3.2).

Loop per the paper: at each episode/segment beginning request a Task from
LeagueMgr (learning policy theta + opponent phi), pull both parameter sets
from ModelPool, run the Env-Agt interaction, ship the trajectory segment to
the Learner (here: a DataServer queue), and report game outcomes back to
LeagueMgr at episode endings.

Two inference modes:
  * local (default): θ and φ forwards run inside the jitted rollout scan —
    the TPU-native "Anakin" layout.
  * served: pass `inf_server=` and every policy forward is routed through
    the central continuous-batching InfServer (SEED-style), with θ and φ
    hosted as separate routes of one grouped forward. The Actor keeps the
    server's routes fresh from the ModelPool before each segment.

Parameter sync rides the param plane (`repro.params`): θ and φ are
pulled through a `CachedPuller`, so a segment whose models did not
change costs one `NotModified` tag per key instead of a full pytree
copy (and, against a remote pool, zero param bytes on the wire), while
a Learner publish ships only the changed leaves. The served refresh is
hash-gated end to end: `update_params`/`ensure_model` carry the
manifest's `tree_hash`, so the InfServer no-ops identical swaps and a
remote server is not even sent the params (`has_model` probe).
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.actors.rollout import build_rollout, build_served_rollout
from repro.core import LeagueMgr, MatchResult
from repro.envs.base import MultiAgentEnv
from repro.params import CachedPuller


class Actor:
    def __init__(self, env: MultiAgentEnv, cfg, league: LeagueMgr, *,
                 agent_id: str = "main", num_envs: int = 16, unroll_len: int = 16,
                 learner_slots=None, seed: int = 0, inf_server=None,
                 actor_id: Optional[str] = None):
        self.env, self.cfg, self.league = env, cfg, league
        self.agent_id = agent_id
        # lease identity: when set, request_task names this actor so the
        # league can tie the lease to heartbeat liveness (and release the
        # previous lease when the next segment starts)
        self.actor_id = actor_id
        self.inf_server = inf_server
        if inf_server is None:
            self.rollout, self.init_carry = build_rollout(
                env, cfg, num_envs=num_envs, unroll_len=unroll_len,
                learner_slots=learner_slots)
        else:
            self.rollout, self.init_carry = build_served_rollout(
                env, num_envs=num_envs, unroll_len=unroll_len,
                learner_slots=learner_slots)
        self.rng = jax.random.PRNGKey(seed)
        self.carry = None
        # version-cached pulls: unchanged models cost a NotModified tag,
        # Learner publishes arrive as changed-leaf deltas
        self._puller = CachedPuller(league.model_pool)
        self._theta_key = None        # current lineage key (cache eviction)
        self._served_theta_key = None
        self._evict_backlog = set()   # routes declined while requests pending
        self.num_envs, self.unroll_len = num_envs, unroll_len
        self.frames_produced = 0   # rfps numerator (paper Table 3)

    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def run_segment(self):
        """One Task -> one unroll segment. Returns the learner trajectory."""
        if self.actor_id is None:
            task = self.league.request_task(self.agent_id)
        else:
            task = self.league.request_task(self.agent_id,
                                            actor_id=self.actor_id)
        # the lineage advanced: drop the superseded theta's cache entry —
        # it is only ever pulled again if it froze into the pool and comes
        # back as somebody's φ (one full re-pull then). Opponent entries
        # stay cached and track pool size, the same growth contract as the
        # ModelPool itself.
        if self._theta_key is not None and self._theta_key != task.learner_key:
            self._puller.drop(self._theta_key)
        self._theta_key = task.learner_key
        theta, theta_man = self._puller.get_with_manifest(task.learner_key)
        phi, phi_man = self._puller.get_with_manifest(task.opponent_keys[0])
        if self.carry is None:
            self.carry = self.init_carry(self._next_rng())
        if self.inf_server is None:
            self.carry, traj, episodes = self.rollout(theta, phi, self.carry,
                                                      self._next_rng())
        else:
            self._maybe_refresh_served(task, theta, theta_man, phi, phi_man)
            self.carry, traj, episodes = self.rollout(
                self.inf_server, task.learner_key, task.opponent_keys[0],
                self.carry, self._next_rng())
        self._report(task, episodes)
        self.frames_produced += self.num_envs * self.unroll_len
        return traj, task

    def _maybe_refresh_served(self, task, theta, theta_man, phi, phi_man):
        """Refresh the shared InfServer's routes from the pool: θ
        hot-swaps whenever its content actually changed (the Learner
        keeps pushing), frozen φ registers once; evict the previous
        lineage route when θ's key advances so the registry doesn't grow
        by one model per learning period.

        Hash-gated (param plane): every refresh carries the manifest's
        `tree_hash` + pool version, so the server no-ops identical
        content (whoever delivered it first) instead of re-uploading and
        re-sharding, and drops stale-version stragglers. Against a
        remote server the `InfServerClient` probes `has_model` first, so
        a gated refresh never ships the bytes — the calls below stay
        unconditional on purpose: the probe doubles as the route
        EXISTENCE check, re-registering a route another actor evicted
        (skipping based on this actor's memory alone would race that
        eviction)."""
        prev = self._served_theta_key
        if prev is not None and prev != task.learner_key:
            self._evict_backlog.add(prev)
        self._evict_backlog.discard(task.learner_key)
        self._evict_backlog.discard(task.opponent_keys[0])
        # a superseded theta that froze into the pool is now a
        # legitimate opponent route other workers may be mid-segment
        # on — keep it hosted (the registry then tracks pool size, the
        # same growth as the ModelPool itself); evict_model declines
        # (returns False) while requests are queued for the route, so
        # whatever remains is retried next segment
        # frozen_pool is read ONCE per segment: against a remote
        # LeagueMgrClient the attribute is a full RPC, so per-element
        # evaluation inside the comprehension would multiply round trips
        frozen = set(self.league.frozen_pool)
        self._evict_backlog = {
            k for k in self._evict_backlog
            if k not in frozen
            and not self.inf_server.evict_model(k)}
        self._served_theta_key = task.learner_key
        self.inf_server.update_params(
            theta, key=task.learner_key,
            content_hash=theta_man.tree_hash if theta_man else None,
            version=theta_man.version if theta_man else None)
        self.inf_server.ensure_model(
            task.opponent_keys[0], phi,
            content_hash=phi_man.tree_hash if phi_man else None)

    def _report(self, task, episodes):
        done = np.asarray(episodes["done"])      # (T, E)
        outcome = np.asarray(episodes["outcome"])
        for t, e in zip(*np.nonzero(done)):
            self.league.report_result(MatchResult(
                learner_key=task.learner_key,
                opponent_keys=task.opponent_keys,
                outcome=int(outcome[t, e]),
                episode_len=int(t) + 1,
                task_id=task.task_id))
