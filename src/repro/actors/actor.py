"""Actor: the data-producing module (§3.2).

Loop per the paper: at each episode/segment beginning request a Task from
LeagueMgr (learning policy theta + opponent phi), pull both parameter sets
from ModelPool, run the Env-Agt interaction, ship the trajectory segment to
the Learner (here: a DataServer queue), and report game outcomes back to
LeagueMgr at episode endings.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.actors.rollout import build_rollout
from repro.core import LeagueMgr, MatchResult
from repro.envs.base import MultiAgentEnv


class Actor:
    def __init__(self, env: MultiAgentEnv, cfg, league: LeagueMgr, *,
                 agent_id: str = "main", num_envs: int = 16, unroll_len: int = 16,
                 learner_slots=None, seed: int = 0):
        self.env, self.cfg, self.league = env, cfg, league
        self.agent_id = agent_id
        self.rollout, self.init_carry = build_rollout(
            env, cfg, num_envs=num_envs, unroll_len=unroll_len,
            learner_slots=learner_slots)
        self.rng = jax.random.PRNGKey(seed)
        self.carry = None
        self.num_envs, self.unroll_len = num_envs, unroll_len
        self.frames_produced = 0   # rfps numerator (paper Table 3)

    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def run_segment(self):
        """One Task -> one unroll segment. Returns the learner trajectory."""
        task = self.league.request_task(self.agent_id)
        theta = self.league.model_pool.pull(task.learner_key)
        phi = self.league.model_pool.pull(task.opponent_keys[0])
        if self.carry is None:
            self.carry = self.init_carry(self._next_rng())
        self.carry, traj, episodes = self.rollout(theta, phi, self.carry,
                                                  self._next_rng())
        self._report(task, episodes)
        self.frames_produced += self.num_envs * self.unroll_len
        return traj, task

    def _report(self, task, episodes):
        done = np.asarray(episodes["done"])      # (T, E)
        outcome = np.asarray(episodes["outcome"])
        for t, e in zip(*np.nonzero(done)):
            self.league.report_result(MatchResult(
                learner_key=task.learner_key,
                opponent_keys=task.opponent_keys,
                outcome=int(outcome[t, e]),
                episode_len=int(t) + 1))
