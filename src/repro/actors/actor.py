"""Actor: the data-producing module (§3.2).

Loop per the paper: at each episode/segment beginning request a Task from
LeagueMgr (learning policy theta + opponent phi), pull both parameter sets
from ModelPool, run the Env-Agt interaction, ship the trajectory segment to
the Learner (here: a DataServer queue), and report game outcomes back to
LeagueMgr at episode endings.

Two inference modes:
  * local (default): θ and φ forwards run inside the jitted rollout scan —
    the TPU-native "Anakin" layout.
  * served: pass `inf_server=` and every policy forward is routed through
    the central continuous-batching InfServer (SEED-style), with θ and φ
    hosted as separate routes of one grouped forward. The Actor keeps the
    server's routes fresh from the ModelPool before each segment.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np

from repro.actors.rollout import build_rollout, build_served_rollout
from repro.core import LeagueMgr, MatchResult
from repro.envs.base import MultiAgentEnv


class Actor:
    def __init__(self, env: MultiAgentEnv, cfg, league: LeagueMgr, *,
                 agent_id: str = "main", num_envs: int = 16, unroll_len: int = 16,
                 learner_slots=None, seed: int = 0, inf_server=None):
        self.env, self.cfg, self.league = env, cfg, league
        self.agent_id = agent_id
        self.inf_server = inf_server
        if inf_server is None:
            self.rollout, self.init_carry = build_rollout(
                env, cfg, num_envs=num_envs, unroll_len=unroll_len,
                learner_slots=learner_slots)
        else:
            self.rollout, self.init_carry = build_served_rollout(
                env, num_envs=num_envs, unroll_len=unroll_len,
                learner_slots=learner_slots)
        self.rng = jax.random.PRNGKey(seed)
        self.carry = None
        self._served_theta_key = None
        self._evict_backlog = set()   # routes declined while requests pending
        self.num_envs, self.unroll_len = num_envs, unroll_len
        self.frames_produced = 0   # rfps numerator (paper Table 3)

    def _next_rng(self):
        self.rng, k = jax.random.split(self.rng)
        return k

    def run_segment(self):
        """One Task -> one unroll segment. Returns the learner trajectory."""
        task = self.league.request_task(self.agent_id)
        theta = self.league.model_pool.pull(task.learner_key)
        phi = self.league.model_pool.pull(task.opponent_keys[0])
        if self.carry is None:
            self.carry = self.init_carry(self._next_rng())
        if self.inf_server is None:
            self.carry, traj, episodes = self.rollout(theta, phi, self.carry,
                                                      self._next_rng())
        else:
            # refresh the server's routes from the pool: θ hot-swaps every
            # segment (the Learner keeps pushing), frozen φ registers once;
            # evict the previous lineage route when θ's key advances so the
            # registry doesn't grow by one model per learning period
            prev = self._served_theta_key
            if prev is not None and prev != task.learner_key:
                self._evict_backlog.add(prev)
            self._evict_backlog.discard(task.learner_key)
            self._evict_backlog.discard(task.opponent_keys[0])
            # a superseded theta that froze into the pool is now a
            # legitimate opponent route other workers may be mid-segment
            # on — keep it hosted (the registry then tracks pool size, the
            # same growth as the ModelPool itself); evict_model declines
            # (returns False) while requests are queued for the route, so
            # whatever remains is retried next segment
            # frozen_pool is read ONCE per segment: against a remote
            # LeagueMgrClient the attribute is a full RPC, so per-element
            # evaluation inside the comprehension would multiply round trips
            frozen = set(self.league.frozen_pool)
            self._evict_backlog = {
                k for k in self._evict_backlog
                if k not in frozen
                and not self.inf_server.evict_model(k)}
            self._served_theta_key = task.learner_key
            self.inf_server.update_params(theta, key=task.learner_key)
            self.inf_server.ensure_model(task.opponent_keys[0], phi)
            self.carry, traj, episodes = self.rollout(
                self.inf_server, task.learner_key, task.opponent_keys[0],
                self.carry, self._next_rng())
        self._report(task, episodes)
        self.frames_produced += self.num_envs * self.unroll_len
        return traj, task

    def _report(self, task, episodes):
        done = np.asarray(episodes["done"])      # (T, E)
        outcome = np.asarray(episodes["outcome"])
        for t, e in zip(*np.nonzero(done)):
            self.league.report_result(MatchResult(
                learner_key=task.learner_key,
                opponent_keys=task.opponent_keys,
                outcome=int(outcome[t, e]),
                episode_len=int(t) + 1))
