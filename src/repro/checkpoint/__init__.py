from repro.checkpoint.checkpoint import save_pytree, load_pytree, save_league, load_league
