"""Checkpointing: pytrees -> .npz (params/opt state), league state -> .json.

The paper freezes models into the ModelPool and persists the league
(payoff matrix, hyperparams, model lineage); `save_league`/`load_league`
cover that, `save_pytree`/`load_pytree` cover the neural-net side.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = np.asarray(leaf)
    return out, treedef


def save_pytree(path: str, tree: Any) -> None:
    arrays, _ = _flatten_with_names(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    np.savez(path, **arrays)


def load_pytree(path: str, template: Any) -> Any:
    with np.load(path) as data:
        arrays, treedef = _flatten_with_names(template)
        leaves = []
        flat, _ = jax.tree_util.tree_flatten_with_path(template)
        for p, leaf in flat:
            key = "/".join(str(getattr(q, "key", getattr(q, "idx", q))) for q in p)
            arr = data[key]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(arr.astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(template), leaves)


def save_league(path: str, state: dict) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        json.dump(state, f, indent=1, default=lambda o: o.tolist() if hasattr(o, "tolist") else str(o))


def load_league(path: str) -> dict:
    with open(path) as f:
        return json.load(f)
