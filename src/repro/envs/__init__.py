from repro.envs.base import EnvSpec, MultiAgentEnv, ENVS, make_env
from repro.envs.vector import (VectorEnv, JaxVectorEnv, HostVectorEnv,
                               make_vector_env)
from repro.envs import matrix_games, pommerman_lite, duel  # noqa: F401 (registration)
