"""Pure-JAX multi-agent environment protocol (the Arena/Env role, §3.2, §3.5).

The paper requires gym-compatible multi-agent envs:
    l_obs = env.reset();  l_obs, l_rwd, done, info = env.step(l_act)
Our functional equivalent (so envs jit/vmap/scan on-device — the TPU-native
actor adaptation, DESIGN.md §2):

    state, obs = env.reset(rng)
    state, obs, rewards, done, info = env.step(state, actions, rng)

obs is (num_agents, obs_len) int32 *tokens* — every env tokenizes its
observation so any assigned policy backbone consumes it directly.
rewards is (num_agents,) fp32; done is a scalar bool.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

from repro.utils.registry import Registry


@dataclass(frozen=True)
class EnvSpec:
    name: str
    num_agents: int
    obs_len: int            # tokens per observation
    num_actions: int
    max_steps: int
    obs_vocab: int          # obs token ids live in [0, obs_vocab)
    team_size: int = 1      # >1: consecutive slots form teams (Pommerman Team mode)
    zero_sum: bool = True


class MultiAgentEnv(NamedTuple):
    spec: EnvSpec
    reset: Callable      # rng -> (state, obs)
    step: Callable       # (state, actions, rng) -> (state, obs, rewards, done, info)


ENVS: Registry = Registry("env")


def make_env(name: str, **kw) -> MultiAgentEnv:
    return ENVS.get(name)(**kw)
