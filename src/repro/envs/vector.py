"""VectorEnv: batched env slots behind one interface (the collector plane).

A `VectorEnv` owns N independent instances ("slots") of one
`MultiAgentEnv` and exposes batched `reset`/`step`/`autoreset` over slot
arrays — the env-stepping layer the Collector drives, extracted from the
rollout drivers so env vectorization, acting, and segment assembly are
separate seams.

Two adapters implement the interface:

* **JaxVectorEnv** — pure-JAX envs: `vmap` over slots, usable both
  *inside* an outer jit/scan (the Anakin-style jitted rollout —
  construct with ``jit=False`` so the ops inline into the caller's
  trace) and as host calls (``jit=True`` compiles each batched op once
  and the driver loops in Python, the served-rollout layout).
* **HostVectorEnv** — the host-loop seam for future envs whose
  reset/step are plain Python (an external simulator, a C++ binding):
  slots are stepped one by one on the host and stacked with NumPy.
  Same interface, `jittable=False`, so a Collector can refuse to build
  a jitted scan over it while the served (host-loop) path works
  unchanged.

RNG contract (bit-compatibility with the pre-collector rollouts): a
single key goes in, the adapter splits it into one key per slot —
`reset(rng)` == ``vmap(env.reset)(split(rng, N))`` and `step(...,rng)`
== ``vmap(env.step)(states, actions, split(rng, N))`` exactly.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.base import EnvSpec, MultiAgentEnv


class VectorEnv:
    """Interface + shared combinators. Subclasses provide `reset`,
    `step` and set `jittable`."""

    jittable: bool = False

    def __init__(self, env: MultiAgentEnv, num_envs: int):
        assert num_envs >= 1, "a VectorEnv needs at least one slot"
        self.env = env
        self.num_envs = num_envs

    @property
    def spec(self) -> EnvSpec:
        return self.env.spec

    # -- batched protocol ---------------------------------------------------
    def reset(self, rng) -> Tuple[Any, Any]:
        """rng -> (states, obs) with a leading (num_envs,) slot axis."""
        raise NotImplementedError

    def step(self, states, actions, rng):
        """(states, actions (E, A), rng) -> (states, obs, rewards, done,
        info), everything carrying the slot axis."""
        raise NotImplementedError

    def autoreset(self, done, reset_states, reset_obs, states, obs):
        """Select per slot: the fresh (reset) state where `done`, the
        stepped state elsewhere. Pure where-select — works under jit."""
        sel = lambda a, b: jnp.where(
            done.reshape((-1,) + (1,) * (a.ndim - 1)), a, b)
        return (jax.tree.map(sel, reset_states, states),
                jax.tree.map(sel, reset_obs, obs))

    def step_autoreset(self, states, actions, step_rng, reset_rng):
        """One collector step: step every slot, auto-reset finished ones.
        Returns (states, obs, rewards, done, outcome) — `outcome` is the
        env's per-slot episode outcome (zeros when the env reports
        none), pulled out of `info` so host-loop adapters need not stack
        arbitrary info dicts."""
        states2, obs2, rewards, done, info = self.step(states, actions,
                                                       step_rng)
        states3, obs3 = self.reset(reset_rng)
        states_n, obs_n = self.autoreset(done, states3, obs3, states2, obs2)
        outcome = info.get("outcome",
                           jnp.zeros((self.num_envs,), jnp.int32))
        return states_n, obs_n, rewards, done, outcome


class JaxVectorEnv(VectorEnv):
    """Slot-vectorized pure-JAX env: `vmap` over the slot axis.

    ``jit=False`` (default) leaves the batched ops untraced so they
    inline into an outer `lax.scan` (the jitted rollout); ``jit=True``
    compiles `reset`/`step`/`step_autoreset` once each for host-loop
    drivers (the served rollout), replacing the per-callsite jits the
    old `build_served_rollout` carried."""

    jittable = True

    def __init__(self, env: MultiAgentEnv, num_envs: int, *, jit: bool = False):
        super().__init__(env, num_envs)
        v_reset = jax.vmap(env.reset)
        v_step = jax.vmap(env.step, in_axes=(0, 0, 0))
        E = num_envs

        def reset(rng):
            return v_reset(jax.random.split(rng, E))

        def step(states, actions, rng):
            return v_step(states, actions, jax.random.split(rng, E))

        self._reset, self._step = reset, step
        if jit:
            self._reset = jax.jit(reset)
            self._step = jax.jit(step)
            self._step_autoreset = jax.jit(
                lambda s, a, ks, kr: VectorEnv.step_autoreset(self, s, a,
                                                              ks, kr))
        else:
            self._step_autoreset = None

    def reset(self, rng):
        return self._reset(rng)

    def step(self, states, actions, rng):
        return self._step(states, actions, rng)

    def step_autoreset(self, states, actions, step_rng, reset_rng):
        if self._step_autoreset is not None:
            return self._step_autoreset(states, actions, step_rng, reset_rng)
        return super().step_autoreset(states, actions, step_rng, reset_rng)


class HostVectorEnv(VectorEnv):
    """Host-loop adapter: slots stepped one at a time in Python, results
    stacked with NumPy. For envs that cannot trace (external simulators);
    pure-JAX envs also run (each slot eagerly), which is what the tests
    drive it with. States are a per-slot list — opaque to callers, as the
    interface requires."""

    jittable = False

    def reset(self, rng):
        keys = jax.random.split(rng, self.num_envs)
        pairs = [self.env.reset(k) for k in keys]
        states = [s for s, _ in pairs]
        obs = np.stack([np.asarray(o) for _, o in pairs])
        return states, obs

    def step(self, states, actions, rng):
        keys = jax.random.split(rng, self.num_envs)
        outs = [self.env.step(states[i], jnp.asarray(actions[i]), keys[i])
                for i in range(self.num_envs)]
        new_states = [o[0] for o in outs]
        obs = np.stack([np.asarray(o[1]) for o in outs])
        rewards = np.stack([np.asarray(o[2]) for o in outs])
        done = np.array([bool(o[3]) for o in outs])
        infos = [o[4] for o in outs]
        info = {}
        if infos and "outcome" in infos[0]:
            info["outcome"] = np.array([int(i["outcome"]) for i in infos],
                                       np.int32)
        return new_states, obs, rewards, done, info

    def autoreset(self, done, reset_states, reset_obs, states, obs):
        done = np.asarray(done)
        states_n = [reset_states[i] if done[i] else states[i]
                    for i in range(self.num_envs)]
        obs_n = np.where(done.reshape((-1,) + (1,) * (np.asarray(obs).ndim - 1)),
                         np.asarray(reset_obs), np.asarray(obs))
        return states_n, obs_n

    def step_autoreset(self, states, actions, step_rng, reset_rng):
        states2, obs2, rewards, done, info = self.step(states, actions,
                                                       step_rng)
        states3, obs3 = self.reset(reset_rng)
        states_n, obs_n = self.autoreset(done, states3, obs3, states2, obs2)
        outcome = info.get("outcome", np.zeros((self.num_envs,), np.int32))
        return states_n, obs_n, rewards, done, outcome


def make_vector_env(env: MultiAgentEnv, num_envs: int, *,
                    host: bool = False, jit: bool = False) -> VectorEnv:
    """Adapter selection: every in-repo env is pure JAX, so the default
    is `JaxVectorEnv`; `host=True` opts into the host-loop seam."""
    if host:
        return HostVectorEnv(env, num_envs)
    return JaxVectorEnv(env, num_envs, jit=jit)
