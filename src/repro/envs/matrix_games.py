"""Matrix games: Rock-Paper-Scissors and friends (§3.1's motivating example).

`rps` is iterated RPS with the opponent's last move in the observation —
rich enough that independent RL visibly circulates (pure-rock -> pure-paper
-> pure-scissors) while FSP converges to the uniform NE; `examples/rps_nash.py`
reproduces that claim.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import ENVS, EnvSpec, MultiAgentEnv

# payoff for (my_action, opp_action): rows rock/paper/scissors
RPS_PAYOFF = jnp.array([
    [0.0, -1.0, 1.0],
    [1.0, 0.0, -1.0],
    [-1.0, 1.0, 0.0],
])

# biased variant: scissors-wins pay double (NE no longer uniform)
RPS_BIASED = jnp.array([
    [0.0, -1.0, 1.0],
    [1.0, 0.0, -2.0],
    [-1.0, 2.0, 0.0],
])


def _make_rps(payoff, name: str, episode_len: int = 8) -> MultiAgentEnv:
    spec = EnvSpec(name=name, num_agents=2, obs_len=2, num_actions=3,
                   max_steps=episode_len, obs_vocab=8)

    def reset(rng):
        state = {"t": jnp.int32(0), "last": jnp.full((2,), 3, jnp.int32)}
        obs = _obs(state)
        return state, obs

    def _obs(state):
        # per agent: [opponent_last_action_token, step_parity]
        opp_last = state["last"][::-1]
        parity = jnp.broadcast_to(state["t"] % 2 + 4, (2,))
        return jnp.stack([opp_last, parity], axis=1)

    def step(state, actions, rng):
        a0, a1 = actions[0], actions[1]
        r0 = payoff[a0, a1]
        state = {"t": state["t"] + 1, "last": actions}
        done = state["t"] >= episode_len
        rewards = jnp.stack([r0, -r0])
        return state, _obs(state), rewards, done, {}

    return MultiAgentEnv(spec, reset, step)


ENVS.register("rps", lambda episode_len=8: _make_rps(RPS_PAYOFF, "rps", episode_len))
ENVS.register("rps_biased", lambda episode_len=8: _make_rps(RPS_BIASED, "rps_biased", episode_len))
