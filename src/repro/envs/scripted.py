"""Scripted (rule-based) opponents — the evaluation baselines.

The paper evaluates against ViZDoom builtin bots (Tables 1-2) and
Pommerman's SimpleAgent (Fig. 4). These are the analogues, operating on the
same token observations the learned policies see.
"""
from __future__ import annotations

import numpy as np

VIEW = 5
C = VIEW // 2  # center index


def _grid(obs_row):
    return np.asarray(obs_row[:VIEW * VIEW]).reshape(VIEW, VIEW)


def duel_bot(obs, rng: np.random.Generator):
    """Turn toward the nearest visible enemy and fire when aligned.
    obs: (k, L) token obs -> (k,) actions {0 idle,1 fwd,2 turn-L,3 turn-R,4 fire}."""
    acts = []
    for row in np.asarray(obs):
        g = _grid(row)
        facing = int(row[VIEW * VIEW] - 8)        # 0 N,1 E,2 S,3 W
        enemies = np.argwhere(g == 6)
        if len(enemies) == 0:
            acts.append(int(rng.integers(1, 4)))  # wander
            continue
        er, ec = enemies[np.abs(enemies - C).sum(1).argmin()]
        dr, dc = er - C, ec - C
        # desired facing
        if abs(dr) >= abs(dc):
            want = 0 if dr < 0 else 2
        else:
            want = 3 if dc < 0 else 1
        if want == facing:
            aligned = (dr == 0) or (dc == 0)
            acts.append(4 if aligned else 1)
        else:
            diff = (want - facing) % 4
            acts.append(3 if diff <= 2 else 2)    # turn toward
    return np.array(acts, np.int32)


def pommerman_simple_bot(obs, rng: np.random.Generator):
    """SimpleAgent-lite: bomb when an enemy or wood is adjacent, flee bombs,
    otherwise random legal-looking move."""
    acts = []
    for row in np.asarray(obs):
        g = _grid(row)
        adj = [g[C - 1, C], g[C + 1, C], g[C, C - 1], g[C, C + 1]]
        ammo = int(row[-1]) - 8
        # flee if standing next to a bomb
        bomb_dirs = [i for i, v in enumerate(adj) if v == 3]
        if bomb_dirs or g[C, C] == 3:
            frees = [i for i, v in enumerate(adj) if v == 0]
            acts.append(1 + rng.choice(frees) if frees else 0)
            continue
        if ammo > 0 and any(v in (2, 6) for v in adj):
            acts.append(5)                         # bomb wood/enemy
            continue
        frees = [i for i, v in enumerate(adj) if v == 0]
        acts.append(1 + int(rng.choice(frees)) if frees else 0)
    return np.array(acts, np.int32)


SCRIPTED = {"duel": duel_bot, "pommerman_lite": pommerman_simple_bot}


def random_bot(num_actions):
    def bot(obs, rng):
        return rng.integers(0, num_actions, size=(len(obs),)).astype(np.int32)
    return bot
