"""Duel: ViZDoom CIG-track-1-like FFA arena (paper §4.2 analogue).

8-player FFA reduced to 4 agents on a 9x9 grid with pillars. Agents face a
direction, move forward, turn, or fire; a shot travels along the facing line
(range 5, blocked by pillars) and frags the first agent hit, who respawns at
the cell farthest from the shooter. Score = FRAG (kills; no rocket splash =>
no suicides). Episode ends after `max_steps`; the info carries per-agent
FRAGs so evaluation ranks players exactly like the CIG protocol.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import ENVS, EnvSpec, MultiAgentEnv

N = 9
RANGE = 5
MAX_STEPS = 64
FACINGS = jnp.array([[-1, 0], [0, 1], [1, 0], [0, -1]])  # N,E,S,W
SPAWNS = jnp.array([[0, 0], [0, N - 1], [N - 1, 0], [N - 1, N - 1]])

PILLARS = jnp.zeros((N, N), bool).at[3, 3].set(True).at[3, 5].set(True) \
    .at[5, 3].set(True).at[5, 5].set(True).at[4, 4].set(True)

# actions: 0 idle, 1 forward, 2 turn-left, 3 turn-right, 4 fire
VIEW = 5


def make_duel(frag_reward: float = 1.0, hit_penalty: float = 0.5) -> MultiAgentEnv:
    spec = EnvSpec(name="duel", num_agents=4, obs_len=VIEW * VIEW + 2,
                   num_actions=5, max_steps=MAX_STEPS, obs_vocab=16,
                   zero_sum=False)

    def reset(rng):
        state = {"pos": SPAWNS, "facing": jnp.array([2, 2, 0, 0]),
                 "frags": jnp.zeros((4,), jnp.int32), "t": jnp.int32(0)}
        return state, _obs(state)

    def _obs(state):
        half = VIEW // 2
        rows = jnp.arange(VIEW) - half
        obs = []
        for i in range(4):
            r0, c0 = state["pos"][i, 0], state["pos"][i, 1]
            rr = r0 + rows[:, None]
            cc = c0 + rows[None, :]
            inb = (rr >= 0) & (rr < N) & (cc >= 0) & (cc < N)
            rrc, ccc = jnp.clip(rr, 0, N - 1), jnp.clip(cc, 0, N - 1)
            cell = jnp.where(PILLARS[rrc, ccc], 1, 0)
            for j in range(4):
                here = (rr == state["pos"][j, 0]) & (cc == state["pos"][j, 1])
                cell = jnp.where(here, 4 if j == i else 6, cell)
            cell = jnp.where(inb, cell, 7)
            obs.append(jnp.concatenate([
                cell.reshape(-1),
                (8 + state["facing"][i])[None],
                (12 + jnp.clip(state["frags"][i], 0, 3))[None],
            ]))
        return jnp.stack(obs)

    def step(state, actions, rng):
        pos, facing = state["pos"], state["facing"]
        # turns
        facing = jnp.where(actions == 2, (facing - 1) % 4, facing)
        facing = jnp.where(actions == 3, (facing + 1) % 4, facing)
        # forward moves (lower index wins conflicts)
        new_pos = pos
        for i in range(4):
            cand = jnp.clip(pos[i] + FACINGS[facing[i]], 0, N - 1)
            free = ~PILLARS[cand[0], cand[1]]
            occ = jnp.bool_(False)
            for j in range(4):
                occ = occ | (jnp.all(pos[j] == cand) & (j != i))
            for j in range(i):
                occ = occ | jnp.all(new_pos[j] == cand)
            ok = (actions[i] == 1) & free & ~occ
            new_pos = new_pos.at[i].set(jnp.where(ok, cand, pos[i]))
        pos = new_pos

        # fire: first agent on facing ray within RANGE, pillars block
        rewards = jnp.zeros((4,))
        frags = state["frags"]
        hit_by = jnp.full((4,), -1, jnp.int32)   # victim -> shooter
        for i in range(4):
            d = FACINGS[facing[i]]
            blocked = jnp.bool_(False)
            already_hit = jnp.bool_(False)
            for k in range(1, RANGE + 1):
                rr = pos[i, 0] + d[0] * k
                cc = pos[i, 1] + d[1] * k
                inb = (rr >= 0) & (rr < N) & (cc >= 0) & (cc < N)
                rrc, ccc = jnp.clip(rr, 0, N - 1), jnp.clip(cc, 0, N - 1)
                blocked = blocked | (inb & PILLARS[rrc, ccc])
                for j in range(4):
                    if j == i:
                        continue
                    here = inb & jnp.all(pos[j] == jnp.stack([rrc, ccc]))
                    hit = (actions[i] == 4) & here & ~blocked & ~already_hit
                    hit_by = hit_by.at[j].set(jnp.where(hit & (hit_by[j] < 0), i, hit_by[j]))
                    already_hit = already_hit | hit
                    blocked = blocked | here  # bodies block the ray

        for j in range(4):
            was_hit = hit_by[j] >= 0
            shooter = jnp.clip(hit_by[j], 0, 3)
            frags = frags.at[shooter].add(was_hit.astype(jnp.int32))
            rewards = rewards.at[shooter].add(jnp.where(was_hit, frag_reward, 0.0))
            rewards = rewards.at[j].add(jnp.where(was_hit, -hit_penalty, 0.0))
            # respawn victim at the spawn farthest from the shooter
            dists = jnp.sum(jnp.abs(SPAWNS - pos[shooter][None]), axis=1)
            pos = pos.at[j].set(jnp.where(was_hit, SPAWNS[jnp.argmax(dists)], pos[j]))

        t = state["t"] + 1
        done = t >= MAX_STEPS
        new_state = {"pos": pos, "facing": facing, "frags": frags, "t": t}
        best = jnp.argmax(frags)
        outcome = jnp.where(done & (best == 0), 1, jnp.where(done, -1, 0))
        return new_state, _obs(new_state), rewards, done, {"frags": frags,
                                                           "outcome": outcome}

    return MultiAgentEnv(spec, reset, step)


ENVS.register("duel", make_duel)
