"""Pommerman-lite: 2v2 Team-mode bomber gridworld (paper §4.3 analogue).

Faithful to the benchmark's structure at reduced scale: 9x9 board with rigid
walls on the even lattice + random wooden walls, 4 agents in two diagonal
teams, bombs with timers/blast-cross/chain detonation, fogged 5x5 local
views (Team mode partial observability), team-zero-sum terminal reward,
800->100 step tie limit. Fully jit/vmap-able: fixed-size bomb slots, static
unrolls over the 4 agents.

Cell codes: 0 empty, 1 rigid, 2 wood. Obs tokens: cell codes 0-2, 3 bomb,
4 self, 5 teammate, 6 enemy, 7 out-of-bounds, 8+ammo (ammo token last).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.envs.base import ENVS, EnvSpec, MultiAgentEnv

N = 9                 # board side
MAX_BOMBS = 8
BOMB_TIMER = 4
BLAST = 2             # blast radius (cross)
VIEW = 5              # local view side
MAX_STEPS = 100

# teams: diagonal as in Pommerman (0,2) vs (1,3) -> we reorder slots so
# consecutive slots are teammates: slots (0,1)=team A corners TL/BR,
# slots (2,3)=team B corners TR/BL.
SPAWNS = jnp.array([[0, 0], [N - 1, N - 1], [0, N - 1], [N - 1, 0]])
TEAM = (0, 0, 1, 1)   # python constants: used for STATIC obs codes under jit
MOVES = jnp.array([[0, 0], [-1, 0], [1, 0], [0, -1], [0, 1]])  # idle,U,D,L,R


def _spawn_safe_mask():
    """Cells that must stay clear so agents can always move off spawn."""
    m = jnp.zeros((N, N), bool)
    for r, c in [(0, 0), (N - 1, N - 1), (0, N - 1), (N - 1, 0)]:
        for dr, dc in [(0, 0), (0, 1), (0, -1), (1, 0), (-1, 0)]:
            rr, cc = r + dr, c + dc
            if 0 <= rr < N and 0 <= cc < N:
                m = m.at[rr, cc].set(True)
    return m


SAFE = _spawn_safe_mask()
RIGID = (jnp.arange(N)[:, None] % 2 == 1) & (jnp.arange(N)[None, :] % 2 == 1)


def make_pommerman_lite(wood_prob: float = 0.35, shaping: float = 0.05) -> MultiAgentEnv:
    spec = EnvSpec(name="pommerman_lite", num_agents=4, obs_len=VIEW * VIEW + 1,
                   num_actions=6, max_steps=MAX_STEPS, obs_vocab=16, team_size=2)

    def reset(rng):
        wood = (jax.random.uniform(rng, (N, N)) < wood_prob) & ~RIGID & ~SAFE
        board = jnp.where(RIGID, 1, jnp.where(wood, 2, 0)).astype(jnp.int8)
        state = {
            "board": board,
            "pos": SPAWNS,
            "alive": jnp.ones((4,), bool),
            "ammo": jnp.ones((4,), jnp.int32),
            "bomb_pos": jnp.zeros((MAX_BOMBS, 2), jnp.int32),
            "bomb_timer": jnp.full((MAX_BOMBS,), -1, jnp.int32),
            "bomb_owner": jnp.zeros((MAX_BOMBS,), jnp.int32),
            "t": jnp.int32(0),
        }
        return state, _obs(state)

    def _cell_occupied(state, rc):
        on_agent = jnp.any(jnp.all(state["pos"] == rc[None], axis=1) & state["alive"])
        on_bomb = jnp.any(jnp.all(state["bomb_pos"] == rc[None], axis=1)
                          & (state["bomb_timer"] >= 0))
        return on_agent | on_bomb

    def _obs(state):
        board = state["board"]
        bomb_map = jnp.zeros((N, N), bool)
        for s in range(MAX_BOMBS):
            live = state["bomb_timer"][s] >= 0
            bomb_map = bomb_map.at[state["bomb_pos"][s, 0], state["bomb_pos"][s, 1]].max(live)
        obs = []
        half = VIEW // 2
        rows = jnp.arange(VIEW) - half
        for i in range(4):
            r0, c0 = state["pos"][i, 0], state["pos"][i, 1]
            rr = r0 + rows[:, None]
            cc = c0 + rows[None, :]
            inb = (rr >= 0) & (rr < N) & (cc >= 0) & (cc < N)
            rrc = jnp.clip(rr, 0, N - 1)
            ccc = jnp.clip(cc, 0, N - 1)
            cell = board[rrc, ccc].astype(jnp.int32)
            cell = jnp.where(bomb_map[rrc, ccc], 3, cell)
            for j in range(4):
                here = (rr == state["pos"][j, 0]) & (cc == state["pos"][j, 1]) & state["alive"][j]
                code = 4 if j == i else (5 if TEAM[j] == TEAM[i] else 6)
                cell = jnp.where(here, code, cell)
            cell = jnp.where(inb, cell, 7)
            ammo_tok = 8 + jnp.clip(state["ammo"][i], 0, 3)
            obs.append(jnp.concatenate([cell.reshape(-1), ammo_tok[None]]))
        return jnp.stack(obs)

    def _blast_mask(state, timers):
        """Cells covered by bombs whose timer hits 0 this step (with one round
        of chain detonation)."""
        board = state["board"]

        def cross(rc):
            m = jnp.zeros((N, N), bool)
            r, c = rc[0], rc[1]
            m = m.at[r, c].set(True)
            for dr, dc in ((1, 0), (-1, 0), (0, 1), (0, -1)):
                blocked = jnp.bool_(False)
                for k in range(1, BLAST + 1):
                    rr, cc = r + dr * k, c + dc * k
                    inb = (rr >= 0) & (rr < N) & (cc >= 0) & (cc < N)
                    rrc, ccc = jnp.clip(rr, 0, N - 1), jnp.clip(cc, 0, N - 1)
                    hit_rigid = inb & (board[rrc, ccc] == 1)
                    place = inb & ~blocked & ~hit_rigid
                    m = m.at[rrc, ccc].max(place)
                    # wood stops further propagation (after being hit)
                    blocked = blocked | hit_rigid | (inb & (board[rrc, ccc] == 2))
            return m

        exploding = timers == 0
        blast = jnp.zeros((N, N), bool)
        for s in range(MAX_BOMBS):
            blast = blast | (cross(state["bomb_pos"][s]) & exploding[s])
        # chain: bombs standing in the blast detonate too
        chained = jnp.zeros((MAX_BOMBS,), bool)
        for s in range(MAX_BOMBS):
            on = blast[state["bomb_pos"][s, 0], state["bomb_pos"][s, 1]]
            chained = chained.at[s].set(on & (timers[s] > 0))
        for s in range(MAX_BOMBS):
            blast = blast | (cross(state["bomb_pos"][s]) & chained[s])
        exploded = exploding | chained
        return blast, exploded

    def step(state, actions, rng):
        board = state["board"]
        pos, alive, ammo = state["pos"], state["alive"], state["ammo"]

        # -- movement (lower slot index wins conflicts) ------------------------
        new_pos = pos
        for i in range(4):
            delta = MOVES[jnp.clip(actions[i], 0, 4)]
            cand = jnp.clip(pos[i] + delta, 0, N - 1)
            free = (board[cand[0], cand[1]] == 0) & ~_cell_occupied(state, cand)
            taken = jnp.bool_(False)
            for j in range(i):
                taken = taken | jnp.all(new_pos[j] == cand)
            ok = alive[i] & (actions[i] >= 1) & (actions[i] <= 4) & free & ~taken
            new_pos = new_pos.at[i].set(jnp.where(ok, cand, pos[i]))
        pos = new_pos

        # -- bomb placement ------------------------------------------------------
        bomb_pos, bomb_timer, bomb_owner = (state["bomb_pos"], state["bomb_timer"],
                                            state["bomb_owner"])
        for i in range(4):
            wants = alive[i] & (actions[i] == 5) & (ammo[i] > 0)
            occupied = jnp.any(jnp.all(bomb_pos == state["pos"][i][None], axis=1)
                               & (bomb_timer >= 0))
            free_slots = bomb_timer < 0
            slot = jnp.argmax(free_slots)
            can = wants & ~occupied & jnp.any(free_slots)
            bomb_pos = bomb_pos.at[slot].set(jnp.where(can, state["pos"][i], bomb_pos[slot]))
            bomb_timer = bomb_timer.at[slot].set(jnp.where(can, BOMB_TIMER, bomb_timer[slot]))
            bomb_owner = bomb_owner.at[slot].set(jnp.where(can, i, bomb_owner[slot]))
            ammo = ammo.at[i].add(-can.astype(jnp.int32))

        # -- timers & explosions ---------------------------------------------------
        bomb_timer = jnp.where(bomb_timer >= 0, bomb_timer - 1, bomb_timer)
        blast, exploded = _blast_mask({**state, "bomb_pos": bomb_pos}, bomb_timer)
        # return ammo to owners, clear exploded bombs
        for s in range(MAX_BOMBS):
            ammo = ammo.at[bomb_owner[s]].add(exploded[s].astype(jnp.int32))
        bomb_timer = jnp.where(exploded, -1, bomb_timer)
        # destroy wood
        wood_destroyed = blast & (board == 2)
        board = jnp.where(wood_destroyed, 0, board).astype(jnp.int8)
        # kill agents in blast
        killed = jnp.array([blast[pos[i, 0], pos[i, 1]] for i in range(4)]) & alive
        alive = alive & ~killed

        t = state["t"] + 1
        team_alive = jnp.array([jnp.any(alive[:2]), jnp.any(alive[2:])])
        done = (~team_alive[0]) | (~team_alive[1]) | (t >= MAX_STEPS)
        win_a = team_alive[0] & ~team_alive[1]
        win_b = team_alive[1] & ~team_alive[0]
        terminal = (jnp.where(win_a, 1.0, 0.0) - jnp.where(win_b, 1.0, 0.0))
        team_sign = jnp.array([1.0, 1.0, -1.0, -1.0])
        rewards = jnp.where(done, terminal * team_sign, 0.0)
        # shaping: wood destroyed credited to bomb owners (via exploded bombs)
        if shaping:
            n_wood = jnp.sum(wood_destroyed).astype(jnp.float32)
            share = jnp.zeros((4,))
            for s in range(MAX_BOMBS):
                share = share.at[bomb_owner[s]].add(exploded[s].astype(jnp.float32))
            share = share / jnp.maximum(jnp.sum(share), 1.0)
            rewards = rewards + shaping * n_wood * share

        new_state = {"board": board, "pos": pos, "alive": alive, "ammo": ammo,
                     "bomb_pos": bomb_pos, "bomb_timer": bomb_timer,
                     "bomb_owner": bomb_owner, "t": t}
        outcome = jnp.where(win_a, 1, jnp.where(win_b, -1, 0))
        return new_state, _obs(new_state), rewards, done, {"outcome": outcome}

    return MultiAgentEnv(spec, reset, step)


ENVS.register("pommerman_lite", make_pommerman_lite)
