"""V-trace (IMPALA) off-policy corrected targets [Espeholt et al. 2018],
the paper's second supported proxy-RL algorithm (tleague.learners.VtraceLearner).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def vtrace(behavior_logp, target_logp, rewards, values, discounts, bootstrap,
           *, lam=1.0, clip_rho=1.0, clip_c=1.0):
    """All per-step arrays (B, T); bootstrap (B,).

    Returns (vs, pg_advantages):
      rho_t = min(clip_rho, pi/mu);  c_t = lam * min(clip_c, pi/mu)
      delta_t = rho_t (r_t + gamma_t v_{t+1} - v_t)
      vs_t = v_t + delta_t + gamma_t c_t (vs_{t+1} - v_{t+1})
      adv_t = rho_t (r_t + gamma_t vs_{t+1} - v_t)

    The correction sum acc_t = vs_t - v_t satisfies the reverse discounted
    recursion acc_t = delta_t + (gamma_t c_t) acc_{t+1}, so it runs through
    the dispatch layer's fused (B, T) scan like GAE does.
    """
    rho = jnp.exp(target_logp - behavior_logp)
    rho_c = jnp.minimum(clip_rho, rho)
    c = lam * jnp.minimum(clip_c, rho)
    v_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rho_c * (rewards + discounts * v_tp1 - values)
    vs = values + dispatch.reverse_scan(deltas, discounts * c)
    vs_tp1 = jnp.concatenate([vs[:, 1:], bootstrap[:, None]], axis=1)
    pg_adv = rho_c * (rewards + discounts * vs_tp1 - values)
    return jax.lax.stop_gradient(vs), jax.lax.stop_gradient(pg_adv)
