"""Return/advantage estimators: GAE, lambda-returns — the "algorithm-specific
terms" the paper's DataServer computes before learning (§3.2 Learner).

Every estimator here is one instance of the reverse discounted recursion

    y_t = delta_t + decay_t * y_{t+1}

and routes through `repro.kernels.dispatch.reverse_scan`: a fused Pallas
kernel over the whole (B, T) minibatch on accelerators (batch-tiled in
VMEM), the pure lax.scan-over-T reference on CPU. Both paths produce
identical targets (tests/test_kernels.py asserts parity).

Conventions: arrays are (B, T); `discounts` is gamma * (1 - done_t) — zero at
episode boundaries; `bootstrap` is V(s_T) (B,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def gae(rewards, values, discounts, bootstrap, lam=0.95):
    """Generalized Advantage Estimation. Returns (advantages, value_targets).

    adv_t = delta_t + (gamma_t * lam) adv_{t+1},
    delta_t = r_t + gamma_t V_{t+1} - V_t.
    """
    v_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + discounts * v_tp1 - values
    advantages = dispatch.reverse_scan(deltas, discounts * lam)
    return advantages, advantages + values


def lambda_return(rewards, values, discounts, bootstrap, lam=0.95):
    """TD(lambda) targets: G_t = r_t + gamma [ (1-lam) V_{t+1} + lam G_{t+1} ].

    Same recursion with delta_t = r_t + gamma_t (1-lam) V_{t+1},
    decay_t = gamma_t * lam, seeded at G_T = bootstrap.
    """
    v_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + discounts * (1.0 - lam) * v_tp1
    return dispatch.reverse_scan(deltas, discounts * lam, bootstrap)


def discounted_return(rewards, discounts, bootstrap):
    """Plain discounted Monte-Carlo return, seeded at the bootstrap value."""
    return dispatch.reverse_scan(rewards, discounts, bootstrap)
