"""Return/advantage estimators: GAE, lambda-returns — the "algorithm-specific
terms" the paper's DataServer computes before learning (§3.2 Learner).

Pure-jnp reverse scans over time; the Pallas `vtrace_scan` kernel implements
the same recursions tiled for VMEM and is tested against these.

Conventions: arrays are (B, T); `discounts` is gamma * (1 - done_t) — zero at
episode boundaries; `bootstrap` is V(s_T) (B,).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _reverse_scan(f, init, xs_tmajor):
    carry, ys = jax.lax.scan(f, init, xs_tmajor, reverse=True)
    return carry, ys


def gae(rewards, values, discounts, bootstrap, lam=0.95):
    """Generalized Advantage Estimation. Returns (advantages, value_targets)."""
    v_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + discounts * v_tp1 - values

    def body(adv, xs):
        delta_t, disc_t = xs
        adv = delta_t + disc_t * lam * adv
        return adv, adv

    xs = (deltas.T, discounts.T)
    _, adv_t = _reverse_scan(body, jnp.zeros_like(bootstrap), xs)
    advantages = adv_t.T
    return advantages, advantages + values


def lambda_return(rewards, values, discounts, bootstrap, lam=0.95):
    """TD(lambda) targets: G_t = r_t + gamma [ (1-lam) V_{t+1} + lam G_{t+1} ]."""
    v_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)

    def body(g, xs):
        r_t, v_t, d_t = xs
        g = r_t + d_t * ((1.0 - lam) * v_t + lam * g)
        return g, g

    xs = (rewards.T, v_tp1.T, discounts.T)
    _, g_t = _reverse_scan(body, bootstrap, xs)
    return g_t.T


def discounted_return(rewards, discounts, bootstrap):
    def body(g, xs):
        r_t, d_t = xs
        g = r_t + d_t * g
        return g, g

    _, g_t = _reverse_scan(body, bootstrap, (rewards.T, discounts.T))
    return g_t.T
