"""V-trace actor-critic loss (IMPALA learner; tleague.learners.VtraceLearner
equivalent, loss structure borrowed from deepmind/trfl as the paper did)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.rl.distributions import categorical_entropy, categorical_logp
from repro.rl.vtrace import vtrace


@dataclass(frozen=True)
class VTraceConfig:
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gamma: float = 0.99
    lam: float = 1.0
    clip_rho: float = 1.0
    clip_c: float = 1.0


def vtrace_loss(logits, values, traj, hp: VTraceConfig):
    actions = traj["actions"]
    mask = traj.get("mask")
    if mask is None:
        mask = jnp.ones_like(traj["rewards"])
    msum = jnp.maximum(jnp.sum(mask), 1.0)

    logp = categorical_logp(logits, actions)
    vs, pg_adv = vtrace(traj["behavior_logp"], jax.lax.stop_gradient(logp),
                        traj["rewards"], values, traj["discounts"],
                        traj["bootstrap_value"], lam=hp.lam,
                        clip_rho=hp.clip_rho, clip_c=hp.clip_c)
    pg_loss = -jnp.sum(logp * pg_adv * mask) / msum
    v_loss = 0.5 * jnp.sum(jnp.square(values - vs) * mask) / msum
    ent = jnp.sum(categorical_entropy(logits) * mask) / msum
    loss = pg_loss + hp.value_coef * v_loss - hp.entropy_coef * ent
    return loss, {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": ent}
