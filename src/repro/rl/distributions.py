"""Categorical policy distribution helpers (logits in fp32)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def categorical_logp(logits, actions):
    """logits: (..., A) fp32; actions: (...) int32 -> (...) fp32 log pi(a)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    la = jnp.take_along_axis(logits, actions[..., None], axis=-1)[..., 0]
    return la - logz


def categorical_entropy(logits):
    logp = jax.nn.log_softmax(logits, axis=-1)
    p = jnp.exp(logp)
    return -jnp.sum(p * logp, axis=-1)


def categorical_kl(logits_p, logits_q):
    """KL(p || q) — the teacher-KL penalty hook (paper §InfServer)."""
    lp = jax.nn.log_softmax(logits_p, axis=-1)
    lq = jax.nn.log_softmax(logits_q, axis=-1)
    return jnp.sum(jnp.exp(lp) * (lp - lq), axis=-1)


def categorical_sample(rng, logits, valid_actions: int | None = None):
    if valid_actions is not None:
        mask = jnp.arange(logits.shape[-1]) < valid_actions
        logits = jnp.where(mask, logits, -jnp.inf)
    return jax.random.categorical(rng, logits, axis=-1)
