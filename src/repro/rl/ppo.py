"""PPO-clip loss (the paper's primary proxy-RL; borrowed structure from
openai/baselines' ppo2 as the paper did)."""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.rl.distributions import categorical_entropy, categorical_kl, categorical_logp
from repro.rl.returns import gae


@dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    value_coef: float = 0.5
    entropy_coef: float = 0.01
    gamma: float = 0.99
    lam: float = 0.95
    clip_value: bool = True
    normalize_adv: bool = True
    teacher_kl_coef: float = 0.0   # KL(pi || teacher) — paper §InfServer hook


def ppo_loss(logits, values, traj, hp: PPOConfig, teacher_logits=None):
    """logits: (B,T,A) fp32; values: (B,T) fp32.

    traj fields (B,T): actions, behavior_logp, behavior_values, rewards,
    discounts; bootstrap_value (B,); mask (B,T) valid steps.
    Returns (loss, metrics).
    """
    actions = traj["actions"]
    mask = traj.get("mask")
    if mask is None:
        mask = jnp.ones_like(traj["rewards"])
    msum = jnp.maximum(jnp.sum(mask), 1.0)

    logp = categorical_logp(logits, actions)
    ratio = jnp.exp(logp - traj["behavior_logp"])

    adv, v_targ = gae(traj["rewards"], traj["behavior_values"], traj["discounts"],
                      traj["bootstrap_value"], lam=hp.lam)
    adv = jax.lax.stop_gradient(adv)
    v_targ = jax.lax.stop_gradient(v_targ)
    if hp.normalize_adv:
        mean = jnp.sum(adv * mask) / msum
        var = jnp.sum(jnp.square(adv - mean) * mask) / msum
        adv = (adv - mean) * jax.lax.rsqrt(var + 1e-8)

    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - hp.clip_eps, 1.0 + hp.clip_eps) * adv
    pg_loss = -jnp.sum(jnp.minimum(unclipped, clipped) * mask) / msum

    v_err = jnp.square(values - v_targ)
    if hp.clip_value:
        v_clip = traj["behavior_values"] + jnp.clip(
            values - traj["behavior_values"], -hp.clip_eps, hp.clip_eps)
        v_err = jnp.maximum(v_err, jnp.square(v_clip - v_targ))
    v_loss = 0.5 * jnp.sum(v_err * mask) / msum

    ent = jnp.sum(categorical_entropy(logits) * mask) / msum
    loss = pg_loss + hp.value_coef * v_loss - hp.entropy_coef * ent

    metrics = {"pg_loss": pg_loss, "v_loss": v_loss, "entropy": ent,
               "ratio_mean": jnp.sum(ratio * mask) / msum,
               "clip_frac": jnp.sum((jnp.abs(ratio - 1.0) > hp.clip_eps) * mask) / msum}
    if teacher_logits is not None and hp.teacher_kl_coef:
        kl = jnp.sum(categorical_kl(logits, teacher_logits) * mask) / msum
        loss = loss + hp.teacher_kl_coef * kl
        metrics["teacher_kl"] = kl
    return loss, metrics
