from repro.rl.distributions import categorical_logp, categorical_entropy, categorical_sample, categorical_kl
from repro.rl.returns import gae, lambda_return, discounted_return
from repro.rl.vtrace import vtrace
from repro.rl.ppo import ppo_loss, PPOConfig
from repro.rl.vtrace_loss import vtrace_loss, VTraceConfig
