from repro.infserver.server import InfServer, Ticket
