"""InfServer: continuous-batching inference service (§3.2).

Collects observations from many Actor clients, runs ONE grouped forward on
the accelerator, scatters actions back — SEED-style central inference. On
TPU this is `serve_step` on the model shards; here the module preserves the
submit/flush protocol and is what the throughput benchmark compares against
local (batch-1) forward passes, reproducing the paper's claim that batched
server inference beats per-actor forwards.

Design (this repo's data-plane rebuild):

* **Ticket futures** — `submit` returns a `Ticket` with `done()`/`result()`;
  the integer id keeps the legacy `get(ticket)` protocol working. Results
  whose owner never collects them (a client killed between submit and
  get) are expired after `ticket_ttl_flushes` flushes so dead actors
  can't leak result arrays into the server's lifetime.
* **Bounded request queue** — pending rows are capped; hitting `max_batch`
  queued rows triggers a flush (the in-process form of backpressure).
* **Multi-model routing** — one server hosts the learner θ plus several
  frozen opponents φ. A flush groups tickets by model, pads each model's
  sub-batch to a shared power-of-two bucket, stacks them to (M, S, L) and
  runs a single `vmap`-over-models jitted forward: one XLA dispatch per
  flush, one jit cache entry per (model-set size, bucket) — not per
  request shape.
* **Param hot-swap** — `update_params`/`ensure_model` replace a model's
  pytree in place; params are traced arguments, so new weights never
  recompile (only the stacked-params cache entry is invalidated). Swaps
  are **hash-gated** (param plane): a refresh carrying the
  `ParamManifest.tree_hash` the route already hosts is a no-op — no
  re-upload, no mesh re-layout, no cache invalidation — and a refresh
  whose pool version is older than the hosted one is dropped so a
  straggler actor can't regress a route.
* **Mesh-sharded execution** (`mesh=`) — hosted params are laid out over a
  `("data", "model")` mesh with the serving shardings from
  `repro.distributed.sharding`: tensor parallelism over 'model' for the
  attention/MLP/vocab weights (no FSDP — forward-only), the continuous
  batch data-parallel over 'data'. The grouped θ+φ forward keeps its
  vmapped model-group axis replicated. `mesh=None` (default) is the
  unchanged single-device path.
* **Telemetry** — per-batch latency and occupancy (real rows / padded
  rows) feed `stats()`, the Table-3-style serving numbers.

Also hosts the teacher-policy forward for KL penalties (paper §3.2).
"""
from __future__ import annotations

import os
import threading
import time
from typing import Any, Dict, Hashable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.policy import make_obs_policy
from repro.kernels import dispatch

_DEFAULT = "__default__"


def _bucket(n: int) -> int:
    """Next power of two >= n: bounds the number of jit cache entries."""
    return 1 << max(0, (n - 1).bit_length())


def _serving_jit(fn):
    """jit(fn) whose traces run inside a dispatch.serving() scope, so the
    inference-only precision mode applies. The scope only matters during
    tracing (dispatch routing is trace-time static); executing the cached
    executable afterwards never re-enters dispatch."""
    jitted = jax.jit(fn)

    def wrapped(*args, **kwargs):
        with dispatch.serving():
            return jitted(*args, **kwargs)

    return wrapped


class Ticket:
    """Future handle for a submitted observation batch."""
    __slots__ = ("tid", "model", "rows", "_server")

    def __init__(self, tid: int, model: Hashable, rows: int, server: "InfServer"):
        self.tid, self.model, self.rows, self._server = tid, model, rows, server

    def done(self) -> bool:
        return self.tid in self._server._results

    def result(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self._server.get(self)

    def __int__(self) -> int:
        return self.tid

    def __repr__(self):
        return f"Ticket({self.tid}, model={self.model!r}, rows={self.rows})"


class InfServer:
    def __init__(self, cfg, num_actions: int, params=None, *, max_batch: int = 256,
                 seed: int = 0, mesh=None, ticket_ttl_flushes: int = 512):
        """`mesh` switches on sharded execution: every hosted model is laid
        out over the mesh with the serving shardings (TP over 'model', no
        FSDP) and flush batches ride the mesh data-parallel. `mesh=None`
        keeps the single-device path bit-for-bit unchanged.

        `ticket_ttl_flushes` bounds result retention: a resolved ticket
        whose owner hasn't collected it within that many subsequent
        flushes is expired (its result arrays freed, `tickets_expired`
        bumped). This is the leak guard for dead clients — an actor that
        is killed between submit and get would otherwise pin its result
        rows for the server's lifetime (`discard` only helps clients
        that die politely)."""
        self.cfg = cfg
        self.policy = make_obs_policy(cfg, num_actions)
        self.max_batch = max_batch
        self.mesh = mesh
        self._param_shardings = None     # lazy: from the first model's shapes
        self._stacked_shardings = None
        self.rng = jax.random.PRNGKey(seed)
        # one reentrant lock serializes registry mutation, queueing and
        # flushing: the async league runtime has many Actor threads sharing
        # one server while each role's Learner hot-swaps its theta route
        # concurrently (`get` may re-enter `flush`, hence reentrant)
        self._lock = threading.RLock()
        # model registry: key -> params, with a swap counter so the
        # stacked-params cache knows when a hot-swap invalidated it, plus
        # the param-plane identity of the hosted copy (content hash +
        # pool version) so identical refreshes no-op instead of
        # re-uploading (and, on the mesh path, re-sharding)
        self._models: Dict[Hashable, Any] = {}
        self._versions: Dict[Hashable, int] = {}
        self._content_hashes: Dict[Hashable, str] = {}
        self._pool_versions: Dict[Hashable, int] = {}
        self._default_key: Optional[Hashable] = None
        self._stack_cache: Dict[tuple, Any] = {}
        # swap telemetry lives up here: the seed registration below counts
        self.swaps = 0               # hot-swaps that actually (re)placed params
        self.swap_noops = 0          # refreshes gated off by content hash
        self.swap_stale_drops = 0    # refreshes dropped as version downgrades
        if params is not None:
            self.register_model(_DEFAULT, params)
        # request queue
        self._pending: List[Tuple[int, Hashable, np.ndarray]] = []
        self._pending_rows = 0
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        # tid -> batches_run at resolution; drives dead-owner expiry
        self._result_born: Dict[int, int] = {}
        self.ticket_ttl_flushes = ticket_ttl_flushes
        self.tickets_expired = 0
        self._next_id = 0
        # forwards: single-model fast path + vmap-over-models grouped path.
        # Both trace inside a dispatch.serving() scope so the inference-only
        # precision mode (REPRO_KERNELS_INFER=bf16) applies to the serving
        # fleet's forwards and never to a learner's training trace.
        self._act = _serving_jit(self.policy.act)
        self._grouped_act = _serving_jit(jax.vmap(self.policy.act))
        # telemetry
        self.requests_served = 0
        self.batches_run = 0
        self.rows_served = 0
        self.rows_padded = 0
        self._latency_sum = 0.0
        self.last_batch_latency_s = 0.0
        self.last_batch_models = 0

    # -- model registry ------------------------------------------------------
    @property
    def params(self):
        """Legacy accessor: the default model's current params."""
        return self._models.get(self._default_key)

    def _place(self, params):
        """Sharded mode: lay the pytree out over the mesh with the serving
        shardings (computed once from the first model's shapes — all routes
        host the same arch). No-op on the single-device path."""
        if self.mesh is None:
            return params
        if self._param_shardings is None:
            from repro.distributed.sharding import (serving_param_shardings,
                                                    stacked_param_shardings)
            shapes = jax.eval_shape(lambda: params)
            self._param_shardings = serving_param_shardings(
                shapes, self.cfg, self.mesh)
            self._stacked_shardings = stacked_param_shardings(
                self._param_shardings, self.mesh)
        return jax.device_put(params, self._param_shardings)

    def _pad_rows(self, rows: int) -> int:
        """Padded batch size for `rows` real rows: the power-of-two bucket,
        rounded up in sharded mode to a multiple of the mesh's data-axis
        extent so the batch dim always divides for the data-parallel
        layout."""
        s = _bucket(rows)
        if self.mesh is not None:
            from repro.distributed.sharding import data_axes
            d = int(np.prod([self.mesh.shape[a]
                             for a in data_axes(self.mesh)]) or 1)
            s = ((s + d - 1) // d) * d
        return s

    def _place_obs(self, obs: np.ndarray, grouped: bool):
        """Commit a flush batch to the mesh data-parallel (sharded mode) or
        just hand it to jit (single-device)."""
        if self.mesh is None:
            return jnp.asarray(obs)
        from repro.distributed.sharding import (grouped_obs_sharding,
                                                obs_batch_sharding)
        ns = (grouped_obs_sharding(self.mesh, obs.shape[1]) if grouped
              else obs_batch_sharding(self.mesh, obs.shape[0]))
        return jax.device_put(obs, ns)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        """Host (or refresh) a model. The first registered model becomes
        the default route for `submit(obs)` without an explicit model.

        `content_hash`/`version` are the param-plane identity of the
        incoming copy (the pulling consumer has both on its
        `ParamManifest`). A refresh whose `content_hash` matches the
        hosted route is a NO-OP: no re-upload, no mesh re-layout, no
        stacked-cache invalidation — the hash-gated hot-swap. A refresh
        whose `version` is OLDER than the hosted one is likewise dropped
        (a straggler actor must not regress a route another actor
        already advanced). Without a hash the swap is unconditional,
        exactly the legacy behavior."""
        with self._lock:
            if self._default_key is None:
                self._default_key = key
            if key in self._models:
                if (content_hash is not None
                        and self._content_hashes.get(key) == content_hash):
                    self.swap_noops += 1
                    return
                hosted_v = self._pool_versions.get(key)
                if (version is not None and hosted_v is not None
                        and version < hosted_v):
                    self.swap_stale_drops += 1
                    return
            self.swaps += 1
            self._versions[key] = self._versions.get(key, -1) + 1
            self._models[key] = self._place(params)
            if content_hash is not None:
                self._content_hashes[key] = content_hash
            else:
                self._content_hashes.pop(key, None)
            if version is not None:
                self._pool_versions[key] = version
            else:
                self._pool_versions.pop(key, None)
            # entries containing this key can never match again (version
            # bumped) — drop them now so stale stacked copies don't pin
            # device memory; entries for other model sets stay warm
            self._stack_cache = {ck: v for ck, v in self._stack_cache.items()
                                 if all(k != key for k, _ in ck)}

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        """Register if absent — the Actor-facing idempotent route setup
        (an existing route is never overwritten, whatever its hash)."""
        with self._lock:
            if key not in self._models:
                self.register_model(key, params, content_hash=content_hash)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        """Cheap route probe: is `key` hosted (and, with `content_hash`,
        hosted at exactly that content)? The RPC client calls this before
        shipping params so identical refreshes cost one tiny round trip."""
        with self._lock:
            if key not in self._models:
                return False
            return (content_hash is None
                    or self._content_hashes.get(key) == content_hash)

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        """Learner pushed new theta to the ModelPool -> hot-swap. Params are
        traced jit arguments, so no recompilation happens. Non-blocking
        (lock only); in-flight flushes finished under the old weights, the
        next flush sees the new ones. The pytree is hosted LIVE on the
        single-device path (callers pass snapshots) and re-laid-out via
        device_put (its own copy) in sharded mode. With a `content_hash`
        matching the hosted copy the swap is a no-op (see
        `register_model`)."""
        with self._lock:
            if key is None:
                # a paramless server gets a real default route, not key None
                key = self._default_key if self._default_key is not None else _DEFAULT
            self.register_model(key, params, content_hash=content_hash,
                                version=version)

    def evict_model(self, key: Hashable) -> bool:
        """Drop a route. Returns False (and keeps the route) when requests
        for it are still queued — under concurrent publishers the caller
        retries after the next flush instead of racing the queue."""
        with self._lock:
            if any(k == key for _, k, _ in self._pending):
                return False
            self._models.pop(key, None)
            self._versions.pop(key, None)
            self._content_hashes.pop(key, None)
            self._pool_versions.pop(key, None)
            self._stack_cache.clear()
            if key == self._default_key:
                self._default_key = next(iter(self._models), None)
            return True

    # -- client protocol -----------------------------------------------------
    def submit(self, obs: np.ndarray, model: Hashable = None) -> Ticket:
        """Queue a (k, L) observation batch for `model` (default: θ); returns
        a ticket future. Usually just an enqueue (lock only), but MAY BLOCK
        for one grouped forward when this submit fills the queue to
        `max_batch` rows — the submitter that trips the threshold pays the
        flush for everyone (the in-process form of backpressure). The obs
        array is referenced until that flush, not copied: callers reusing
        a staging buffer must not overwrite it before `get`."""
        obs = np.asarray(obs)
        with self._lock:
            key = self._default_key if model is None else model
            assert key in self._models, f"unknown model route {key!r}"
            ticket = Ticket(self._next_id, key, obs.shape[0], self)
            self._next_id += 1
            self._pending.append((ticket.tid, key, obs))
            self._pending_rows += obs.shape[0]
            if self._pending_rows >= self.max_batch:
                self.flush()
            return ticket

    @property
    def queue_depth(self) -> int:
        return self._pending_rows

    def flush(self) -> None:
        """Run the grouped forward over everything pending and resolve
        tickets. One XLA dispatch regardless of how many models are routed.
        BLOCKS for the device round trip while HOLDING the server lock —
        concurrent submit/get/hot-swap callers wait behind it (that
        serialization is what makes the batch 'continuous')."""
        with self._lock:
            if not self._pending:
                return
            t0 = time.perf_counter()
            pending, self._pending, self._pending_rows = self._pending, [], 0

            groups: Dict[Hashable, List[Tuple[int, np.ndarray]]] = {}
            for tid, key, obs in pending:
                groups.setdefault(key, []).append((tid, obs))

            if len(groups) == 1:
                (key, items), = groups.items()
                self._flush_single(key, items)
            else:
                self._flush_grouped(groups)

            self.requests_served += len(pending)
            self.batches_run += 1
            self.last_batch_models = len(groups)
            self.last_batch_latency_s = time.perf_counter() - t0
            self._latency_sum += self.last_batch_latency_s
            # dead-owner expiry: results nobody collected within the TTL
            # window are leaked by a crashed client — free them now
            # strict >: a result born in THIS flush (born == batches_run - 1)
            # must survive the full TTL window before it can be reclaimed
            expired = [tid for tid, born in self._result_born.items()
                       if self.batches_run - born > self.ticket_ttl_flushes]
            for tid in expired:
                self._results.pop(tid, None)
                self._result_born.pop(tid, None)
                self.tickets_expired += 1

    def _next_rng(self, n: int = 1):
        self.rng, *ks = jax.random.split(self.rng, n + 1)
        return ks[0] if n == 1 else jnp.stack(ks)

    def _flush_single(self, key, items) -> None:
        tickets = [t for t, _ in items]
        sizes = [o.shape[0] for _, o in items]
        rows = sum(sizes)
        big = np.concatenate([o for _, o in items], axis=0)
        pad = self._pad_rows(rows) - rows
        if pad:
            big = np.concatenate([big, np.zeros((pad,) + big.shape[1:],
                                                big.dtype)], axis=0)
        a, logp, v = self._act(self._models[key], self._next_rng(),
                               self._place_obs(big, grouped=False))
        self._scatter(tickets, sizes, np.asarray(a), np.asarray(logp),
                      np.asarray(v))
        self.rows_served += rows
        self.rows_padded += rows + pad

    def _flush_grouped(self, groups) -> None:
        keys = sorted(groups, key=repr)
        per_model = [np.concatenate([o for _, o in groups[k]], axis=0)
                     for k in keys]
        rows = [m.shape[0] for m in per_model]
        S = self._pad_rows(max(rows))
        obs_mat = np.zeros((len(keys), S) + per_model[0].shape[1:],
                           per_model[0].dtype)
        for m, sub in enumerate(per_model):
            obs_mat[m, :sub.shape[0]] = sub
        stacked = self._stacked_params(keys)
        rngs = self._next_rng(len(keys))
        a, logp, v = self._grouped_act(stacked, rngs,
                                       self._place_obs(obs_mat, grouped=True))
        a, logp, v = np.asarray(a), np.asarray(logp), np.asarray(v)
        for m, k in enumerate(keys):
            tickets = [t for t, _ in groups[k]]
            sizes = [o.shape[0] for _, o in groups[k]]
            self._scatter(tickets, sizes, a[m], logp[m], v[m])
        self.rows_served += sum(rows)
        self.rows_padded += len(keys) * S

    def _stacked_params(self, keys) -> Any:
        """(M, ...) stacked pytree for the model set, cached until any
        member hot-swaps (version bump clears the cache)."""
        cache_key = tuple((k, self._versions[k]) for k in keys)
        hit = self._stack_cache.get(cache_key)
        if hit is None:
            hit = jax.tree.map(lambda *xs: jnp.stack(xs),
                               *(self._models[k] for k in keys))
            if self.mesh is not None:
                # re-commit the stack to the (None, *serving-spec) layout:
                # stacking sharded members leaves XLA's inferred placement,
                # and the grouped forward wants the per-model TP layout back
                hit = jax.device_put(hit, self._stacked_shardings)
            while len(self._stack_cache) >= 8:     # bound without thrashing
                self._stack_cache.pop(next(iter(self._stack_cache)))
            self._stack_cache[cache_key] = hit
        return hit

    def _scatter(self, tickets, sizes, a, logp, v) -> None:
        ofs = 0
        for t, n in zip(tickets, sizes):
            self._results[t] = (a[ofs:ofs + n], logp[ofs:ofs + n],
                                v[ofs:ofs + n])
            self._result_born[t] = self.batches_run
            ofs += n

    def get(self, ticket) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Resolve a ticket: (actions, logps, values) for its rows, each a
        fresh host array the caller owns. MAY BLOCK for one forward — an
        unresolved ticket triggers a flush (so `get` is self-sufficient:
        submit/get with no explicit flush always completes). Results pop
        on read; a second get for the same ticket raises KeyError."""
        tid = ticket.tid if isinstance(ticket, Ticket) else int(ticket)
        with self._lock:
            if tid not in self._results:
                self.flush()
            self._result_born.pop(tid, None)
            return self._results.pop(tid)

    def discard(self, ticket) -> None:
        """Forget a ticket without consuming it: drop its queued request
        (if not yet flushed) and its result (if already resolved).
        Non-blocking. The eviction path for clients that submitted and
        then died — without it an abandoned ticket's result arrays live
        forever."""
        tid = ticket.tid if isinstance(ticket, Ticket) else int(ticket)
        with self._lock:
            self._results.pop(tid, None)
            self._result_born.pop(tid, None)
            kept = [(t, k, o) for t, k, o in self._pending if t != tid]
            if len(kept) != len(self._pending):
                self._pending_rows -= sum(o.shape[0] for t, k, o
                                          in self._pending if t == tid)
                self._pending = kept

    # -- telemetry ------------------------------------------------------------
    def telemetry(self) -> dict:
        """The router's occupancy/latency probe: the cheap subset of
        `stats()` a serving gateway polls at high cadence to steer
        lineage spill. No dispatch counters, no mesh introspection —
        just load and latency, safe to call every few milliseconds
        against a busy replica (single dict, no locks beyond the
        server's own)."""
        batches = max(self.batches_run, 1)
        return {
            "queue_depth": self.queue_depth,
            "results_held": len(self._results),
            "rows_served": self.rows_served,
            "batches_run": self.batches_run,
            "occupancy": self.rows_served / max(self.rows_padded, 1),
            "mean_batch_latency_ms": 1e3 * self._latency_sum / batches,
            "last_batch_latency_ms": 1e3 * self.last_batch_latency_s,
            "models_hosted": len(self._models),
        }

    def stats(self) -> dict:
        batches = max(self.batches_run, 1)
        return {
            "requests_served": self.requests_served,
            "batches_run": self.batches_run,
            "rows_served": self.rows_served,
            "mean_batch_rows": self.rows_served / batches,
            "occupancy": self.rows_served / max(self.rows_padded, 1),
            "mean_batch_latency_ms": 1e3 * self._latency_sum / batches,
            "last_batch_latency_ms": 1e3 * self.last_batch_latency_s,
            "last_batch_models": self.last_batch_models,
            "swaps": self.swaps,
            "swap_noops": self.swap_noops,
            "swap_stale_drops": self.swap_stale_drops,
            "models_hosted": len(self._models),
            "queue_depth": self.queue_depth,
            "results_held": len(self._results),
            "tickets_expired": self.tickets_expired,
            "sharded": self.mesh is not None,
            "mesh_shape": (dict(self.mesh.shape)
                           if self.mesh is not None else None),
            # which kernel tier the forwards actually traced to (a
            # misrouted reference fallback shows up here in production)
            "infer_mode": os.environ.get("REPRO_KERNELS_INFER") or None,
            "dispatch": dispatch.stats(),
        }
