"""InfServer: batched inference service (§3.2, optional module).

Collects observations from many Actor clients, runs ONE batched forward on
the accelerator, scatters actions back — SEED-style central inference. On
TPU this is `serve_step` on the model shards; here the module preserves the
submit/flush protocol and is what the throughput benchmark compares against
local (batch-1) forward passes, reproducing the paper's claim that batched
server inference beats per-actor forwards.

Also hosts the teacher-policy forward for KL penalties (paper §3.2).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.actors.policy import make_obs_policy


class InfServer:
    def __init__(self, cfg, num_actions: int, params, *, max_batch: int = 256,
                 seed: int = 0):
        self.cfg = cfg
        self.policy = make_obs_policy(cfg, num_actions)
        self.params = params
        self.max_batch = max_batch
        self._pending: List[Tuple[int, np.ndarray]] = []
        self._results: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray]] = {}
        self._next_id = 0
        self.rng = jax.random.PRNGKey(seed)
        self.requests_served = 0
        self.batches_run = 0
        self._act = jax.jit(self.policy.act)

    def update_params(self, params):
        """Learner pushed new theta to the ModelPool -> refresh."""
        self.params = params

    # -- client protocol -----------------------------------------------------
    def submit(self, obs: np.ndarray) -> int:
        """Queue a (k, L) observation batch; returns a ticket."""
        ticket = self._next_id
        self._next_id += 1
        self._pending.append((ticket, np.asarray(obs)))
        if sum(o.shape[0] for _, o in self._pending) >= self.max_batch:
            self.flush()
        return ticket

    def flush(self) -> None:
        if not self._pending:
            return
        tickets, obs_list = zip(*self._pending)
        sizes = [o.shape[0] for o in obs_list]
        big = jnp.concatenate([jnp.asarray(o) for o in obs_list], axis=0)
        self.rng, k = jax.random.split(self.rng)
        a, logp, v = self._act(self.params, k, big)
        a, logp, v = np.asarray(a), np.asarray(logp), np.asarray(v)
        ofs = 0
        for t, n in zip(tickets, sizes):
            self._results[t] = (a[ofs:ofs + n], logp[ofs:ofs + n], v[ofs:ofs + n])
            ofs += n
        self.requests_served += len(tickets)
        self.batches_run += 1
        self._pending.clear()

    def get(self, ticket: int):
        if ticket not in self._results:
            self.flush()
        return self._results.pop(ticket)
