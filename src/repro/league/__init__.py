"""Role-based asynchronous league runtime (§3.2, Fig. 2): LeagueSpec roles
over an event-driven Actor/Learner/coordinator control plane."""
from repro.core.types import FreezeGate
from repro.league.spec import LeagueSpec, RoleSpec, ROLE_DEFAULTS
from repro.league.roles import install_roles, make_game_mgr
from repro.league.runtime import (ActorWorker, Coordinator, LearnerWorker,
                                  LeagueRuntime, RoleRuntime, build_runtime)
