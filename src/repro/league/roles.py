"""Role wiring: turn a LeagueSpec into a populated LeagueMgr.

`make_game_mgr` maps a RoleSpec onto the GAME_MGRS registry (injecting the
exploiter target lineage where the matchmaker takes one), and
`install_roles` registers every role as a learning agent — shared payoff
matrix, per-role matchmaking, freeze gate and reset policy — on a LeagueMgr
whose ModelPool snapshots on pull (the concurrency-safe default for the
async runtime).
"""
from __future__ import annotations

from typing import Any, Callable, Optional

from repro.core import GAME_MGRS, LeagueMgr, ModelPool
from repro.core.game_mgr import GameMgr
from repro.league.spec import LeagueSpec, RoleSpec

# matchmakers that chase a specific lineage, and the kwarg that names it
_TARGETED = {"exploiter": "target_agent_id", "minimax": "target_agent_id"}


def make_game_mgr(role: RoleSpec, *, payoff, seed: int = 0) -> GameMgr:
    name = role.matchmaking_name
    assert name in GAME_MGRS, (
        f"role {role.name!r}: unknown matchmaking {name!r}; "
        f"pick from {sorted(GAME_MGRS)}")
    kwargs = dict(role.matchmaking_kwargs)
    if name in _TARGETED:
        kwargs.setdefault(_TARGETED[name], role.target)
    return GAME_MGRS[name](payoff=payoff, seed=seed, **kwargs)


def install_roles(spec: LeagueSpec, init_params_fn: Callable[[int], Any], *,
                  league: Optional[LeagueMgr] = None, pbt: bool = False,
                  seed: int = 0,
                  lease_ttl_s: Optional[float] = None) -> LeagueMgr:
    """Build (or extend) a LeagueMgr from a spec. `init_params_fn(i)` makes
    the seed params for the i-th role — a fresh random init per lineage, or
    a shared imitation-learned seed. `lease_ttl_s` activates the task-lease
    plane (dead-actor matches get reaped and re-issued)."""
    if league is None:
        league = LeagueMgr(model_pool=ModelPool(snapshot_on_pull=True),
                           pbt=pbt, seed=seed, lease_ttl_s=lease_ttl_s)
    for i, role in enumerate(spec):
        gm = make_game_mgr(role, payoff=league.payoff, seed=seed + i)
        league.add_learning_agent(
            role.name, init_params_fn(i), game_mgr=gm, role=role.role,
            gate=role.gate, reset_on_freeze=role.reset_policy)
    return league
