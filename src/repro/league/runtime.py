"""Event-driven league runtime: the paper's decoupled services (§3.2,
Fig. 2) as threads over the existing thread-capable seams.

The synchronous driver (`launch/train.py --sync`) interleaves every actor
segment with every learner step in one nested loop — actors idle while the
learner steps and vice versa. This runtime gives each module its own
thread, communicating only through the services the paper names:

  * **ActorWorker** (one per Actor) — pulls a Task from the LeagueMgr,
    runs a rollout segment, pushes the trajectory into its role's
    DataServer. Blocks on ring-full backpressure (`wait_for_room`) so a
    slow learner throttles its producers instead of losing frames.
  * **LearnerWorker** (one per role) — drains the DataServer continuously
    (`wait_ready`), steps the train step, publishes theta to the
    ModelPool (and the InfServer hot-swap path when serving centrally).
    Executes freeze requests at step boundaries, where the params are
    quiescent.
  * **Coordinator** (one per league) — polls each role's FreezeGate via
    `LeagueMgr.should_freeze` and posts freeze requests to the owning
    LearnerWorker; owns the league-level stop conditions.

Freeze decisions are made by the coordinator but *executed* by the learner
thread that owns the params — the request/execute split keeps every pytree
single-writer, and the request->execute delay is the `freeze_latency_s`
telemetry in the run report.

Liveness: the coordinator beats a shared `Heartbeat` every loop; Actor
and Learner workers treat a beat gap longer than `heartbeat_timeout_s`
as "coordinator dead" and exit their loops cleanly instead of producing
into a leaderless league forever — the in-process form of the worker
heartbeat the multiprocess runtime runs over RPC
(`repro.distributed.heartbeat`).
"""
from __future__ import annotations

import threading
import time
import traceback
from dataclasses import dataclass
from typing import List, Optional, Tuple

import jax

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import LeagueMgr, ModelKey
from repro.distributed.heartbeat import Heartbeat
from repro.envs import make_env
from repro.infserver import InfServer
from repro.league.roles import install_roles
from repro.league.spec import LeagueSpec, RoleSpec
from repro.learners import DataServer, Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw


class _Worker(threading.Thread):
    """Stoppable loop thread that captures its own failure instead of
    dying silently (the runtime re-raises after shutdown)."""

    def __init__(self, name: str):
        super().__init__(name=name, daemon=True)
        self.stop_event = threading.Event()
        self.error: Optional[BaseException] = None
        self.error_tb: str = ""

    def run(self):
        try:
            self._loop()
        except BaseException as e:          # noqa: BLE001 — reported, not hidden
            self.error = e
            self.error_tb = traceback.format_exc()

    def stop(self):
        self.stop_event.set()

    def _loop(self):
        raise NotImplementedError


class ActorWorker(_Worker):
    def __init__(self, name: str, actor: Actor, data_server: DataServer,
                 poll_s: float = 0.05, heartbeat: Optional[Heartbeat] = None,
                 heartbeat_timeout_s: float = 30.0):
        super().__init__(name)
        self.actor = actor
        self.data_server = data_server
        self.poll_s = poll_s
        self.heartbeat = heartbeat
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.segments = 0

    def _coordinator_dead(self) -> bool:
        return (self.heartbeat is not None
                and self.heartbeat.stalled(self.heartbeat_timeout_s))

    def _loop(self):
        while not self.stop_event.is_set():
            if self._coordinator_dead():
                return                     # clean exit: nobody to freeze us
            traj, _task = self.actor.run_segment()
            # backpressure: never bury frames the learner has not consumed.
            # put_when_room holds the room predicate and the write under one
            # lock, so producers of the same role can't jointly overshoot.
            while not self.stop_event.is_set() and not self._coordinator_dead():
                if self.data_server.put_when_room(traj, timeout=self.poll_s):
                    self.segments += 1
                    break


class LearnerWorker(_Worker):
    def __init__(self, name: str, learner: Learner, data_server: DataServer,
                 poll_s: float = 0.05, heartbeat: Optional[Heartbeat] = None,
                 heartbeat_timeout_s: float = 30.0):
        super().__init__(name)
        self.learner = learner
        self.data_server = data_server
        self.poll_s = poll_s
        self.heartbeat = heartbeat
        self.heartbeat_timeout_s = heartbeat_timeout_s
        self.period_steps = 0               # steps since the last freeze
        self.total_steps = 0
        self.freezes: List[dict] = []
        self._freeze_request: Optional[Tuple[str, float]] = None

    # -- coordinator-facing ---------------------------------------------------
    def request_freeze(self, reason: str) -> None:
        """Posted by the coordinator; executed by this worker at the next
        step boundary (params are single-writer: this thread owns them)."""
        if self._freeze_request is None:
            self._freeze_request = (reason, time.monotonic())

    @property
    def freeze_pending(self) -> bool:
        return self._freeze_request is not None

    # -- loop ----------------------------------------------------------------
    def _loop(self):
        while not self.stop_event.is_set():
            if (self.heartbeat is not None
                    and self.heartbeat.stalled(self.heartbeat_timeout_s)):
                return                     # coordinator dead: clean exit
            req = self._freeze_request
            if req is not None:
                reason, t_req = req
                old_key = self.learner.current_key
                new_key = self.learner.end_learning_period(reason=reason)
                self.freezes.append({
                    "frozen": str(old_key), "minted": str(new_key),
                    "reason": reason, "period_steps": self.period_steps,
                    "latency_s": time.monotonic() - t_req,
                })
                self.period_steps = 0
                self._freeze_request = None
                continue
            if not self.data_server.wait_ready(timeout=self.poll_s):
                continue
            m = self.learner.learn(num_steps=1)
            if m:
                self.period_steps += 1
                self.total_steps += 1


@dataclass
class RoleRuntime:
    spec: RoleSpec
    actors: List[ActorWorker]
    learner: LearnerWorker
    data_server: DataServer


class Coordinator(_Worker):
    """Applies freeze decisions and owns the league-level stop conditions."""

    def __init__(self, league: LeagueMgr, roles: List[RoleRuntime],
                 done_event: threading.Event, poll_s: float = 0.01,
                 max_freezes_per_role: Optional[int] = None,
                 max_steps_per_role: Optional[int] = None,
                 deadline: Optional[float] = None,
                 heartbeat: Optional[Heartbeat] = None):
        super().__init__("league-coordinator")
        self.league = league
        self.roles = roles
        self.done_event = done_event
        self.poll_s = poll_s
        self.max_freezes = max_freezes_per_role
        self.max_steps = max_steps_per_role
        self.deadline = deadline
        self.heartbeat = heartbeat

    def _role_quota_met(self, role: RoleRuntime) -> bool:
        """True once every stop condition that was actually set is met."""
        met_any = False
        if self.max_freezes is not None:
            if (len(role.learner.freezes) < self.max_freezes
                    or role.learner.freeze_pending):
                return False
            met_any = True
        if self.max_steps is not None:
            if role.learner.total_steps < self.max_steps:
                return False
            met_any = True
        return met_any

    def _loop(self):
        while not self.stop_event.is_set():
            if self.heartbeat is not None:
                self.heartbeat.beat()      # liveness: workers watch this
            for role in self.roles:
                lw = role.learner
                if lw.freeze_pending:
                    continue
                if (self.max_freezes is not None
                        and len(lw.freezes) >= self.max_freezes):
                    continue                 # quota filled: stop freezing
                reason = self.league.should_freeze(role.spec.name,
                                                   lw.period_steps)
                if reason:
                    lw.request_freeze(reason)
            quota = ((self.max_freezes is not None
                      or self.max_steps is not None)
                     and all(self._role_quota_met(r) for r in self.roles))
            timed_out = (self.deadline is not None
                         and time.monotonic() >= self.deadline)
            if quota or timed_out:
                self.done_event.set()
                return
            time.sleep(self.poll_s)


class LeagueRuntime:
    """Owns the worker threads for one league. `run` is the one-call
    entry: start everything, wait for the stop condition, shut down
    cleanly, and either raise the first worker failure or return the
    run report."""

    def __init__(self, league: LeagueMgr, roles: List[RoleRuntime],
                 inf_server: Optional[InfServer] = None,
                 coordinator_poll_s: float = 0.01,
                 heartbeat: Optional[Heartbeat] = None):
        self.league = league
        self.roles = roles
        self.inf_server = inf_server
        self.coordinator_poll_s = coordinator_poll_s
        self.heartbeat = heartbeat
        self.done_event = threading.Event()
        self._coordinator: Optional[Coordinator] = None

    # -- lifecycle -------------------------------------------------------------
    def _workers(self) -> List[_Worker]:
        ws: List[_Worker] = []
        for r in self.roles:
            ws.extend(r.actors)
            ws.append(r.learner)
        if self._coordinator is not None:
            ws.append(self._coordinator)
        return ws

    def start(self, *, max_freezes_per_role: Optional[int] = None,
              max_steps_per_role: Optional[int] = None,
              max_seconds: Optional[float] = None) -> None:
        deadline = (time.monotonic() + max_seconds
                    if max_seconds is not None else None)
        self.done_event.clear()
        if self.heartbeat is not None:
            self.heartbeat.beat()    # fresh epoch: a runtime built long ago
                                     # must not look dead at worker start
        self._coordinator = Coordinator(
            self.league, self.roles, self.done_event,
            poll_s=self.coordinator_poll_s,
            max_freezes_per_role=max_freezes_per_role,
            max_steps_per_role=max_steps_per_role, deadline=deadline,
            heartbeat=self.heartbeat)
        for w in self._workers():
            w.start()

    def stop(self, join_timeout: float = 180.0) -> List[_Worker]:
        """Signal every worker and join. Returns workers that failed (the
        in-flight XLA call of an ActorWorker can take a while to drain —
        hence the generous join timeout)."""
        workers = self._workers()
        for w in workers:
            w.stop()
        deadline = time.monotonic() + join_timeout
        for w in workers:
            w.join(timeout=max(0.0, deadline - time.monotonic()))
        stuck = [w for w in workers if w.is_alive()]
        assert not stuck, f"workers failed to shut down: {[w.name for w in stuck]}"
        return [w for w in workers if w.error is not None]

    def run(self, *, max_seconds: Optional[float] = None,
            max_freezes_per_role: Optional[int] = None,
            max_steps_per_role: Optional[int] = None,
            join_timeout: float = 180.0) -> dict:
        assert any(x is not None for x in
                   (max_seconds, max_freezes_per_role, max_steps_per_role)), \
            "the runtime needs at least one stop condition"
        t0 = time.monotonic()
        self.start(max_freezes_per_role=max_freezes_per_role,
                   max_steps_per_role=max_steps_per_role,
                   max_seconds=max_seconds)
        try:
            while not self.done_event.wait(timeout=0.05):
                dead = [w for w in self._workers() if w.error is not None]
                if dead:
                    break
        finally:
            failed = self.stop(join_timeout=join_timeout)
        if failed:
            details = "\n\n".join(f"[{w.name}]\n{w.error_tb}" for w in failed)
            raise RuntimeError(
                f"{len(failed)} league worker(s) failed:\n{details}")
        return self.report(wall_s=time.monotonic() - t0)

    # -- telemetry ------------------------------------------------------------
    def report(self, wall_s: float) -> dict:
        per_role = {}
        frames_total = 0
        latencies: List[float] = []
        for r in self.roles:
            frames = sum(a.actor.frames_produced for a in r.actors)
            frames_total += frames
            latencies.extend(f["latency_s"] for f in r.learner.freezes)
            tp = r.data_server.throughput()
            per_role[r.spec.name] = {
                "role": r.spec.role,
                "segments": sum(a.segments for a in r.actors),
                "frames_produced": frames,
                "learner_steps": r.learner.total_steps,
                "freezes": list(r.learner.freezes),
                "rfps": round(tp["rfps"], 1),
                "cfps": round(tp["cfps"], 1),
                "rfps_window": round(tp["rfps_window"], 1),
                "cfps_window": round(tp["cfps_window"], 1),
                "sampler": r.data_server.sampler.name,
            }
        return {
            "wall_s": round(wall_s, 3),
            "frames_total": frames_total,
            "frames_per_s": round(frames_total / max(wall_s, 1e-9), 1),
            "freeze_latency_s_mean": (round(sum(latencies) / len(latencies), 4)
                                      if latencies else None),
            "freeze_latency_s_max": (round(max(latencies), 4)
                                     if latencies else None),
            "roles": per_role,
            "league": self.league.league_state(),
            "clean_shutdown": True,
        }


# ---------------------------------------------------------------------------
def build_runtime(spec: LeagueSpec, *, env_name: str = "rps",
                  arch: str = "tleague-policy-s", loss: str = "ppo",
                  num_envs: int = 8, unroll_len: int = 8, lr: float = 3e-4,
                  seed: int = 0, served: bool = False, pbt: bool = False,
                  ring_segments: Optional[int] = None,
                  heartbeat_timeout_s: float = 30.0,
                  sampler: str = "uniform") -> LeagueRuntime:
    """Wire a LeagueRuntime from a LeagueSpec: per-role Actors + Learner +
    DataServer over one shared LeagueMgr/ModelPool/PayoffMatrix (and one
    shared InfServer when `served`). `ring_segments` sizes each role's ring
    in segments; default = 2x the role's actor count so every actor can
    stay one segment ahead of the learner before backpressure bites.
    `heartbeat_timeout_s` is how long workers keep running without a
    coordinator beat before exiting cleanly. `sampler` picks each role's
    replay strategy (`repro.learners.samplers`); non-uniform samplers run
    the DataServer off-policy (blocking=False) since their whole point is
    revisiting old rows."""
    env = make_env(env_name)
    cfg = get_arch(arch)
    rng = jax.random.PRNGKey(seed)
    league = install_roles(spec, lambda i: init_params(jax.random.fold_in(rng, i), cfg),
                           pbt=pbt, seed=seed)
    opt = adamw(lr, clip_norm=1.0)
    inf_server = None
    if served:
        inf_server = InfServer(
            cfg, env.spec.num_actions, seed=seed + 7919,
            max_batch=max(64, num_envs * env.spec.num_agents
                          * spec.num_actors_total))

    n_learner_slots = env.spec.team_size
    seg_rows = num_envs * n_learner_slots
    seg_frames = seg_rows * unroll_len

    heartbeat = Heartbeat()
    roles: List[RoleRuntime] = []
    for i, role in enumerate(spec):
        segs = ring_segments or max(2, 2 * role.num_actors)
        ds = DataServer(capacity_frames=segs * seg_frames,
                        blocking=(sampler == "uniform"), sampler=sampler)
        actor_workers = []
        for a in range(role.num_actors):
            actor = Actor(env, cfg, league, agent_id=role.name,
                          num_envs=num_envs, unroll_len=unroll_len,
                          seed=seed * 1000 + i * 100 + a,
                          inf_server=inf_server)
            actor_workers.append(ActorWorker(
                f"actor/{role.name}/{a}", actor, ds, heartbeat=heartbeat,
                heartbeat_timeout_s=heartbeat_timeout_s))
        step = build_env_train_step(cfg, env.spec.num_actions, opt, loss=loss)
        learner = Learner(league, step, opt,
                          league.model_pool.pull(ModelKey(role.name, 0)),
                          agent_id=role.name, data_server=ds)
        roles.append(RoleRuntime(
            spec=role, actors=actor_workers,
            learner=LearnerWorker(f"learner/{role.name}", learner, ds,
                                  heartbeat=heartbeat,
                                  heartbeat_timeout_s=heartbeat_timeout_s),
            data_server=ds))
    return LeagueRuntime(league, roles, inf_server=inf_server,
                         heartbeat=heartbeat)
