"""LeagueSpec: declarative description of a role-based league population.

One spec = one population of learning agents, each playing an
AlphaStar-style role. A role bundles three policies:

  * **matchmaking** — which GameMgr (opponent distribution Q) the role's
    Actors sample phi from;
  * **freeze gate** — when theta freezes into the opponent pool M
    (winrate-gated vs the pool, with a timeout; see
    `repro.core.types.FreezeGate`);
  * **reset-on-freeze** — whether theta_{v+1} continues from theta
    (`continue`, the main agent) or restarts from the seed params
    (`seed`, the exploiter reset of AlphaStar).

Role defaults (matchmaking / reset) follow the published schemes:

  | role               | matchmaking (default)        | reset  |
  |--------------------|------------------------------|--------|
  | main               | sp_pfsp (35% self, 65% PFSP) | no     |
  | main_exploiter     | exploiter (main's current)   | seed   |
  | league_exploiter   | league_pfsp (whole pool)     | seed   |
  | minimax_exploiter  | minimax (curriculum over     | seed   |
  |                    | the target lineage)          |        |

JSON schema (`LeagueSpec.from_json`):

    {"roles": [
       {"name": "main", "role": "main", "num_actors": 2,
        "gate": {"winrate": 0.7, "min_games": 16, "min_steps": 8,
                 "timeout_steps": 64}},
       {"name": "mm", "role": "minimax_exploiter", "target": "main",
        "matchmaking_kwargs": {"beat_threshold": 0.6}}
    ]}

Every field except `name` is optional; omitted fields take the role
defaults above (and `FreezeGate()` for the gate).
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.core.types import FreezeGate

ROLE_DEFAULTS: Dict[str, Dict[str, str]] = {
    "main": {"matchmaking": "sp_pfsp", "reset_on_freeze": "continue"},
    "main_exploiter": {"matchmaking": "exploiter", "reset_on_freeze": "seed"},
    "league_exploiter": {"matchmaking": "league_pfsp",
                         "reset_on_freeze": "seed"},
    "minimax_exploiter": {"matchmaking": "minimax", "reset_on_freeze": "seed"},
}


@dataclass(frozen=True)
class RoleSpec:
    name: str                       # the agent_id of this lineage
    role: str = "main"
    matchmaking: Optional[str] = None          # GAME_MGRS name; role default
    matchmaking_kwargs: Dict = field(default_factory=dict)
    gate: FreezeGate = field(default_factory=FreezeGate)
    reset_on_freeze: Optional[str] = None      # 'continue'|'seed'; role default
    num_actors: int = 1
    target: str = "main"            # lineage the exploiter roles chase

    def __post_init__(self):
        assert self.role in ROLE_DEFAULTS, (
            f"unknown role {self.role!r}; pick from {sorted(ROLE_DEFAULTS)}")
        assert self.num_actors >= 1, "every role needs at least one Actor"

    @property
    def matchmaking_name(self) -> str:
        return self.matchmaking or ROLE_DEFAULTS[self.role]["matchmaking"]

    @property
    def reset_policy(self) -> str:
        return self.reset_on_freeze or ROLE_DEFAULTS[self.role]["reset_on_freeze"]

    def to_dict(self) -> Dict:
        d = dataclasses.asdict(self)
        d["gate"] = self.gate.to_dict()
        return d

    @classmethod
    def from_dict(cls, d: Dict) -> "RoleSpec":
        d = dict(d)
        if isinstance(d.get("gate"), dict):
            d["gate"] = FreezeGate.from_dict(d["gate"])
        return cls(**d)


@dataclass(frozen=True)
class LeagueSpec:
    roles: tuple   # Tuple[RoleSpec, ...]

    def __post_init__(self):
        object.__setattr__(self, "roles", tuple(self.roles))
        names = [r.name for r in self.roles]
        assert names, "a LeagueSpec needs at least one role"
        assert len(set(names)) == len(names), f"duplicate role names: {names}"
        known = set(names)
        for r in self.roles:
            if r.role != "main":
                assert r.target in known, (
                    f"role {r.name!r} targets unknown lineage {r.target!r}")

    def __iter__(self):
        return iter(self.roles)

    def __len__(self):
        return len(self.roles)

    def get(self, name: str) -> RoleSpec:
        for r in self.roles:
            if r.name == name:
                return r
        raise KeyError(name)

    @property
    def num_actors_total(self) -> int:
        return sum(r.num_actors for r in self.roles)

    # -- (de)serialization ----------------------------------------------------
    def to_dict(self) -> Dict:
        return {"roles": [r.to_dict() for r in self.roles]}

    @classmethod
    def from_dict(cls, d: Dict) -> "LeagueSpec":
        return cls(roles=tuple(RoleSpec.from_dict(r) for r in d["roles"]))

    def to_json(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2)
            f.write("\n")

    @classmethod
    def from_json(cls, path: str) -> "LeagueSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    # -- common shapes ---------------------------------------------------------
    @classmethod
    def main_vs_exploiter(cls, exploiter_role: str = "minimax_exploiter",
                          num_actors: int = 1,
                          gate: Optional[FreezeGate] = None) -> "LeagueSpec":
        """The smallest interesting league: one main + one exploiter."""
        g = gate or FreezeGate()
        return cls(roles=(
            RoleSpec(name="main", role="main", num_actors=num_actors, gate=g),
            RoleSpec(name="exploiter:0", role=exploiter_role, target="main",
                     num_actors=num_actors, gate=g),
        ))
