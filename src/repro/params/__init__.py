"""The param plane: versioned, content-addressed parameter distribution.

One manifest per hosted pytree (`ParamManifest`: monotonic version +
per-leaf content hashes), minted by `ModelPool.push`, lets every
consumer synchronize by the cheapest sufficient means — `NotModified`
tags, changed-leaf deltas, or hash-gated InfServer hot-swaps — instead
of re-shipping the full pytree on every pull. See
docs/architecture.md ("The param plane").
"""
from repro.params.cache import CachedPuller
from repro.params.manifest import (NotModified, ParamDelta, ParamManifest,
                                   apply_delta, build_manifest,
                                   flatten_with_paths, leaf_hash)

__all__ = ["CachedPuller", "NotModified", "ParamDelta", "ParamManifest",
           "apply_delta", "build_manifest", "flatten_with_paths",
           "leaf_hash"]
