"""Versioned, content-addressed parameter manifests (the param plane).

Every pytree the ModelPool hosts gets a `ParamManifest`: a monotonic
per-key version plus one content hash per leaf (blake2b over
dtype/shape/bytes), minted by the pool and shipped to every consumer.
The manifest is what makes cheap synchronization possible everywhere
else in the system:

* **hash-gated pulls** — `ModelPool.pull_if_changed(key, have_version)`
  answers `NotModified` when the caller is current, or a `ParamDelta`
  carrying only the leaves whose hash changed (the full pytree only when
  the caller's version is unknown to the server);
* **hash-gated hot-swap** — the InfServer skips re-upload (and, on the
  mesh path, re-sharding) when an incoming route refresh carries the
  `tree_hash` it already hosts;
* **bit-exact reconstruction** — `apply_delta` grafts changed leaves
  onto the consumer's cached copy by leaf path; the result hashes to the
  new manifest, which `CachedPuller` treats as the correctness oracle.

Leaves are addressed by their `jax.tree_util.keystr` path, so manifests
survive serialization (plain str->str dicts) and diff across processes.
Hashing reads the raw host bytes (`np.asarray` is zero-copy for CPU jax
arrays); manifests are minted lazily — a pool that is never asked for
one (the in-process `--sync` loop) never pays for hashing.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Dict, List, Optional, Tuple

import numpy as np


def leaf_hash(x) -> str:
    """Content hash of one array leaf: dtype + shape + raw bytes. Hashes
    through the buffer protocol — no byte-copy of the (possibly huge)
    leaf, which matters because the ModelPool mints manifests under its
    global lock."""
    a = np.ascontiguousarray(np.asarray(x))
    h = hashlib.blake2b(digest_size=16)
    h.update(a.dtype.str.encode())
    h.update(repr(a.shape).encode())
    h.update(a.data)
    return h.hexdigest()


def flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    """(keystr-path, leaf) pairs in canonical flatten order."""
    import jax

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


@dataclasses.dataclass(frozen=True)
class ParamManifest:
    """The version identity of one hosted pytree: per-leaf content
    hashes keyed by leaf path, a whole-tree hash over them, and the
    pool's monotonic per-key version counter."""
    version: int
    leaf_hashes: Dict[str, str]
    tree_hash: str
    nbytes: int

    def changed_paths(self, old: "ParamManifest") -> Optional[List[str]]:
        """Leaf paths whose hash differs from `old`. None means the leaf
        SET itself changed (a reshaped/renamed pytree) — no delta exists
        and the consumer needs a full pull."""
        if set(self.leaf_hashes) != set(old.leaf_hashes):
            return None
        return [p for p, h in self.leaf_hashes.items()
                if old.leaf_hashes[p] != h]

    def __eq__(self, other):
        return (isinstance(other, ParamManifest)
                and self.version == other.version
                and self.tree_hash == other.tree_hash)

    def __hash__(self):
        return hash((self.version, self.tree_hash))


def build_manifest(params, version: int) -> ParamManifest:
    leaves = flatten_with_paths(params)
    hashes = {p: leaf_hash(x) for p, x in leaves}
    nbytes = int(sum(np.asarray(x).nbytes for _, x in leaves))
    top = hashlib.blake2b(digest_size=16)
    for p in sorted(hashes):
        top.update(p.encode())
        top.update(hashes[p].encode())
    return ParamManifest(version=version, leaf_hashes=hashes,
                         tree_hash=top.hexdigest(), nbytes=nbytes)


@dataclasses.dataclass(frozen=True)
class NotModified:
    """`pull_if_changed` answer when the caller's version is current:
    nothing crosses the wire but this tag."""
    version: int


@dataclasses.dataclass
class ParamDelta:
    """`pull_if_changed` answer when the caller is stale. `full=True`
    carries the whole pytree in `params` (caller's version unknown to
    the server, or the leaf set changed); otherwise `leaves` maps the
    changed leaf paths to their new arrays and the caller grafts them
    onto its cached copy with `apply_delta`.

    `by_hash` is the cross-key content-addressing channel: leaf paths
    whose content the caller advertised it already holds (under ANY key
    — `pull_if_changed(..., have_hashes=...)`) map to their content
    hash instead of shipping bytes; the caller resolves them from its
    own hash store. An exploiter reset-on-freeze back to the seed
    pytree therefore costs zero param bytes for a warm consumer."""
    manifest: ParamManifest
    full: bool
    params: Any = None
    leaves: Optional[Dict[str, Any]] = None
    by_hash: Optional[Dict[str, str]] = None


def apply_delta(base, leaves: Dict[str, Any]):
    """Graft `leaves` (path -> new array) onto `base` FUNCTIONALLY: the
    returned pytree shares every unchanged leaf with `base` and `base`
    itself is never mutated — callers that handed their cached copy to
    someone else (an InfServer hosting it live) stay safe."""
    import jax

    flat, treedef = jax.tree_util.tree_flatten_with_path(base)
    out, seen = [], set()
    for path, leaf in flat:
        p = jax.tree_util.keystr(path)
        if p in leaves:
            out.append(leaves[p])
            seen.add(p)
        else:
            out.append(leaf)
    missing = set(leaves) - seen
    if missing:
        raise KeyError(f"delta carries leaves absent from the base pytree: "
                       f"{sorted(missing)[:3]}...")
    return jax.tree_util.tree_unflatten(treedef, out)
