"""Version-cached parameter pulls: the consumer side of the param plane.

`CachedPuller` wraps anything with the ModelPool pull surface — the
in-process `repro.core.ModelPool`, the RPC `ModelPoolClient`, or any
test double — and turns every `get` into the cheapest sufficient
operation:

* cache current  -> one `NotModified` tag crosses the seam, the cached
  pytree is returned as-is (zero copies, zero bytes of params);
* cache stale    -> only the changed leaves cross, grafted functionally
  onto the cached copy (`apply_delta` never mutates the old object, so
  a copy the caller handed elsewhere — e.g. hosted live by an
  InfServer — is never written through);
* cache empty / pool without `pull_if_changed` -> a plain full pull.

The cached object is returned by reference: callers must treat it as
immutable (every producer in this codebase does — the ModelPool replaces
entries, never mutates them). Callers that feed a donating train step
must snapshot first, exactly as they must after a plain `pull`.
"""
from __future__ import annotations

from typing import Any, Dict, Hashable, Optional, Tuple

from repro.params.manifest import NotModified, ParamManifest, apply_delta


class CachedPuller:
    def __init__(self, pool, copy: Optional[bool] = None):
        self._pool = pool
        self._copy = copy
        self._cache: Dict[Hashable, Tuple[ParamManifest, Any]] = {}

    def get(self, key) -> Any:
        return self.get_with_manifest(key)[0]

    def get_with_manifest(self, key) -> Tuple[Any, Optional[ParamManifest]]:
        """Current params for `key` plus their manifest (None when the
        pool predates the param plane and only `pull` exists)."""
        pull_if_changed = getattr(self._pool, "pull_if_changed", None)
        if pull_if_changed is None:
            return self._pool.pull(key), None
        ent = self._cache.get(key)
        have = ent[0].version if ent is not None else None
        r = pull_if_changed(key, have, copy=self._copy)
        if isinstance(r, NotModified):
            return ent[1], ent[0]
        params = r.params if r.full else apply_delta(ent[1], r.leaves)
        self._cache[key] = (r.manifest, params)
        return params, r.manifest

    def manifest(self, key) -> Optional[ParamManifest]:
        """The cached manifest (None if `key` was never pulled)."""
        ent = self._cache.get(key)
        return ent[0] if ent is not None else None

    def drop(self, key) -> None:
        self._cache.pop(key, None)

    def clear(self) -> None:
        self._cache.clear()
