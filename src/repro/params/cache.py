"""Version-cached parameter pulls: the consumer side of the param plane.

`CachedPuller` wraps anything with the ModelPool pull surface — the
in-process `repro.core.ModelPool`, the RPC `ModelPoolClient`, or any
test double — and turns every `get` into the cheapest sufficient
operation:

* cache current  -> one `NotModified` tag crosses the seam, the cached
  pytree is returned as-is (zero copies, zero bytes of params);
* cache stale    -> only the changed leaves cross, grafted functionally
  onto the cached copy (`apply_delta` never mutates the old object, so
  a copy the caller handed elsewhere — e.g. hosted live by an
  InfServer — is never written through);
* cache empty / pool without `pull_if_changed` -> a plain full pull;
* answer OLDER than the cache (a failover landed on a lagging read
  replica) -> ignored, the cached newer params win (`stale_answers`).

On top of the per-key version cache sits a CROSS-KEY hash store: every
cached leaf is indexed by its content hash, the set of held hashes is
advertised with each `pull_if_changed` (pools that predate the protocol
just ignore the extra keyword, via a TypeError retry), and a delta whose
`by_hash` references held content is resolved locally — so a fresh key
whose content the cache already holds under another key (an exploiter
reset to the seed, a PBT exploit of the leader) costs zero param bytes.
Hash-resolved leaves alias the cache's own arrays, which is exactly the
read-only-by-reference contract cached objects already carry.

The cached object is returned by reference: callers must treat it as
immutable (every producer in this codebase does — the ModelPool replaces
entries, never mutates them). Callers that feed a donating train step
must snapshot first, exactly as they must after a plain `pull`.
"""
from __future__ import annotations

from typing import Any, Dict, Hashable, Optional, Tuple

from repro.params.manifest import (NotModified, ParamManifest, apply_delta,
                                   flatten_with_paths)


class CachedPuller:
    def __init__(self, pool, copy: Optional[bool] = None):
        self._pool = pool
        self._copy = copy
        self._cache: Dict[Hashable, Tuple[ParamManifest, Any]] = {}
        self._hashes: Dict[str, Any] = {}    # content hash -> cached leaf
        self._cross_key_supported = True     # cleared on TypeError retry
        self.stale_answers = 0               # lagging-replica answers ignored

    def get(self, key) -> Any:
        return self.get_with_manifest(key)[0]

    def get_with_manifest(self, key) -> Tuple[Any, Optional[ParamManifest]]:
        """Current params for `key` plus their manifest (None when the
        pool predates the param plane and only `pull` exists)."""
        pull_if_changed = getattr(self._pool, "pull_if_changed", None)
        if pull_if_changed is None:
            return self._pool.pull(key), None
        ent = self._cache.get(key)
        have = ent[0].version if ent is not None else None
        r = None
        if self._hashes and self._cross_key_supported:
            try:
                r = pull_if_changed(key, have, copy=self._copy,
                                    have_hashes=sorted(self._hashes))
            except TypeError:                # legacy pool / test double
                self._cross_key_supported = False
        if r is None:
            r = pull_if_changed(key, have, copy=self._copy)
        if isinstance(r, NotModified):
            return ent[1], ent[0]
        if ent is not None and r.manifest.version < ent[0].version:
            # a LAGGING pool answered (failover landed on a replica that
            # has not caught up): versions are monotonic per key, so the
            # cached entry is strictly newer — keep it, never regress
            self.stale_answers += 1
            return ent[1], ent[0]
        params = self._reconstruct(r, ent)
        if params is None:
            # unresolvable (hash store raced an eviction, or a cross-key
            # delta with no structural scaffold): take the full answer,
            # re-asking WITHOUT have_hashes so it cannot divert again
            r = pull_if_changed(key, None, copy=self._copy)
            params = r.params
        self._cache[key] = (r.manifest, params)
        self._reindex()
        return params, r.manifest

    def _reconstruct(self, r, ent) -> Optional[Any]:
        """Params for a ParamDelta answer; None when it cannot be built
        from local state (caller falls back to a full pull)."""
        if r.full:
            return r.params
        leaves = dict(r.leaves or {})
        for p, h in (getattr(r, "by_hash", None) or {}).items():
            leaf = self._hashes.get(h)
            if leaf is None:
                return None
            leaves[p] = leaf
        if ent is not None:
            return apply_delta(ent[1], leaves)
        # cross-key answer with no same-key base: every leaf must be in
        # hand, grafted onto any cached entry with the same leaf-path
        # set (the structural scaffold — values all come from `leaves`)
        want = set(r.manifest.leaf_hashes)
        if set(leaves) != want:
            return None
        for man2, params2 in self._cache.values():
            if set(man2.leaf_hashes) == want:
                return apply_delta(params2, leaves)
        return None

    def _reindex(self) -> None:
        """Rebuild the content-hash index from live cache entries (old
        versions' leaves drop out here — the store never outgrows the
        cache)."""
        self._hashes = {
            man.leaf_hashes[p]: leaf
            for man, params in self._cache.values()
            for p, leaf in flatten_with_paths(params)
        }

    def manifest(self, key) -> Optional[ParamManifest]:
        """The cached manifest (None if `key` was never pulled)."""
        ent = self._cache.get(key)
        return ent[0] if ent is not None else None

    def drop(self, key) -> None:
        if self._cache.pop(key, None) is not None:
            self._reindex()

    def clear(self) -> None:
        self._cache.clear()
        self._hashes.clear()
