"""Production meshes (TPU v5e pods; dry-run uses forced host devices).

Defined as FUNCTIONS so importing this module never touches jax device
state (dryrun.py must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """Single-host mesh for CPU tests (1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))


# TPU v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12      # FLOP/s
HBM_BW = 819e9                # B/s
ICI_BW = 50e9                 # B/s per link
