"""Serving drivers: the single-process decode demo AND the replica-fleet
gateway (the serving-gateway plane).

Decode demo (prefill + autoregressive serve_step for any assigned arch;
the InfServer data path at production layout, CPU-runnable on the
reduced variants):

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 16 [--sliding]

Standalone replica (one InfServer behind an RpcServer; prints
`REPLICA host:port` for fleet discovery, serves until killed — the unit
`serving.fleet.spawn_replica` manages and k8s deploys):

  PYTHONPATH=src python -m repro.launch.serve --replica \
      --bind 0.0.0.0:9006 --arch tleague-policy-s --env rps

Gateway fleet (spawn N local replica processes, front them with a
`ServingGateway`, roll a model out to the fleet and drive a short
deadline-tagged traffic demo — the one-command serving-plane smoke):

  PYTHONPATH=src python -m repro.launch.serve --replicas 4 \
      --arch tleague-policy-s --env rps --demo-rounds 50

On a pod, the same step functions lower under the production mesh with
serving shardings (TP-only weights + length-sharded cache — the §Perf-1
layout): see `repro.launch.steps.make_dryrun_step(..., fsdp=False,
shard_cache_len=True)`.
"""
from __future__ import annotations

import argparse
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import decode_step, init_params, prefill


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 64, new_tokens: int = 16, sliding: bool = False,
          temperature: float = 1.0, seed: int = 0, verbose: bool = True):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    assert not cfg.encoder_only, "encoder-only archs have no decode step"
    rng = jax.random.PRNGKey(seed)
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)

    window = 0
    if sliding and cfg.family != "ssm":
        window = cfg.long_context_window

    t0 = time.perf_counter()
    pf = jax.jit(lambda p, b: prefill(p, cfg, b, sliding=sliding))
    logits, values, state = pf(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    dstep = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s, window=window,
                                                uniform=True))
    out = []
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        lg, _, state = dstep(params, tok, state)
        rng, k = jax.random.split(rng)
        if temperature > 0:
            tok = jax.random.categorical(k, lg[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(lg[:, -1:], -1)
        tok = tok.astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = (time.perf_counter() - t0) / new_tokens
    if verbose:
        print(f"[serve] {cfg.name}: prefill({batch}x{prompt_len}) "
              f"{t_prefill*1e3:.1f}ms; decode {t_decode*1e3:.1f}ms/token "
              f"(window={window or 'full'})")
        print("[serve] sampled tokens[0]:",
              [int(t[0, 0]) for t in out])
    return out


def run_replica(*, arch: str = "tleague-policy-s", env_name: str = "rps",
                seed: int = 0, max_batch: int = 256,
                bind: str = "127.0.0.1:0", verbose: bool = True) -> None:
    """One standalone serving replica: an InfServer behind an RpcServer,
    no coordinator required (the gateway is its control plane). Prints
    the `REPLICA host:port` discovery banner and blocks until
    SIGTERM/SIGINT."""
    from repro.distributed.transport import (InfServerBackend, RpcServer,
                                             parse_addr)
    from repro.envs import make_env
    from repro.infserver import InfServer

    cfg = get_arch(arch)
    env = make_env(env_name)
    server = InfServer(cfg, env.spec.num_actions, seed=seed,
                       max_batch=max_batch)
    host, port = parse_addr(bind)
    rpc = RpcServer({"inf": InfServerBackend(server)},
                    host=host, port=port).start()
    print(f"REPLICA {rpc.address}", flush=True)
    done = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, lambda *_: done.set())
        except ValueError:                    # pragma: no cover - not main thread
            pass
    done.wait()
    rpc.close()
    if verbose:
        st = server.stats()
        print(f"[replica] served {st['rows_served']} rows over "
              f"{st['batches_run']} batches", flush=True)


def run_gateway(replica_endpoints, *, bind: str = "127.0.0.1:0",
                router: str = "lineage", max_inflight_rows: int = 4096,
                verbose: bool = True) -> None:
    """Serve a `ServingGateway` over RPC (namespace `inf`): every
    existing `InfServerClient` — and therefore every served Actor —
    talks to the replica FLEET through this address without knowing it.
    `replica_endpoints` is a comma-separated list (or list) of replica
    `host:port`s, e.g. the per-pod DNS names of the k8s StatefulSet.
    Blocks until SIGTERM/SIGINT."""
    from repro.distributed.transport import RpcServer, parse_addr
    from repro.serving import GatewayBackend, ServingGateway
    from repro.serving.fleet import connect

    if isinstance(replica_endpoints, str):
        replica_endpoints = [e.strip() for e in replica_endpoints.split(",")
                             if e.strip()]
    gw = ServingGateway([connect(ep) for ep in replica_endpoints],
                        router=router,
                        max_inflight_rows=max_inflight_rows).start()
    host, port = parse_addr(bind)
    rpc = RpcServer({"inf": GatewayBackend(gw)}, host=host,
                    port=port).start()
    print(f"GATEWAY {rpc.address} fronting "
          f"{len(replica_endpoints)} replicas", flush=True)
    done = threading.Event()
    for sig in (signal.SIGTERM, signal.SIGINT):
        try:
            signal.signal(sig, lambda *_: done.set())
        except ValueError:                    # pragma: no cover - not main thread
            pass
    done.wait()
    rpc.close()
    gw.stop()
    if verbose:
        st = gw.stats()
        print(f"[gateway] {st['rows']} rows over {st['requests']} requests, "
              f"shed {st['shed_requests']}, failovers {st['failovers']}",
              flush=True)


def serve_fleet(replicas: int, *, arch: str = "tleague-policy-s",
                env_name: str = "rps", seed: int = 0,
                demo_rounds: int = 50, demo_rows: int = 8,
                deadline_ms: float = 250.0, verbose: bool = True) -> dict:
    """Spawn `replicas` local replica processes, front them with a
    `ServingGateway`, roll the demo model out to the fleet (probe-gated)
    and drive `demo_rounds` of deadline-tagged traffic across two
    lineages. Returns the gateway stats dict; the fleet is torn down
    before returning."""
    from repro.core import ModelKey
    from repro.envs import make_env
    from repro.params.manifest import build_manifest
    from repro.serving import ServingGateway
    from repro.serving.fleet import connect, shutdown, spawn_fleet

    cfg = get_arch(arch)
    env = make_env(env_name)
    params = init_params(jax.random.PRNGKey(seed), cfg)
    fleet = spawn_fleet(replicas, arch=arch, env_name=env_name,
                        base_seed=seed)
    try:
        gw = ServingGateway([connect(r.address) for r in fleet]).start()
        keys = [ModelKey("main", 0), ModelKey("exploiter", 0)]
        for key in keys:
            report = gw.rollout(key, params,
                                build_manifest(params, version=0))
            if verbose:
                print(f"[gateway] rollout {key}: shipped to "
                      f"{report['shipped_to']}/{replicas} replicas "
                      f"({report['bytes_shipped']} bytes, "
                      f"{report['propagation_ms']:.1f}ms)", flush=True)
        obs_len = env.spec.obs_len
        rng = np.random.default_rng(seed)
        t0 = time.perf_counter()
        for _ in range(demo_rounds):
            tickets = [gw.submit(
                rng.integers(0, 8, (demo_rows, obs_len)).astype(np.int32),
                model=keys[rng.integers(len(keys))],
                deadline_s=deadline_ms / 1e3) for _ in range(replicas)]
            for t in tickets:
                gw.get(t)
        dt = time.perf_counter() - t0
        st = gw.stats()
        if verbose:
            served = st["rows"]
            print(f"[gateway] {replicas} replicas: {served} rows in "
                  f"{dt:.2f}s ({served / dt:,.0f} rows/s), "
                  f"deadlines: {st['deadlines']}", flush=True)
        gw.stop()
        return st
    finally:
        shutdown(fleet)


def main():
    ap = argparse.ArgumentParser()
    # default depends on mode: the decode demo wants a decoder arch, the
    # replica/fleet modes serve the league policy
    ap.add_argument("--arch", default=None)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sliding", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    # serving-gateway plane
    ap.add_argument("--replica", action="store_true",
                    help="run one standalone InfServer replica (RPC) "
                         "until killed; prints 'REPLICA host:port'")
    ap.add_argument("--replicas", type=int, default=0, metavar="N",
                    help="spawn an N-replica local fleet behind a "
                         "ServingGateway and run the traffic demo")
    ap.add_argument("--gateway", action="store_true",
                    help="serve a ServingGateway over RPC fronting "
                         "--replica-endpoints (the k8s gateway pod)")
    ap.add_argument("--replica-endpoints", default="",
                    help="comma-separated replica host:port list for "
                         "--gateway")
    ap.add_argument("--router", default="lineage",
                    choices=("lineage", "least_loaded", "round_robin"))
    ap.add_argument("--max-inflight-rows", type=int, default=4096)
    ap.add_argument("--env", dest="env_name", default="rps")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-batch", type=int, default=256)
    ap.add_argument("--bind", default="127.0.0.1:0")
    ap.add_argument("--demo-rounds", type=int, default=50)
    ap.add_argument("--demo-rows", type=int, default=8)
    ap.add_argument("--deadline-ms", type=float, default=250.0)
    args = ap.parse_args()
    if args.replica:
        run_replica(arch=args.arch or "tleague-policy-s",
                    env_name=args.env_name, seed=args.seed,
                    max_batch=args.max_batch, bind=args.bind)
        return
    if args.gateway:
        assert args.replica_endpoints, "--gateway needs --replica-endpoints"
        run_gateway(args.replica_endpoints, bind=args.bind,
                    router=args.router,
                    max_inflight_rows=args.max_inflight_rows)
        return
    if args.replicas > 0:
        serve_fleet(args.replicas, arch=args.arch or "tleague-policy-s",
                    env_name=args.env_name, seed=args.seed,
                    demo_rounds=args.demo_rounds, demo_rows=args.demo_rows,
                    deadline_ms=args.deadline_ms)
        return
    serve(args.arch or "gemma2-2b", smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, new_tokens=args.new_tokens,
          sliding=args.sliding, temperature=args.temperature)


if __name__ == "__main__":
    main()
