"""Serving driver: prefill + autoregressive serve_step for any assigned
arch (the InfServer data path at production layout; CPU-runnable on the
reduced variants).

  PYTHONPATH=src python -m repro.launch.serve --arch gemma2-2b --smoke \
      --batch 4 --prompt-len 64 --new-tokens 16 [--sliding]

On a pod, the same step functions lower under the production mesh with
serving shardings (TP-only weights + length-sharded cache — the §Perf-1
layout): see `repro.launch.steps.make_dryrun_step(..., fsdp=False,
shard_cache_len=True)`.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.models import decode_step, init_params, prefill


def serve(arch: str, *, smoke: bool = True, batch: int = 4,
          prompt_len: int = 64, new_tokens: int = 16, sliding: bool = False,
          temperature: float = 1.0, seed: int = 0, verbose: bool = True):
    cfg = get_arch(arch)
    if smoke:
        cfg = cfg.smoke()
    assert not cfg.encoder_only, "encoder-only archs have no decode step"
    rng = jax.random.PRNGKey(seed)
    params = init_params(rng, cfg)
    toks = jax.random.randint(rng, (batch, prompt_len), 0, cfg.vocab_size)

    window = 0
    if sliding and cfg.family != "ssm":
        window = cfg.long_context_window

    t0 = time.perf_counter()
    pf = jax.jit(lambda p, b: prefill(p, cfg, b, sliding=sliding))
    logits, values, state = pf(params, {"tokens": toks})
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    dstep = jax.jit(lambda p, t, s: decode_step(p, cfg, t, s, window=window,
                                                uniform=True))
    out = []
    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(new_tokens):
        lg, _, state = dstep(params, tok, state)
        rng, k = jax.random.split(rng)
        if temperature > 0:
            tok = jax.random.categorical(k, lg[:, -1] / temperature)[:, None]
        else:
            tok = jnp.argmax(lg[:, -1:], -1)
        tok = tok.astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(tok)
    t_decode = (time.perf_counter() - t0) / new_tokens
    if verbose:
        print(f"[serve] {cfg.name}: prefill({batch}x{prompt_len}) "
              f"{t_prefill*1e3:.1f}ms; decode {t_decode*1e3:.1f}ms/token "
              f"(window={window or 'full'})")
        print("[serve] sampled tokens[0]:",
              [int(t[0, 0]) for t in out])
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma2-2b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--sliding", action="store_true")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()
    serve(args.arch, smoke=args.smoke, batch=args.batch,
          prompt_len=args.prompt_len, new_tokens=args.new_tokens,
          sliding=args.sliding, temperature=args.temperature)


if __name__ == "__main__":
    main()
