"""Multiprocess league launch: the PR 3 thread seams as process boundaries.

The event-driven runtime (`repro.league.runtime`) already communicates
only through the decoupled-service seams; this module places those seams
on the `repro.distributed.transport` RPC layer so LeagueMgr/ModelPool,
each Learner, each Actor and a shared (optionally mesh-sharded) InfServer
run as separate OS processes — the paper's §3.4 hybrid-cluster layout,
with TCP standing in for ZeroMQ.

Process roles (each is `python -m repro.launch.train --role <role>`):

  * **coordinator** — owns LeagueMgr + ModelPool (and the shared InfServer
    unless a separate `--role infserver` process is launched), serves them
    over one RPC socket, runs the freeze/stop control plane (`ctrl`
    namespace: endpoint registry, learner step reports, the stop flag).
  * **learner** (one per role) — hosts its role's DataServer behind its
    own RPC socket (registered with the coordinator so actors can find
    it), pulls θ from the remote ModelPool, drains the ring, pushes θ
    back, polls `should_freeze` at step boundaries and executes freezes
    through `LeagueMgrClient.end_learning_period` — params cross the wire,
    so the pool entry stays authoritative exactly as in-process.
  * **actor** — requests tasks and reports results against the remote
    LeagueMgr, ships trajectory segments into its role's remote DataServer
    (`put_when_room`: ring-full backpressure crosses the process
    boundary), and in `--served` mode routes every policy forward through
    the shared serving mesh via `InfServerClient`.
  * **infserver** — a standalone serving process hosting the grouped θ+φ
    forward, mesh-sharded over the local devices with `--sharded`.

`run_multiprocess` (`train.py --workers N`) is the one-command form: the
parent becomes the coordinator and spawns one learner process per role
plus N actor processes (round-robin over roles), then tears everything
down on the stop condition and prints the merged report.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import threading
import time
from typing import Dict, List, Optional

from repro.distributed.heartbeat import (BeatRegistry, Heartbeat,
                                         HeartbeatMonitor)
from repro.distributed.transport import (DataServerClient, FaultPlan,
                                         InfServerClient, LeagueMgrClient,
                                         ModelPoolClient, RetryableError,
                                         RpcClient, RpcServer, TransportError,
                                         serve_league)

_POLL_S = 0.05
_HEARTBEAT_INTERVAL_S = 1.0
DEFAULT_HEARTBEAT_TIMEOUT_S = 30.0
# lease plane defaults: an actor that neither finishes a segment nor beats
# the ctrl plane for ACTOR_STALE_S is presumed dead and its lease reaped;
# the TTL itself is the backstop for actors that never identified themselves
DEFAULT_LEASE_TTL_S = 30.0
DEFAULT_ACTOR_STALE_S = 10.0
_REAP_INTERVAL_S = 1.0
# in-process restart budget for crashed actor children (run_multiprocess);
# mirrored into the k8s renderer's backoff annotations
DEFAULT_ACTOR_RESTARTS = 2


class Ctrl:
    """Coordinator control plane, served under the `ctrl` namespace: a
    process-boundary replacement for the runtime's in-process Coordinator
    thread state. All methods are called over RPC from worker processes;
    the lock makes them linearizable (the RpcServer runs one thread per
    connection). `ping` exposes the coordinator heartbeat — workers run a
    `HeartbeatMonitor` against it so a WEDGED coordinator (stopped,
    deadlocked, partitioned — sockets open, no progress) is
    distinguished from a merely slow one and triggers clean shutdown
    instead of an eternal blocked recv."""

    def __init__(self):
        self._lock = threading.Lock()
        self._stop = False
        self._endpoints: Dict[str, str] = {}
        self._steps: Dict[str, int] = {}
        self._segments: Dict[str, int] = {}
        self._frames: Dict[str, int] = {}
        self.heartbeat = Heartbeat()
        self.beats = BeatRegistry()     # per-actor liveness (lease reaper feed)

    # -- liveness -----------------------------------------------------------
    def ping(self) -> int:
        """Current beat count of the coordinator's beater thread."""
        return self.heartbeat.ping()

    # -- stop flag ----------------------------------------------------------
    def stop(self) -> None:
        with self._lock:
            self._stop = True

    def should_stop(self) -> bool:
        with self._lock:
            return self._stop

    # -- endpoint registry --------------------------------------------------
    def register_endpoint(self, name: str, address: str) -> None:
        """`name` is free-form (`data/<role>`, `inf/shared`); workers poll
        `endpoint` until the owning process has bound and registered."""
        with self._lock:
            self._endpoints[name] = address

    def endpoint(self, name: str) -> Optional[str]:
        with self._lock:
            return self._endpoints.get(name)

    # -- progress reports ---------------------------------------------------
    def report_learner(self, role: str, steps: int) -> None:
        with self._lock:
            self._steps[role] = steps

    def report_actor(self, actor_id: str, segments: int, frames: int) -> None:
        self.beats.beat(actor_id)       # a progress report IS a liveness beat
        with self._lock:
            self._segments[actor_id] = segments
            self._frames[actor_id] = frames

    def actor_beat(self, actor_id: str) -> int:
        """Explicit liveness beat: actors call this while waiting out
        DataServer backpressure, when segment completion (and therefore
        `report_actor`) can stall arbitrarily long on a slow learner —
        a backpressured actor must not look dead to the lease reaper."""
        return self.beats.beat(actor_id)

    def progress(self) -> dict:
        with self._lock:
            return {"learner_steps": dict(self._steps),
                    "actor_segments": dict(self._segments),
                    "frames_total": sum(self._frames.values())}


def _ctrl_client(address: str) -> RpcClient:
    return RpcClient(address)


def _wait_endpoint(ctrl: RpcClient, name: str, timeout: float = 60.0) -> str:
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        addr = ctrl.call("ctrl.endpoint", name)
        if addr:
            return addr
        time.sleep(_POLL_S)
    raise TimeoutError(f"endpoint {name!r} never registered with coordinator")


def _coordinator_alive(connect: str) -> bool:
    """Probe the coordinator with a fresh connection (the cached client's
    socket may be the thing that just died). Short socket timeout: a
    wedged coordinator that accepts but never answers counts as dead."""
    probe = RpcClient(connect, timeout=3.0, connect_retries=1,
                      retry_delay_s=0.01)
    try:
        probe.call("ctrl.should_stop")
        return True
    except TransportError:
        return False
    finally:
        probe.close()


def _start_monitor(connect: str, timeout_s: float, stop_event: threading.Event,
                   clients) -> HeartbeatMonitor:
    """Worker-side liveness: watch `ctrl.ping` on its own connection; on
    a stalled heartbeat set the stop flag and close the worker's RPC
    clients, turning any blocked in-flight `recv` into the
    `TransportError` the worker loops already treat as shutdown."""
    def _on_dead():
        stop_event.set()
        for c in clients:
            try:
                # abort, not close: the worker thread may be blocked in
                # recv HOLDING the client lock — shutdown wakes it with a
                # TransportError (close would deadlock/never wake it)
                getattr(c, "abort", c.close)()
            except Exception:            # noqa: BLE001 — best-effort unblock
                pass

    mon = HeartbeatMonitor(connect, interval_s=_HEARTBEAT_INTERVAL_S,
                           timeout_s=timeout_s, on_dead=_on_dead)
    mon.start()
    return mon


def _advertised(address: str) -> str:
    """What to publish in the ctrl endpoint registry for a socket bound at
    `address`: a wildcard bind (0.0.0.0 / ::) is reachable by nobody, so
    advertise this machine's hostname instead (inside k8s that resolves
    via the pod's Service). Loopback binds are advertised as-is — correct
    for the single-host default, never routable across hosts (bind
    0.0.0.0 for multi-host layouts)."""
    import socket

    host, _, port = address.rpartition(":")
    if host in ("0.0.0.0", "::", ""):
        return f"{socket.gethostname()}:{port}"
    return address


def _build_mesh(sharded: bool):
    if not sharded:
        return None
    from repro.launch.mesh import make_local_mesh
    return make_local_mesh()


# -- coordinator -------------------------------------------------------------
def run_coordinator(spec, *, env_name: str = "rps",
                    arch: str = "tleague-policy-s", seed: int = 0,
                    served: bool = False, sharded: bool = False,
                    pbt: bool = False, bind: str = "127.0.0.1:0",
                    max_seconds: Optional[float] = None,
                    max_steps_per_role: Optional[int] = None,
                    lease_ttl_s: Optional[float] = DEFAULT_LEASE_TTL_S,
                    actor_stale_s: float = DEFAULT_ACTOR_STALE_S,
                    fault_plan: Optional[FaultPlan] = None,
                    on_bound=None, verbose: bool = True) -> dict:
    """Host the league services and run the stop-condition loop. Blocks
    until `max_seconds` elapses or every role's learner reported
    `max_steps_per_role` steps, then raises the ctrl stop flag, lingers
    briefly so workers can observe it, and returns the final report.

    With NO stop condition the coordinator serves until something calls
    `ctrl.stop` over RPC (or the process is killed) — the k8s Deployment
    semantics, where the pod's lifetime is the run's lifetime.

    Liveness: a reaper thread classifies actors by their ctrl-plane beat
    age (`actor_stale_s`), extends the leases of live ones, and reaps the
    leases of stale/silent ones (`lease_ttl_s`; None disables the lease
    plane entirely). `fault_plan` (or the REPRO_FAULT_PLAN env var — the
    chaos smoke's cross-process seam) arms seeded fault injection on the
    serving socket."""
    import jax

    from repro.configs import get_arch
    from repro.distributed.transport import parse_addr
    from repro.envs import make_env
    from repro.infserver import InfServer
    from repro.league.roles import install_roles
    from repro.models import init_params

    env = make_env(env_name)
    cfg = get_arch(arch)
    rng = jax.random.PRNGKey(seed)
    league = install_roles(
        spec, lambda i: init_params(jax.random.fold_in(rng, i), cfg),
        pbt=pbt, seed=seed, lease_ttl_s=lease_ttl_s)
    inf_server = None
    if served:
        inf_server = InfServer(cfg, env.spec.num_actions, seed=seed + 7919,
                               max_batch=max(64, 16 * spec.num_actors_total),
                               mesh=_build_mesh(sharded))
    ctrl = Ctrl()
    # the beater thread is the liveness signal: it advances even when the
    # stop-condition loop below is busy, and stops only with the process
    ctrl.heartbeat.start_beating(_HEARTBEAT_INTERVAL_S)
    if fault_plan is None:
        fault_plan = FaultPlan.from_env()
        if fault_plan is not None and verbose:
            print(f"[coordinator] fault plan armed: {fault_plan.to_json()}",
                  flush=True)
    host, port = parse_addr(bind)
    server = serve_league(league, inf_server, extra={"ctrl": ctrl},
                          host=host, port=port, fault_plan=fault_plan)
    reaper_stop = threading.Event()

    def _reap_loop():
        while not reaper_stop.wait(_REAP_INTERVAL_S):
            alive, stale = ctrl.beats.split(actor_stale_s)
            for actor_id in alive:
                league.touch_actor(actor_id)
            reaped = league.reap_leases(dead_actors=stale)
            if reaped and verbose:
                print(f"[coordinator] reaped {len(reaped)} lease(s) "
                      f"(stale actors: {stale})", flush=True)

    reaper = None
    if lease_ttl_s is not None:
        reaper = threading.Thread(target=_reap_loop, name="lease-reaper",
                                  daemon=True)
        reaper.start()
    if inf_server is not None:
        ctrl.register_endpoint("inf/shared", _advertised(server.address))
    if on_bound is not None:
        on_bound(server.address)
    if verbose:
        print(f"[coordinator] serving league at {server.address} "
              f"(roles: {[r.name for r in spec]})", flush=True)
    t0 = time.monotonic()
    try:
        while not ctrl.should_stop():
            if max_seconds is not None and time.monotonic() - t0 >= max_seconds:
                break
            if max_steps_per_role is not None:
                steps = ctrl.progress()["learner_steps"]
                if (len(steps) == len(spec)
                        and all(s >= max_steps_per_role for s in steps.values())):
                    break
            time.sleep(_POLL_S)
        ctrl.stop()
        time.sleep(1.0)          # let workers observe the flag and detach
        report = {
            "wall_s": round(time.monotonic() - t0, 3),
            "progress": ctrl.progress(),
            "league": league.league_state(),
            "leases": league.lease_state(),
            "faults": fault_plan.stats() if fault_plan is not None else None,
            "serving": inf_server.stats() if inf_server is not None else None,
        }
        if verbose:
            print(f"[coordinator] done: {json.dumps(report['progress'])}",
                  flush=True)
            print(f"[coordinator] leases: {json.dumps(report['leases'])}",
                  flush=True)
        return report
    finally:
        ctrl.stop()
        reaper_stop.set()
        if reaper is not None:
            reaper.join(timeout=5.0)
        ctrl.heartbeat.stop_beating()
        server.close()


# -- learner -----------------------------------------------------------------
def run_learner(role_name: str, connect: str, *, env_name: str = "rps",
                arch: str = "tleague-policy-s", loss: str = "ppo",
                lr: float = 3e-4, seed: int = 0, num_envs: int = 8,
                unroll_len: int = 8, ring_segments: int = 4,
                data_bind: str = "127.0.0.1:0",
                advertise: Optional[str] = None,
                heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
                pool_endpoints: Optional[str] = None,
                verbose: bool = True) -> dict:
    """One role's Learner as a process: local DataServer (served to the
    role's actors over RPC), remote league protocol for everything else.
    `advertise` overrides the address registered for `data/<role>` —
    under k8s that is the learner's Service DNS name, which stays stable
    across pod restarts. A `HeartbeatMonitor` watches the coordinator:
    `heartbeat_timeout_s` without a beat advance and this process shuts
    down cleanly instead of blocking forever on a wedged socket.
    `pool_endpoints` (comma list) replicates the pool READ path across
    those endpoints; pushes stay pinned to the coordinator's pool."""
    from repro.configs import get_arch
    from repro.distributed.transport import parse_addr
    from repro.envs import make_env
    from repro.learners import DataServer, Learner, build_env_train_step
    from repro.optim import adamw

    env = make_env(env_name)
    cfg = get_arch(arch)
    league = LeagueMgrClient(connect, pool_endpoints=pool_endpoints)
    ctrl = _ctrl_client(connect)
    ctrl.call("ctrl.should_stop")    # probe: a bad endpoint fails loudly here
    coord_dead = threading.Event()
    monitor = _start_monitor(connect, heartbeat_timeout_s, coord_dead,
                             [ctrl, league])
    seg_frames = num_envs * env.spec.team_size * unroll_len
    ds = DataServer(capacity_frames=ring_segments * seg_frames, blocking=True)
    host, port = parse_addr(data_bind)
    data_srv = RpcServer({"data": ds}, host=host, port=port).start()
    try:
        ctrl.call("ctrl.register_endpoint", f"data/{role_name}",
                  advertise or _advertised(data_srv.address))

        opt = adamw(lr, clip_norm=1.0)
        step = build_env_train_step(cfg, env.spec.num_actions, opt, loss=loss)
        # warm-start from the role's CURRENT key, not version 0: a learner
        # process restarted mid-run (the k8s auto-restart path) must adopt
        # the lineage where it left off, not push seed weights over it
        current = league.agents[role_name].current
        learner = Learner(league, step, opt, league.model_pool.pull(current),
                          agent_id=role_name, data_server=ds)
        # the Learner snapshotted the boot pull and syncs through its own
        # CachedPuller from here on — drop the client cache's copy so a
        # model-sized allocation isn't pinned for the process lifetime
        league.model_pool.drop(current)
        period_steps, freezes = 0, 0
        while not coord_dead.is_set() and not ctrl.call("ctrl.should_stop"):
            reason = league.should_freeze(role_name, period_steps)
            if reason:
                new_key = learner.end_learning_period(reason=reason)
                freezes += 1
                period_steps = 0
                if verbose:
                    print(f"[learner/{role_name}] froze ({reason}) "
                          f"-> {new_key}", flush=True)
                continue
            if not ds.wait_ready(timeout=_POLL_S):
                continue
            if learner.learn(num_steps=1):
                period_steps += 1
                # one-way telemetry: nobody consumes a reply, so the train
                # loop no longer pays a ctrl round trip per step (the loop
                # condition's should_stop still detects a dead coordinator)
                ctrl.notify("ctrl.report_learner", role_name,
                            learner.step_count)
        steps = learner.step_count
    except TransportError as e:
        # the coordinator owns the run's lifetime: once we were connected,
        # its disappearance IS the shutdown signal, not a failure (the stop
        # flag and the socket close race — a worker mid-poll sees whichever
        # comes first; a heartbeat-timeout monitor closes our clients and
        # lands here too). A *connect* failure still raises out of RpcClient.
        if verbose:
            why = "heartbeat timed out" if coord_dead.is_set() else str(e)
            print(f"[learner/{role_name}] coordinator gone ({why}); "
                  "shutting down", flush=True)
        steps, freezes = -1, -1
    finally:
        monitor.stop()
        data_srv.close()
    return {"role": role_name, "steps": steps, "freezes": freezes,
            "heartbeat_dead": coord_dead.is_set()}


# -- actor -------------------------------------------------------------------
def run_actor(role_name: str, connect: str, *, actor_index: int = 0,
              env_name: str = "rps", arch: str = "tleague-policy-s",
              num_envs: int = 8, unroll_len: int = 8, seed: int = 0,
              served: bool = False,
              heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
              pool_endpoints: Optional[str] = None,
              verbose: bool = True) -> dict:
    """One Actor as a process: remote task/result protocol, remote
    DataServer put (with cross-process backpressure), and optionally the
    shared serving mesh for every policy forward. A `HeartbeatMonitor`
    watches the coordinator (see `run_learner`).

    Robustness: the actor names itself on every `request_task` so the
    coordinator can lease-track it, beats the ctrl plane while waiting
    out backpressure (a backpressured actor is slow, not dead), pulls
    params with failover across `pool_endpoints` when given, and treats
    an ambiguous segment ship (`RetryableError`) as a dropped segment —
    trajectory frames are data, losing one is cheaper than double-feeding
    the ring. Segment shipping is overlapped: `put_when_room_async` puts
    the rows on the wire immediately and the next segment's env steps run
    while the server waits out ring backpressure; beats and progress
    reports ride one-way notifies instead of round trips."""
    from repro.actors import Actor
    from repro.configs import get_arch
    from repro.envs import make_env

    env = make_env(env_name)
    cfg = get_arch(arch)
    league = LeagueMgrClient(connect, pool_endpoints=pool_endpoints)
    ctrl = _ctrl_client(connect)
    ctrl.call("ctrl.should_stop")    # probe: a bad endpoint fails loudly here
    actor_id = f"{role_name}/{actor_index}"
    segments = 0
    segments_dropped = 0
    coord_dead = threading.Event()
    clients = [ctrl, league]
    monitor = _start_monitor(connect, heartbeat_timeout_s, coord_dead, clients)
    try:
        data = DataServerClient(_wait_endpoint(ctrl, f"data/{role_name}"))
        clients.append(data)
        inf = None
        if served:
            inf = InfServerClient(_wait_endpoint(ctrl, "inf/shared"))
            clients.append(inf)
        actor = Actor(env, cfg, league, agent_id=role_name, num_envs=num_envs,
                      unroll_len=unroll_len,
                      seed=seed * 1000 + actor_index, inf_server=inf,
                      actor_id=actor_id)
        # the ship pipeline: at most ONE segment in flight. The rows go on
        # the wire (or the shm ring) the moment a segment completes; the
        # server-side backpressure wait then overlaps the NEXT segment's
        # env steps + inference instead of blocking the actor. Depth 1 is
        # deliberate — deeper would buffer trajectories actor-side exactly
        # when the learner is already the bottleneck.
        pending = None                     # (_ShipFuture, traj)

        def _settle(fut, traj):
            """Resolve one in-flight ship: re-submit on server-side
            ring-full timeouts, beat the ctrl plane while waiting (a
            backpressured actor is slow, not dead), drop the segment on
            an ambiguous failure. The server blocks on the ring condition
            for the whole timeout, so a LONG timeout means the segment is
            shipped once and waits server-side — client-side re-polling
            would re-serialize the full pytree 20x/s exactly when the
            learner is already the bottleneck."""
            nonlocal segments, segments_dropped
            while not coord_dead.is_set():
                try:
                    ok = fut.result(timeout=2.5)
                except TimeoutError:
                    ctrl.notify("ctrl.actor_beat", actor_id)  # slow != dead
                    continue
                except RetryableError:
                    # the learner may or may not have taken the segment (a
                    # restarting learner pod, a dropped reply): frames are
                    # data, not protocol state — drop it and move on rather
                    # than risk feeding the ring twice
                    segments_dropped += 1
                    return
                if ok:
                    segments += 1
                    return
                # server-side timeout: the ring stayed full — re-ship
                # unless the run is coming down anyway
                if ctrl.call("ctrl.should_stop"):
                    return
                ctrl.notify("ctrl.actor_beat", actor_id)
                fut = data.put_when_room_async(traj, timeout=2.0)

        while not coord_dead.is_set() and not ctrl.call("ctrl.should_stop"):
            traj, _task = actor.run_segment()
            if pending is not None:        # previous ship: await admission
                _settle(*pending)
                pending = None
            ctrl.notify("ctrl.actor_beat", actor_id)
            pending = (data.put_when_room_async(traj, timeout=2.0), traj)
            # one-way progress telemetry: no reply consumed, no round trip
            ctrl.notify("ctrl.report_actor", actor_id, segments,
                        actor.frames_produced)
        if pending is not None and not coord_dead.is_set():
            _settle(*pending)              # drain the in-flight ship
            pending = None
        frames = actor.frames_produced
    except TransportError as e:
        # a vanished coordinator is shutdown, not failure (see run_learner)
        # — but this handler also guards calls to the learner's DataServer
        # and the InfServer, whose death with a live coordinator is a REAL
        # failure that must surface (nonzero exit -> k8s restarts the pod)
        if not coord_dead.is_set() and _coordinator_alive(connect):
            raise
        if verbose:
            why = "heartbeat timed out" if coord_dead.is_set() else str(e)
            print(f"[actor/{actor_id}] coordinator gone ({why}); "
                  "shutting down", flush=True)
        frames = -1
    finally:
        monitor.stop()
    if verbose:
        print(f"[actor/{actor_id}] {segments} segments "
              f"({segments_dropped} dropped), {frames} frames", flush=True)
    return {"actor": actor_id, "segments": segments,
            "segments_dropped": segments_dropped, "frames": frames,
            "heartbeat_dead": coord_dead.is_set()}


# -- standalone inference server ---------------------------------------------
def run_infserver(connect: str, *, env_name: str = "rps",
                  arch: str = "tleague-policy-s", seed: int = 0,
                  sharded: bool = False, max_batch: int = 256,
                  bind: str = "127.0.0.1:0", advertise: Optional[str] = None,
                  heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
                  verbose: bool = True) -> dict:
    """A standalone serving process: host the grouped θ+φ forward
    (mesh-sharded over the local devices with `sharded=True`) and register
    as the shared `inf/shared` endpoint. Routes are installed lazily by
    served Actors (`update_params`/`ensure_model` over RPC).

    `advertise` overrides the registered address. REQUIRED for replicated
    deployments: N replicas each registering their own pod hostname under
    the single `inf/shared` key would last-write-win and leave N-1 idle —
    advertising the k8s Service name instead lets the Service spread
    actor connections across all replicas."""
    from repro.configs import get_arch
    from repro.distributed.transport import InfServerBackend, parse_addr
    from repro.envs import make_env
    from repro.infserver import InfServer

    env = make_env(env_name)
    cfg = get_arch(arch)
    server = InfServer(cfg, env.spec.num_actions, seed=seed,
                       max_batch=max_batch, mesh=_build_mesh(sharded))
    ctrl = _ctrl_client(connect)
    coord_dead = threading.Event()
    monitor = _start_monitor(connect, heartbeat_timeout_s, coord_dead, [ctrl])
    host, port = parse_addr(bind)
    rpc = RpcServer({"inf": InfServerBackend(server)},
                    host=host, port=port).start()
    try:
        ctrl.call("ctrl.register_endpoint", "inf/shared",
                  advertise or _advertised(rpc.address))
        if verbose:
            print(f"[infserver] serving at {rpc.address} "
                  f"(sharded={server.mesh is not None})", flush=True)
        while not coord_dead.is_set() and not ctrl.call("ctrl.should_stop"):
            time.sleep(_POLL_S)
    except TransportError:
        pass                         # coordinator gone == shutdown signal
    finally:
        monitor.stop()
        rpc.close()
    return server.stats()


# -- pool read replica --------------------------------------------------------
def run_pool_replica(connect: str, *, replica_index: int = 0,
                     sync_interval_s: float = 0.5,
                     bind: str = "127.0.0.1:0",
                     advertise: Optional[str] = None,
                     heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
                     verbose: bool = True) -> dict:
    """A ModelPool READ replica as a process — the paper's M_M pool
    instances. Follows the coordinator's authoritative pool over the
    manifest/delta protocol (an unchanged key per sync cycle costs one
    NotModified tag) and serves the read half of the pool protocol under
    the `pool` namespace, so actors pointed here via `--pool-endpoints`
    keep pulling through a primary-pool outage. Writes are refused —
    learners push to the coordinator. Registers as
    `pool/replica/<index>`; `advertise` overrides the published address
    (the k8s Service name for replicated Deployments)."""
    from repro.core.model_pool import ModelPoolReplica
    from repro.distributed.transport import parse_addr

    primary = ModelPoolClient(RpcClient(connect))
    ctrl = _ctrl_client(connect)
    ctrl.call("ctrl.should_stop")    # probe: a bad endpoint fails loudly here
    coord_dead = threading.Event()
    monitor = _start_monitor(connect, heartbeat_timeout_s, coord_dead,
                             [ctrl, primary])
    replica = ModelPoolReplica(primary, sync_interval_s=sync_interval_s)
    host, port = parse_addr(bind)
    srv = RpcServer({"pool": replica}, host=host, port=port).start()
    try:
        # first catch-up BEFORE advertising: by the time the endpoint is
        # discoverable the replica already serves the current pool
        try:
            replica.sync_once()
        except Exception:                # noqa: BLE001 — follower retries
            pass
        replica.start_following()
        ctrl.call("ctrl.register_endpoint", f"pool/replica/{replica_index}",
                  advertise or _advertised(srv.address))
        if verbose:
            print(f"[pool-replica/{replica_index}] serving pool replica at "
                  f"{srv.address} ({len(replica.keys())} keys)", flush=True)
        while not coord_dead.is_set() and not ctrl.call("ctrl.should_stop"):
            time.sleep(_POLL_S)
    except TransportError:
        if verbose:
            print(f"[pool-replica/{replica_index}] coordinator gone; "
                  "shutting down", flush=True)
    finally:
        monitor.stop()
        replica.stop()
        srv.close()
    stats = dict(replica.sync_stats)
    stats["heartbeat_dead"] = coord_dead.is_set()
    return stats


# -- one-command multiprocess launch ------------------------------------------
def _spawn_role(role: str, connect: str, extra: List[str],
                env_overrides: Optional[Dict[str, str]] = None) -> subprocess.Popen:
    cmd = [sys.executable, "-m", "repro.launch.train",
           "--role", role, "--connect", connect] + extra
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.dirname(os.path.dirname(os.path.dirname(
            os.path.abspath(__file__)))), env.get("PYTHONPATH")) if p)
    env.update(env_overrides or {})
    return subprocess.Popen(cmd, env=env)


def run_multiprocess(spec, *, workers: int, env_name: str = "rps",
                     arch: str = "tleague-policy-s", loss: str = "ppo",
                     num_envs: int = 8, unroll_len: int = 8, lr: float = 3e-4,
                     seed: int = 0, served: bool = False, sharded: bool = False,
                     pbt: bool = False,
                     max_seconds: Optional[float] = None,
                     max_steps_per_role: Optional[int] = None,
                     heartbeat_timeout_s: float = DEFAULT_HEARTBEAT_TIMEOUT_S,
                     max_actor_restarts: int = DEFAULT_ACTOR_RESTARTS,
                     verbose: bool = True) -> dict:
    """`train.py --workers N`: this process becomes the coordinator; one
    learner process per role plus `workers` actor processes (round-robin
    over roles, min one each) are spawned as `--role` children. Returns
    the coordinator report with per-child exit codes merged in.

    Actor supervision: a crashed actor child (nonzero exit while the run
    is live) is respawned with the same CLI up to `max_actor_restarts`
    times per slot — the respawn starts clean, requests a fresh task
    (fresh lease), and the reaper has already re-issued whatever the dead
    actor held. Learners are NOT respawned here (their in-memory
    optimizer state is the run); k8s restartPolicy owns that layer."""
    assert workers >= 1, "--workers needs at least one actor process"
    assert max_seconds is not None or max_steps_per_role is not None, \
        "--workers needs a stop condition (--max-seconds / --max-steps)"
    ctrl_box: Dict[str, object] = {}
    addr_ready = threading.Event()

    def _on_bound(address: str):
        ctrl_box["address"] = address
        addr_ready.set()

    def _coordinator():
        try:
            ctrl_box["report"] = run_coordinator(
                spec, env_name=env_name, arch=arch, seed=seed, served=served,
                sharded=sharded, pbt=pbt, max_seconds=max_seconds,
                max_steps_per_role=max_steps_per_role,
                on_bound=_on_bound, verbose=verbose)
        except BaseException as e:      # noqa: BLE001 — re-raised by parent
            ctrl_box["error"] = e
            addr_ready.set()            # unblock the parent if bind failed

    coord = threading.Thread(target=_coordinator, name="coordinator",
                             daemon=True)
    coord.start()
    assert addr_ready.wait(timeout=30.0), "coordinator failed to bind"
    if "error" in ctrl_box:
        raise RuntimeError("coordinator failed") from ctrl_box["error"]  # type: ignore[arg-type]
    address = str(ctrl_box["address"])

    common = ["--env", env_name, "--arch", arch, "--loss", loss,
              "--num-envs", str(num_envs), "--unroll-len", str(unroll_len),
              "--lr", str(lr), "--seed", str(seed),
              "--heartbeat-timeout", str(heartbeat_timeout_s)]
    if served:
        common.append("--served")
    # children as supervision records: actors carry their spawn args so a
    # crashed one can be relaunched; learners get restarts=None (never
    # respawned — their in-memory optimizer state IS the run)
    children: List[Dict[str, object]] = []
    for role in spec:
        args = common + ["--league-role", role.name]
        children.append({"proc": _spawn_role("learner", address, args),
                         "role": "learner", "args": args, "restarts": None})
    role_names = [r.name for r in spec]
    for w in range(workers):
        role = role_names[w % len(role_names)]
        args = common + ["--league-role", role, "--actor-index", str(w)]
        children.append({"proc": _spawn_role("actor", address, args),
                         "role": "actor", "args": args, "restarts": 0})

    def _run_stopping() -> bool:
        """True when the coordinator has raised (or lost) its stop flag —
        crashes during shutdown are expected, don't respawn into them."""
        try:
            return bool(RpcClient(address, connect_retries=1)
                        .call("ctrl.should_stop"))
        except TransportError:
            return True

    actor_restarts = 0
    # the coordinator loop owns the stop condition — but if every child
    # died (e.g. crashed on startup) a step-quota coordinator would wait
    # forever, so raise its ctrl stop flag through its own RPC socket
    while coord.is_alive():
        coord.join(timeout=1.0)
        if not coord.is_alive():
            break
        for rec in children:
            proc: subprocess.Popen = rec["proc"]           # type: ignore[assignment]
            if (rec["restarts"] is None or proc.poll() is None
                    or proc.returncode == 0):
                continue                   # learner / running / clean exit
            if rec["restarts"] >= max_actor_restarts or _run_stopping():  # type: ignore[operator]
                continue
            rec["restarts"] = int(rec["restarts"]) + 1     # type: ignore[arg-type]
            actor_restarts += 1
            if verbose:
                print(f"[supervisor] actor exited {proc.returncode}; "
                      f"respawn {rec['restarts']}/{max_actor_restarts} "
                      f"({' '.join(rec['args'][-2:])})", flush=True)  # type: ignore[index]
            rec["proc"] = _spawn_role("actor", address, list(rec["args"]))  # type: ignore[arg-type]
        if all(r["proc"].poll() is not None for r in children):  # type: ignore[union-attr]
            try:
                RpcClient(address, connect_retries=1).call("ctrl.stop")
            except TransportError:
                pass
            coord.join(timeout=30.0)
            break
    deadline = time.monotonic() + 30.0
    exit_codes = []
    for rec in children:
        c: subprocess.Popen = rec["proc"]                  # type: ignore[assignment]
        try:
            exit_codes.append(c.wait(
                timeout=max(0.1, deadline - time.monotonic())))
        except subprocess.TimeoutExpired:
            c.terminate()
            exit_codes.append(c.wait(timeout=10.0))
    if "error" in ctrl_box:
        # children saw the dead socket as shutdown and exited 0 — the
        # coordinator's own failure must still fail the run
        raise RuntimeError("coordinator crashed mid-run") from ctrl_box["error"]  # type: ignore[arg-type]
    report = dict(ctrl_box.get("report") or {})
    report["worker_exit_codes"] = exit_codes
    report["actor_restarts"] = actor_restarts
    report["clean_shutdown"] = all(code == 0 for code in exit_codes)
    return report
