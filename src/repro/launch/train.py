"""League training driver (the paper's full lifecycle, single-host scale).

Wires LeagueMgr + ModelPool + HyperMgr + GameMgr + Actors + Learner and runs
learning periods with freezes — the same modules the k8s deployment would
run as services (launch/k8s.py renders that spec).

Usage:
  PYTHONPATH=src python -m repro.launch.train --env pommerman_lite \
      --arch tleague-policy-s --game-mgr sp_pfsp --periods 3 --steps 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import GAME_MGRS, Hyperparam, LeagueMgr
from repro.core.game_mgr import GameMgr
from repro.envs import make_env
from repro.infserver import InfServer
from repro.learners import DataServer, Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw
from repro.rl.ppo import PPOConfig
from repro.checkpoint import save_league, save_pytree


def run_league_training(*, env_name="pommerman_lite", arch="tleague-policy-s",
                        game_mgr="sp_pfsp", loss="ppo", num_envs=16,
                        unroll_len=16, periods=2, steps_per_period=16,
                        num_actors=1, num_exploiters=0, pbt=False,
                        lr=3e-4, seed=0, log_every=8, checkpoint_dir=None,
                        served=False, verbose=True):
    """`served=True` runs the SEED-style actor mode (ROADMAP next step):
    every Actor routes its policy forwards through ONE shared
    continuous-batching InfServer instead of per-actor jitted forwards —
    θ and each lineage's φ ride the same grouped batch as server routes."""
    env = make_env(env_name)
    cfg = get_arch(arch)
    rng = jax.random.PRNGKey(seed)
    league = LeagueMgr(pbt=pbt, seed=seed)
    opt = adamw(lr, clip_norm=1.0)
    inf_server = None
    if served:
        # each rollout step submits one row per env-slot per actor; cap the
        # queue so a full actor sweep rides one grouped flush
        inf_server = InfServer(
            cfg, env.spec.num_actions, seed=seed + 7919,
            max_batch=max(64, num_envs * env.spec.num_agents * num_actors))

    agents = {}
    ids = ["main"] + [f"exploiter:{i}" for i in range(num_exploiters)]
    for i, aid in enumerate(ids):
        params = init_params(jax.random.fold_in(rng, i), cfg)
        gm_name = game_mgr if aid == "main" else "exploiter"
        gm = GAME_MGRS[gm_name](payoff=league.payoff, seed=seed + i)
        league.add_learning_agent(aid, params, game_mgr=gm)
        actors = [Actor(env, cfg, league, agent_id=aid, num_envs=num_envs,
                        unroll_len=unroll_len, seed=seed * 1000 + i * 100 + a,
                        inf_server=inf_server)
                  for a in range(num_actors)]
        step = build_env_train_step(cfg, env.spec.num_actions, opt, loss=loss)
        learner = Learner(league, step, opt, params, agent_id=aid,
                          data_server=DataServer())
        agents[aid] = (actors, learner)

    history = []
    t0 = time.time()
    for period in range(periods):
        for it in range(steps_per_period):
            for aid, (actors, learner) in agents.items():
                for actor in actors:
                    traj, _ = actor.run_segment()
                    learner.data_server.put(traj)
                m = learner.learn(num_steps=len(actors))
                if verbose and it % log_every == 0 and m:
                    tp = learner.data_server.throughput()
                    print(f"[train] p{period} it{it} {aid} "
                          f"loss={float(m['loss']):.3f} "
                          f"ent={float(m['entropy']):.3f} "
                          f"rfps={tp['rfps']:.0f} cfps={tp['cfps']:.0f}")
                row = {"period": period, "it": it, "agent": aid}
                if "loss" in m:
                    row["loss"] = float(m["loss"])
                else:
                    # learn() ran zero steps (DataServer not ready yet):
                    # mark the row instead of recording a bogus loss=nan
                    row["skipped"] = True
                history.append(row)
        for aid, (_, learner) in agents.items():
            new_key = learner.end_learning_period()
            if verbose:
                print(f"[train] period {period} end: {aid} froze -> {new_key}")

    state = league.league_state()
    state["wall_s"] = time.time() - t0
    if checkpoint_dir:
        save_league(f"{checkpoint_dir}/league.json", state)
        for aid, (_, learner) in agents.items():
            save_pytree(f"{checkpoint_dir}/{aid.replace(':', '_')}.npz",
                        learner.params)
    return league, agents, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="pommerman_lite")
    ap.add_argument("--arch", default="tleague-policy-s")
    ap.add_argument("--game-mgr", default="sp_pfsp", choices=sorted(GAME_MGRS))
    ap.add_argument("--loss", default="ppo", choices=["ppo", "vtrace"])
    ap.add_argument("--num-envs", type=int, default=16)
    ap.add_argument("--unroll-len", type=int, default=16)
    ap.add_argument("--periods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--actors", type=int, default=1)
    ap.add_argument("--exploiters", type=int, default=0)
    ap.add_argument("--pbt", action="store_true")
    ap.add_argument("--served", action="store_true",
                    help="route all actor inference through one shared "
                         "continuous-batching InfServer (SEED-style)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    args = ap.parse_args()
    league, _, _ = run_league_training(
        env_name=args.env, arch=args.arch, game_mgr=args.game_mgr,
        loss=args.loss, num_envs=args.num_envs, unroll_len=args.unroll_len,
        periods=args.periods, steps_per_period=args.steps,
        num_actors=args.actors, num_exploiters=args.exploiters, pbt=args.pbt,
        lr=args.lr, seed=args.seed, checkpoint_dir=args.checkpoint_dir,
        served=args.served)
    print(json.dumps(league.league_state(), indent=1))


if __name__ == "__main__":
    main()
