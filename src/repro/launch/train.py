"""League training driver (the paper's full lifecycle, single-host scale).

Wires LeagueMgr + ModelPool + HyperMgr + GameMgr + Actors + Learner and runs
learning periods with freezes — the same modules the k8s deployment would
run as services (launch/k8s.py renders that spec).

Three execution modes:

  * **async (default with `--league-spec`)** — the event-driven
    `repro.league.runtime`: every Actor and Learner on its own thread, a
    coordinator thread applying the spec's winrate-gated freeze decisions.
  * **sync (`--sync`, or no spec)** — the legacy lockstep nested loop with
    fixed `--periods x --steps` freezes; bit-deterministic under a fixed
    seed, kept as the determinism oracle for the async runtime.
  * **multiprocess (`--workers N`, or one `--role` per process)** — the
    thread seams as real process boundaries over the
    `repro.distributed.transport` RPC layer (the paper's §3.4 layout):
    `--workers N` forks one learner process per role plus N actor
    processes from a parent coordinator; alternatively run each role
    yourself with `--role {coordinator,learner,actor,infserver}
    --connect host:port`. Add `--served --sharded` for a mesh-sharded
    shared InfServer.

Usage:
  PYTHONPATH=src python -m repro.launch.train --env pommerman_lite \
      --arch tleague-policy-s --game-mgr sp_pfsp --periods 3 --steps 20
  PYTHONPATH=src python -m repro.launch.train --env rps \
      --league-spec examples/league_specs/main_minimax.json --max-seconds 10
  PYTHONPATH=src python -m repro.launch.train --env rps --workers 2 \
      --league-spec examples/league_specs/main_minimax.json --max-seconds 20
"""
from __future__ import annotations

import argparse
import json
import time

import jax

from repro.actors import Actor
from repro.configs import get_arch
from repro.core import GAME_MGRS, Hyperparam, LeagueMgr
from repro.core.game_mgr import GameMgr
from repro.envs import make_env
from repro.infserver import InfServer
from repro.launch import distributed as dist_defaults
from repro.league import LeagueSpec, build_runtime, make_game_mgr
from repro.learners import DataServer, Learner, build_env_train_step
from repro.models import init_params
from repro.optim import adamw
from repro.rl.ppo import PPOConfig
from repro.checkpoint import save_league, save_pytree


def run_league_training(*, env_name="pommerman_lite", arch="tleague-policy-s",
                        game_mgr="sp_pfsp", loss="ppo", num_envs=16,
                        unroll_len=16, periods=2, steps_per_period=16,
                        num_actors=1, num_exploiters=0, pbt=False,
                        lr=3e-4, seed=0, log_every=8, checkpoint_dir=None,
                        served=False, verbose=True, league_spec=None,
                        sampler="uniform"):
    """`served=True` runs the SEED-style actor mode (ROADMAP next step):
    every Actor routes its policy forwards through ONE shared
    continuous-batching InfServer instead of per-actor jitted forwards —
    θ and each lineage's φ ride the same grouped batch as server routes.

    `league_spec` (a LeagueSpec) builds the population from role specs —
    role matchmaking and reset-on-freeze policies apply, while freezing
    stays on the fixed `periods x steps_per_period` schedule (the `--sync`
    determinism path). Without a spec, the legacy main+N-exploiters layout
    is used.

    `sampler` picks the replay strategy per `repro.learners.samplers`;
    non-uniform samplers run each DataServer off-policy (blocking=False)
    so old rows stay sampleable."""
    env = make_env(env_name)
    cfg = get_arch(arch)
    rng = jax.random.PRNGKey(seed)
    league = LeagueMgr(pbt=pbt, seed=seed)
    opt = adamw(lr, clip_norm=1.0)
    if league_spec is not None:
        total_actors = league_spec.num_actors_total
    else:
        total_actors = num_actors * (1 + num_exploiters)
    inf_server = None
    if served:
        # each rollout step submits one row per env-slot per actor; cap the
        # queue so a full actor sweep rides one grouped flush
        inf_server = InfServer(
            cfg, env.spec.num_actions, seed=seed + 7919,
            max_batch=max(64, num_envs * env.spec.num_agents * total_actors))

    if league_spec is not None:
        role_rows = [(r.name, r.num_actors,
                      lambda payoff, s, r=r: make_game_mgr(r, payoff=payoff, seed=s),
                      dict(role=r.role, gate=None,           # fixed-period driver
                           reset_on_freeze=r.reset_policy))
                     for r in league_spec]
    else:
        ids = ["main"] + [f"exploiter:{i}" for i in range(num_exploiters)]
        role_rows = [(aid, num_actors,
                      lambda payoff, s, aid=aid: GAME_MGRS[
                          game_mgr if aid == "main" else "exploiter"](
                              payoff=payoff, seed=s),
                      {})
                     for aid in ids]

    agents = {}
    for i, (aid, n_act, gm_fn, extra) in enumerate(role_rows):
        params = init_params(jax.random.fold_in(rng, i), cfg)
        gm = gm_fn(league.payoff, seed + i)
        league.add_learning_agent(aid, params, game_mgr=gm, **extra)
        actors = [Actor(env, cfg, league, agent_id=aid, num_envs=num_envs,
                        unroll_len=unroll_len, seed=seed * 1000 + i * 100 + a,
                        inf_server=inf_server)
                  for a in range(n_act)]
        step = build_env_train_step(cfg, env.spec.num_actions, opt, loss=loss)
        learner = Learner(league, step, opt, params, agent_id=aid,
                          data_server=DataServer(
                              sampler=sampler,
                              blocking=(sampler == "uniform")))
        agents[aid] = (actors, learner)

    history = []
    t0 = time.time()
    for period in range(periods):
        for it in range(steps_per_period):
            for aid, (actors, learner) in agents.items():
                for actor in actors:
                    traj, _ = actor.run_segment()
                    learner.data_server.put(traj)
                m = learner.learn(num_steps=len(actors))
                if verbose and it % log_every == 0 and m:
                    tp = learner.data_server.throughput()
                    print(f"[train] p{period} it{it} {aid} "
                          f"loss={float(m['loss']):.3f} "
                          f"ent={float(m['entropy']):.3f} "
                          f"rfps={tp['rfps']:.0f} cfps={tp['cfps']:.0f}")
                row = {"period": period, "it": it, "agent": aid}
                if "loss" in m:
                    row["loss"] = float(m["loss"])
                else:
                    # learn() ran zero steps (DataServer not ready yet):
                    # mark the row instead of recording a bogus loss=nan
                    row["skipped"] = True
                history.append(row)
        for aid, (_, learner) in agents.items():
            new_key = learner.end_learning_period()
            if verbose:
                print(f"[train] period {period} end: {aid} froze -> {new_key}")

    state = league.league_state()
    state["wall_s"] = time.time() - t0
    if checkpoint_dir:
        save_league(f"{checkpoint_dir}/league.json", state)
        for aid, (_, learner) in agents.items():
            save_pytree(f"{checkpoint_dir}/{aid.replace(':', '_')}.npz",
                        learner.params)
    return league, agents, history


def run_league_training_async(spec, *, env_name="pommerman_lite",
                              arch="tleague-policy-s", loss="ppo",
                              num_envs=16, unroll_len=16, lr=3e-4, seed=0,
                              served=False, pbt=False, max_seconds=None,
                              max_freezes_per_role=None,
                              max_steps_per_role=None, verbose=True,
                              sampler="uniform"):
    """The event-driven league runtime: one thread per Actor and per
    Learner, a coordinator applying the spec's freeze gates. Returns
    (league, runtime, report); raises if any worker failed, so a normal
    return IS the clean-shutdown certificate."""
    runtime = build_runtime(spec, env_name=env_name, arch=arch, loss=loss,
                            num_envs=num_envs, unroll_len=unroll_len, lr=lr,
                            seed=seed, served=served, pbt=pbt,
                            sampler=sampler)
    report = runtime.run(max_seconds=max_seconds,
                         max_freezes_per_role=max_freezes_per_role,
                         max_steps_per_role=max_steps_per_role)
    if verbose:
        print(f"[train:async] {report['frames_total']} frames in "
              f"{report['wall_s']:.1f}s ({report['frames_per_s']:.0f} fps), "
              f"{report['league']['num_freezes']} freezes "
              f"(mean latency {report['freeze_latency_s_mean']}s)")
    return runtime.league, runtime, report


def _main_distributed(args, spec):
    """Dispatch the multiprocess modes (`--workers` / `--role`) onto
    `repro.launch.distributed`. Worker roles read the coordinator endpoint
    from `--connect` or the `LEAGUE_MGR_EP` env var (the name the k8s
    renderer injects; a `tcp://` scheme prefix is accepted and stripped)."""
    import os

    from repro.launch import distributed as dist

    def endpoint():
        ep = args.connect or os.environ.get("LEAGUE_MGR_EP", "")
        assert ep, f"--role {args.role} needs --connect or $LEAGUE_MGR_EP"
        return ep.removeprefix("tcp://")

    pool_eps = (args.pool_endpoints.split(",") if args.pool_endpoints
                else None)
    if args.workers is not None:
        assert args.role is None, "--workers spawns its own --role children"
        assert spec is not None, "--workers needs --league-spec"
        report = dist.run_multiprocess(
            spec, workers=args.workers, env_name=args.env, arch=args.arch,
            loss=args.loss, num_envs=args.num_envs,
            unroll_len=args.unroll_len, lr=args.lr, seed=args.seed,
            served=args.served, sharded=args.sharded, pbt=args.pbt,
            max_seconds=args.max_seconds, max_steps_per_role=args.max_steps,
            heartbeat_timeout_s=args.heartbeat_timeout,
            max_actor_restarts=args.max_actor_restarts)
        print(json.dumps(report, indent=1, default=str))
        assert report["clean_shutdown"], (
            f"worker exit codes: {report['worker_exit_codes']}")
    elif args.role == "coordinator":
        assert spec is not None, "--role coordinator needs --league-spec"
        report = dist.run_coordinator(
            spec, env_name=args.env, arch=args.arch, seed=args.seed,
            served=args.served, sharded=args.sharded, pbt=args.pbt,
            bind=args.bind, max_seconds=args.max_seconds,
            max_steps_per_role=args.max_steps,
            lease_ttl_s=(args.lease_ttl if args.lease_ttl > 0 else None),
            actor_stale_s=args.actor_stale)
        print(json.dumps(report, indent=1, default=str))
    elif args.role == "learner":
        dist.run_learner(args.league_role, endpoint(), env_name=args.env,
                         arch=args.arch, loss=args.loss, lr=args.lr,
                         seed=args.seed, num_envs=args.num_envs,
                         unroll_len=args.unroll_len, data_bind=args.bind,
                         advertise=args.advertise,
                         heartbeat_timeout_s=args.heartbeat_timeout,
                         pool_endpoints=pool_eps)
    elif args.role == "actor":
        dist.run_actor(args.league_role, endpoint(),
                       actor_index=args.actor_index, env_name=args.env,
                       arch=args.arch, num_envs=args.num_envs,
                       unroll_len=args.unroll_len, seed=args.seed,
                       served=args.served,
                       heartbeat_timeout_s=args.heartbeat_timeout,
                       pool_endpoints=pool_eps)
    elif args.role == "pool-replica":
        dist.run_pool_replica(endpoint(), replica_index=args.replica_index,
                              sync_interval_s=args.sync_interval,
                              bind=args.bind, advertise=args.advertise,
                              heartbeat_timeout_s=args.heartbeat_timeout)
    elif args.role == "infserver":
        dist.run_infserver(endpoint(), env_name=args.env, arch=args.arch,
                           seed=args.seed, sharded=args.sharded,
                           bind=args.bind, advertise=args.advertise,
                           heartbeat_timeout_s=args.heartbeat_timeout)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--env", default="pommerman_lite")
    ap.add_argument("--arch", default="tleague-policy-s")
    ap.add_argument("--game-mgr", default="sp_pfsp", choices=sorted(GAME_MGRS))
    ap.add_argument("--loss", default="ppo", choices=["ppo", "vtrace"])
    ap.add_argument("--num-envs", type=int, default=16)
    ap.add_argument("--collector-slots", type=int, default=None,
                    help="env slots per collector (the collector plane's "
                         "name for --num-envs; overrides it when given)")
    ap.add_argument("--sampler", default="uniform",
                    choices=["uniform", "prioritized", "episode"],
                    help="replay sampling strategy "
                         "(repro.learners.samplers); non-uniform samplers "
                         "run the DataServer off-policy")
    ap.add_argument("--unroll-len", type=int, default=16)
    ap.add_argument("--periods", type=int, default=2)
    ap.add_argument("--steps", type=int, default=16)
    ap.add_argument("--actors", type=int, default=1)
    ap.add_argument("--exploiters", type=int, default=0)
    ap.add_argument("--pbt", action="store_true")
    ap.add_argument("--served", action="store_true",
                    help="route all actor inference through one shared "
                         "continuous-batching InfServer (SEED-style)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--checkpoint-dir", default=None)
    ap.add_argument("--league-spec", default=None,
                    help="LeagueSpec JSON (roles + gates); runs the async "
                         "event-driven runtime unless --sync is given")
    ap.add_argument("--sync", action="store_true",
                    help="force the legacy lockstep loop (fixed-period "
                         "freezes; bit-deterministic under --seed)")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="async runtime: wall-clock stop condition")
    ap.add_argument("--max-freezes", type=int, default=None,
                    help="async runtime: stop once every role froze this "
                         "many times")
    ap.add_argument("--max-steps", type=int, default=None,
                    help="multiprocess mode: stop once every role's learner "
                         "reported this many steps")
    # -- multiprocess / distributed flags (repro.launch.distributed) ---------
    ap.add_argument("--workers", type=int, default=None,
                    help="spawn a multiprocess league: one learner process "
                         "per role plus N actor processes, this process "
                         "coordinating over the RPC transport")
    ap.add_argument("--role", default=None,
                    choices=["coordinator", "learner", "actor", "infserver",
                             "pool-replica"],
                    help="run exactly one league role in this process "
                         "(pair with --connect, or --bind for coordinator)")
    ap.add_argument("--league-role", default="main",
                    help="--role learner/actor: which LeagueSpec role this "
                         "process works for")
    ap.add_argument("--actor-index", type=int, default=0,
                    help="--role actor: index for seeding/telemetry")
    ap.add_argument("--connect", default=None,
                    help="coordinator endpoint host:port (worker roles); "
                         "defaults to $LEAGUE_MGR_EP")
    ap.add_argument("--bind", default="127.0.0.1:0",
                    help="listen address for the socket this role serves "
                         "(coordinator: league RPC; learner: its "
                         "DataServer; infserver: the serving RPC). Bind "
                         "0.0.0.0 for multi-host layouts — a wildcard "
                         "bind is advertised to peers as this hostname")
    ap.add_argument("--advertise", default=None,
                    help="--role learner/infserver: address to register "
                         "with the coordinator instead of the bound "
                         "socket (k8s: the Service DNS name, so replicas "
                         "load-balance and restarts keep the address)")
    ap.add_argument("--sharded", action="store_true",
                    help="with --served: shard the InfServer's grouped "
                         "forward over the local ('data','model') mesh")
    ap.add_argument("--heartbeat-timeout", type=float, default=30.0,
                    help="worker roles: seconds without a coordinator "
                         "heartbeat advance before this process treats "
                         "the coordinator as dead and shuts down cleanly")
    # -- robustness flags (leases / replicas / supervision) -------------------
    ap.add_argument("--pool-endpoints", default=None,
                    help="--role learner/actor: comma list of ModelPool "
                         "read endpoints (replicas first for actors, "
                         "coordinator first for learners); pulls fail over "
                         "across the list, writes stay on the coordinator")
    ap.add_argument("--replica-index", type=int, default=0,
                    help="--role pool-replica: index for telemetry and the "
                         "ctrl-plane endpoint name")
    ap.add_argument("--sync-interval", type=float, default=0.5,
                    help="--role pool-replica: seconds between primary "
                         "sync cycles")
    ap.add_argument("--lease-ttl", type=float,
                    default=dist_defaults.DEFAULT_LEASE_TTL_S,
                    help="coordinator: task-lease TTL in seconds; an "
                         "unreported task is re-issued after this long "
                         "without an actor beat extension (<=0 disables "
                         "the lease plane entirely)")
    ap.add_argument("--actor-stale", type=float,
                    default=dist_defaults.DEFAULT_ACTOR_STALE_S,
                    help="coordinator: seconds without an actor beat "
                         "before its leases are reaped immediately")
    ap.add_argument("--max-actor-restarts", type=int,
                    default=dist_defaults.DEFAULT_ACTOR_RESTARTS,
                    help="--workers mode: per-slot respawn budget for "
                         "crashed actor children")
    args = ap.parse_args()
    if args.collector_slots is not None:
        args.num_envs = args.collector_slots

    spec = LeagueSpec.from_json(args.league_spec) if args.league_spec else None
    if args.workers is not None or args.role is not None:
        _main_distributed(args, spec)
        return
    if spec is not None and not args.sync:
        league, _, report = run_league_training_async(
            spec, env_name=args.env, arch=args.arch, loss=args.loss,
            num_envs=args.num_envs, unroll_len=args.unroll_len, lr=args.lr,
            seed=args.seed, served=args.served, pbt=args.pbt,
            max_seconds=args.max_seconds, max_freezes_per_role=args.max_freezes,
            sampler=args.sampler)
        print(json.dumps(report, indent=1))
        return
    league, _, _ = run_league_training(
        env_name=args.env, arch=args.arch, game_mgr=args.game_mgr,
        loss=args.loss, num_envs=args.num_envs, unroll_len=args.unroll_len,
        periods=args.periods, steps_per_period=args.steps,
        num_actors=args.actors, num_exploiters=args.exploiters, pbt=args.pbt,
        lr=args.lr, seed=args.seed, checkpoint_dir=args.checkpoint_dir,
        served=args.served, league_spec=spec, sampler=args.sampler)
    print(json.dumps(league.league_state(), indent=1))


if __name__ == "__main__":
    main()
