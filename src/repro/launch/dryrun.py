import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): for every (arch x input-shape x mesh),
`.lower().compile()` the real step function with production shardings and
record memory/cost/collective analysis for the roofline (deliverable g).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--out DIR]
"""
import argparse      # noqa: E402
import json          # noqa: E402
import re            # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import get_arch  # noqa: E402
from repro.configs.base import INPUT_SHAPES  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import make_dryrun_step  # noqa: E402

ASSIGNED = [
    "qwen3-8b", "mistral-large-123b", "command-r-35b", "pixtral-12b",
    "rwkv6-3b", "hubert-xlarge", "gemma2-2b", "kimi-k2-1t-a32b",
    "qwen3-moe-235b-a22b", "hymba-1.5b",
]

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "s32": 4,
                "u64": 8, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
_SHAPE_RE = re.compile(r"\b(f64|f32|f16|bf16|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
                       r"f8e4m3fn|f8e5m2)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(dt: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dt]


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the optimized HLO."""
    per_op = {c: 0 for c in _COLLECTIVES}
    counts = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(?:\([^)]*\)|\S+)\s+"
                     r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                     r"collective-permute)(?:-start|-done)?\(", s)
        if not m:
            continue
        op = m.group(1)
        if "-done(" in s:
            continue  # counted at -start
        # operand shapes: everything inside the call parens
        call = s[s.index("("):]
        shapes = _SHAPE_RE.findall(call)
        b = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if b == 0:  # fall back to result shape
            shapes = _SHAPE_RE.findall(s)
            b = sum(_shape_bytes(dt, dims) for dt, dims in shapes[:1])
        per_op[op] += b
        counts[op] += 1
    per_op_counts = {f"n_{k}": v for k, v in counts.items()}
    return {"total": sum(per_op.values()), **per_op, **per_op_counts}


def _measure_shallow(cfg, shape, mesh, *, fsdp, shard_cache_len, remat,
                     moe_ep=False):
    """XLA cost analysis counts while-loop (scan) bodies ONCE, not x trips.
    Measure 1-unit and 2-unit UNROLLED variants and extrapolate:
        total = m(1) + (R_full - 1) * (m(2) - m(1)).
    Exact for per-layer-homogeneous stacks (all assigned archs)."""
    import dataclasses
    u = len(cfg.layer_pattern)
    fkd = cfg.moe.first_k_dense if cfg.moe else 0
    r_full = (cfg.num_layers - fkd) // u
    ms = []
    for reps in (1, 2):
        c = dataclasses.replace(cfg, num_layers=fkd + u * reps)
        with mesh:
            built = make_dryrun_step(c, shape, mesh, fsdp=fsdp,
                                     shard_cache_len=shard_cache_len,
                                     remat=remat, unroll=True, moe_ep=moe_ep)
            compiled = jax.jit(built["fn"], in_shardings=built["in_shardings"],
                               out_shardings=built["out_shardings"]
                               ).lower(*built["args"]).compile()
            cost = compiled.cost_analysis()
            if isinstance(cost, (list, tuple)):
                cost = cost[0]
            coll = collective_bytes(compiled.as_text())
            ms.append({"flops": float(cost.get("flops", 0.0)),
                       "bytes": float(cost.get("bytes accessed", 0.0)),
                       "coll": coll})

    def extrap(a, b):
        return a + (r_full - 1) * (b - a)

    out = {
        "flops": extrap(ms[0]["flops"], ms[1]["flops"]),
        "bytes": extrap(ms[0]["bytes"], ms[1]["bytes"]),
        "collective_bytes": extrap(ms[0]["coll"]["total"], ms[1]["coll"]["total"]),
        "per_unit_flops": ms[1]["flops"] - ms[0]["flops"],
        "per_unit_coll": ms[1]["coll"]["total"] - ms[0]["coll"]["total"],
        "units": r_full,
        "coll_breakdown": {k: extrap(ms[0]["coll"][k], ms[1]["coll"][k])
                           for k in _COLLECTIVES},
    }
    return out


def run_one(arch: str, shape: str, *, multi_pod: bool = False,
            fsdp: bool = True, shard_cache_len: bool = False,
            remat: bool = True, measure: bool = True, moe_ep: bool = False,
            verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "chips": 512 if multi_pod else 256,
           "fsdp": fsdp, "shard_cache_len": shard_cache_len, "remat": remat,
           "moe_ep": moe_ep,
           "params": cfg.param_count(), "active_params": cfg.active_param_count()}
    t0 = time.time()
    try:
        with mesh:
            built = make_dryrun_step(cfg, shape, mesh, fsdp=fsdp,
                                     shard_cache_len=shard_cache_len,
                                     remat=remat, moe_ep=moe_ep)
            if built["kind"] == "skip":
                rec["status"] = "skip"
                rec["reason"] = "encoder-only arch: no decode step (DESIGN.md)"
                return rec
            rec["kind"] = built["kind"]
            lowered = jax.jit(built["fn"],
                              in_shardings=built["in_shardings"],
                              out_shardings=built["out_shardings"]
                              ).lower(*built["args"])
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            rec["lower_s"] = round(t1 - t0, 1)
            rec["compile_s"] = round(t2 - t1, 1)

            try:
                mem = compiled.memory_analysis()
                rec["memory"] = {
                    k: int(getattr(mem, k))
                    for k in ("argument_size_in_bytes", "output_size_in_bytes",
                              "temp_size_in_bytes", "generated_code_size_in_bytes")
                    if hasattr(mem, k)}
            except Exception as e:  # CPU backend may not support it
                rec["memory"] = {"error": str(e)}

            try:
                cost = compiled.cost_analysis()
                if isinstance(cost, (list, tuple)):
                    cost = cost[0]
                rec["cost"] = {k: float(v) for k, v in cost.items()
                               if isinstance(v, (int, float)) and
                               (k in ("flops",) or k.startswith("bytes") or
                                k.startswith("utilization") or "transcendentals" in k)}
            except Exception as e:
                rec["cost"] = {"error": str(e)}

            hlo = compiled.as_text()
            rec["collectives"] = collective_bytes(hlo)
            rec["hlo_lines"] = hlo.count("\n")
            if measure:
                rec["measured"] = _measure_shallow(
                    cfg, shape, mesh, fsdp=fsdp,
                    shard_cache_len=shard_cache_len, remat=remat,
                    moe_ep=moe_ep)
            rec["status"] = "ok"
            if verbose:
                print(f"[dryrun] {arch} x {shape} x {rec['mesh']} "
                      f"({rec['kind']}): OK lower={rec['lower_s']}s "
                      f"compile={rec['compile_s']}s "
                      f"flops={rec['cost'].get('flops', -1):.3e} "
                      f"coll={rec['collectives']['total']:.3e}B")
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape}: FAIL {rec['error'][:200]}")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--shard-cache-len", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--moe-ep", action="store_true",
                    help="explicit shard_map expert parallelism (Perf-2)")
    ap.add_argument("--no-measure", action="store_true",
                    help="skip the 2-point unrolled cost measurement "
                         "(multi-pod pass: roofline is single-pod only)")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = [args.arch] if args.arch else ASSIGNED
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    os.makedirs(args.out, exist_ok=True)
    results = []
    for a in archs:
        for s in shapes:
            rec = run_one(a, s, multi_pod=args.multi_pod,
                          fsdp=not args.no_fsdp,
                          shard_cache_len=args.shard_cache_len,
                          remat=not args.no_remat,
                          measure=not args.no_measure, moe_ep=args.moe_ep)
            results.append(rec)
            tag = f"{a}_{s}_{rec['mesh']}" + ("" if not args.shard_cache_len else "_scl") \
                  + ("" if not args.no_fsdp else "_nofsdp") \
                  + ("" if not args.moe_ep else "_ep")
            with open(os.path.join(args.out, tag + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
    n_ok = sum(r["status"] == "ok" for r in results)
    n_skip = sum(r["status"] == "skip" for r in results)
    n_fail = sum(r["status"] == "fail" for r in results)
    print(f"[dryrun] done: {n_ok} ok, {n_skip} skip, {n_fail} fail "
          f"/ {len(results)} pairs")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
