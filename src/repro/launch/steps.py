"""Dry-run step factory: builds (fn, args, in_shardings, out_shardings) for
every (arch x input-shape x mesh) combination — the thing dryrun.py lowers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, INPUT_SHAPES
from repro.distributed.sharding import (batch_shardings, param_shardings,
                                        set_hint_mesh, state_shardings)
from repro.launch import specs as SP
from repro.learners.steps import build_mlm_train_step, build_seq_train_step
from repro.models import decode_step, init_params, prefill
from repro.optim import adamw


def make_optimizer(cfg: ArchConfig):
    return adamw(3e-4, clip_norm=1.0,
                 master_fp32=(cfg.param_dtype == "bfloat16"))


def _opt_shardings(opt_shapes, pshard, mesh):
    out = {"step": NamedSharding(mesh, P()), "mu": pshard, "nu": pshard}
    if "master" in opt_shapes:
        out["master"] = pshard
    return out


def _replicate_tree(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def make_dryrun_step(cfg: ArchConfig, shape_name: str, mesh, *,
                     fsdp: bool = True, shard_cache_len: bool = False,
                     loss: str = "ppo", remat: bool = True,
                     unroll: bool = False, q_chunk: int = 512,
                     uniform_lengths: bool = True, moe_ep: bool = False):
    """Returns dict(kind, fn, args, in_shardings, out_shardings) or
    dict(kind='skip')."""
    kind, sp = SP.input_specs(cfg, shape_name)
    if kind == "skip":
        return {"kind": "skip"}
    set_hint_mesh(mesh)   # in-graph shard_hints (MoE dispatch) resolve here
    from repro.models.moe import set_expert_parallel
    set_expert_parallel(moe_ep)   # §Perf-2: explicit shard_map expert parallelism

    params_shapes = jax.eval_shape(functools.partial(init_params, cfg=cfg),
                                   jax.random.PRNGKey(0))
    pshard = param_shardings(params_shapes, cfg, mesh, fsdp=fsdp)

    if kind in ("train", "mlm_train"):
        opt = make_optimizer(cfg)
        if kind == "train":
            fn = build_seq_train_step(cfg, opt, loss=loss, q_chunk=q_chunk,
                                      remat=remat, unroll=unroll, jit=False)
        else:
            fn = build_mlm_train_step(cfg, opt, remat=remat, unroll=unroll,
                                      jit=False)
        opt_shapes = jax.eval_shape(opt.init, params_shapes)
        oshard = _opt_shardings(opt_shapes, pshard, mesh)
        bshard = batch_shardings(sp, mesh)
        metrics_shapes = jax.eval_shape(fn, params_shapes, opt_shapes, sp)[2]
        return {
            "kind": kind, "fn": fn,
            "args": (params_shapes, opt_shapes, sp),
            "in_shardings": (pshard, oshard, bshard),
            "out_shardings": (pshard, oshard, _replicate_tree(metrics_shapes, mesh)),
        }

    if kind == "prefill":
        sliding = False

        def fn(params, batch):
            logits, values, state = prefill(params, cfg, batch,
                                            sliding=sliding, q_chunk=q_chunk,
                                            unroll=unroll)
            return logits[:, -1], values[:, -1], state

        bshard = batch_shardings(sp, mesh)
        B = INPUT_SHAPES[shape_name].global_batch
        out_state_shapes = jax.eval_shape(fn, params_shapes, sp)[2]
        sshard = state_shardings(out_state_shapes, cfg, mesh,
                                 shard_cache_len=shard_cache_len)
        dp_out = batch_shardings(
            (jax.ShapeDtypeStruct((B, cfg.vocab_size), jnp.float32),
             jax.ShapeDtypeStruct((B,), jnp.float32)), mesh)
        return {
            "kind": kind, "fn": fn,
            "args": (params_shapes, sp),
            "in_shardings": (pshard, bshard),
            "out_shardings": (dp_out[0], dp_out[1], sshard),
        }

    # decode
    shp = INPUT_SHAPES[shape_name]
    sliding = SP.uses_sliding(cfg, shp)
    window = 0
    if sliding and cfg.family != "ssm":
        # window == ring-buffer cache length
        kv0 = jax.tree_util.tree_leaves(sp["state"]["blocks"])[0]
        window = min(shp.seq_len, cfg.long_context_window)

    def fn(params, tokens, state):
        # uniform=True: serving batches decode in lockstep (same position
        # per row) -> dynamic_update_slice keeps the cache sharding intact.
        return decode_step(params, cfg, tokens, state, window=window,
                           unroll=unroll, uniform=uniform_lengths)

    sshard = state_shardings(sp["state"], cfg, mesh,
                             shard_cache_len=shard_cache_len)
    tshard = batch_shardings(sp["tokens"], mesh)
    B = shp.global_batch
    head_out = batch_shardings(
        (jax.ShapeDtypeStruct((B, 1, cfg.vocab_size), jnp.float32),
         jax.ShapeDtypeStruct((B, 1), jnp.float32)), mesh)
    return {
        "kind": kind, "fn": fn,
        "args": (params_shapes, sp["tokens"], sp["state"]),
        "in_shardings": (pshard, tshard, sshard),
        "out_shardings": (head_out[0], head_out[1], sshard),
    }
