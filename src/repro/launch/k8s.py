"""Cloud-native launcher: render the k8s spec for a distributed run (§3.4).

The paper prepares one yml.jinja2 per training ("56 Learners, 8 InfServers,
each Learner 1 GPU, every 7 Learners + 1 InfServer co-located...") and runs
`render_template | kubectl apply -f -`. This module is that renderer,
dependency-free: LeagueMgr/ModelPool/Learner/InfServer as Services, Actors
as a ReplicaSet (auto-restart on env crashes per the k8s imperative
semantics), nodeSelector co-location, all RL + league hyperparameters in
the spec. On a TPU cloud the Learner block becomes a JobSet over the pod
slice; the rendered spec is what `kubectl apply` would take.

  PYTHONPATH=src python -m repro.launch.k8s --learners 56 --inf-servers 8 \
      --actors-per-learner 16 | kubectl apply -f -   # (on a real cluster)
"""
from __future__ import annotations

import argparse

SERVICE_TMPL = """\
---
apiVersion: v1
kind: Service
metadata:
  name: {signature}-{role}
  labels: {{app: {signature}, role: {role}}}
spec:
  selector: {{app: {signature}, role: {role}}}
  ports: [{{port: {port}, targetPort: {port}}}]
---
apiVersion: apps/v1
kind: Deployment
metadata:
  name: {signature}-{role}
spec:
  replicas: {replicas}
  selector: {{matchLabels: {{app: {signature}, role: {role}}}}}
  template:
    metadata: {{labels: {{app: {signature}, role: {role}}}}}
    spec:
      nodeSelector: {{pool: {node_pool}}}
      containers:
      - name: {role}
        image: {image}
        command: ["python", "-m", "{module}"]
        args: {args}
        resources:
          requests: {{cpu: "{cpus}"{accel}}}
          limits: {{cpu: "{cpus}"{accel}}}
        env:
        - {{name: LEAGUE_MGR_EP, value: "tcp://{signature}-league-mgr:9003"}}
        - {{name: MODEL_POOL_EP, value: "tcp://{signature}-model-pool:9004"}}
"""


def render(*, signature="tleague", image="repro:latest", learners=8,
           inf_servers=2, actors_per_learner=16, model_pools=2,
           actor_cpus=4, learner_accel="google.com/tpu: 1",
           env="pommerman_lite", arch="tleague-policy-s",
           game_mgr="sp_pfsp", lr=3e-4):
    common = dict(signature=signature, image=image)
    blocks = []
    blocks.append(SERVICE_TMPL.format(
        role="league-mgr", port=9003, replicas=1, node_pool="cpu-highmem",
        module="repro.launch.train",
        args=f'["--env", "{env}", "--arch", "{arch}", "--game-mgr", "{game_mgr}", "--lr", "{lr}"]',
        cpus=8, accel="", **common))
    blocks.append(SERVICE_TMPL.format(
        role="model-pool", port=9004, replicas=model_pools,
        node_pool="cpu-highmem", module="repro.core.model_pool",
        args="[]", cpus=8, accel="", **common))
    blocks.append(SERVICE_TMPL.format(
        role="learner", port=9005, replicas=learners, node_pool="tpu-v5e",
        module="repro.launch.train", args='["--role", "learner"]',
        cpus=16, accel=", " + learner_accel, **common))
    blocks.append(SERVICE_TMPL.format(
        role="inf-server", port=9006, replicas=inf_servers,
        node_pool="tpu-v5e", module="repro.infserver.server", args="[]",
        cpus=8, accel=", " + learner_accel, **common))
    blocks.append(SERVICE_TMPL.format(
        role="actor", port=9007, replicas=learners * actors_per_learner,
        node_pool="cpu", module="repro.actors.actor", args="[]",
        cpus=actor_cpus, accel="", **common))
    return "".join(blocks)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--signature", default="tleague")
    ap.add_argument("--learners", type=int, default=8)
    ap.add_argument("--inf-servers", type=int, default=2)
    ap.add_argument("--actors-per-learner", type=int, default=16)
    ap.add_argument("--model-pools", type=int, default=2)
    ap.add_argument("--env", default="pommerman_lite")
    ap.add_argument("--arch", default="tleague-policy-s")
    args = ap.parse_args()
    print(render(signature=args.signature, learners=args.learners,
                 inf_servers=args.inf_servers,
                 actors_per_learner=args.actors_per_learner,
                 model_pools=args.model_pools, env=args.env, arch=args.arch))


if __name__ == "__main__":
    main()
