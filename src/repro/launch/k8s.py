"""Cloud-native launcher: render the k8s spec for a distributed run (§3.4).

The paper prepares one yml.jinja2 per training ("56 Learners, 8 InfServers,
each Learner 1 GPU, every 7 Learners + 1 InfServer co-located...") and runs
`render_template | kubectl apply -f -`. This module is that renderer,
dependency-free: the coordinator (LeagueMgr + ModelPool + ctrl plane),
Learners, InfServers as Services, Actors as a high-replica Deployment
(auto-restart on env crashes per the k8s imperative semantics),
nodeSelector co-location, all RL + league hyperparameters in the spec.

Every rendered command line is the REAL `repro.launch.train` CLI — the
same flags a laptop run uses (README "Mesh-sharded serving +
multiprocess league"):

  * coordinator: `--role coordinator --league-spec <path> [--served]`
    — hosts LeagueMgr + the AUTHORITATIVE ModelPool behind the RPC
    transport (`repro.distributed.transport`); all writes land here.
  * pool-replica: `--role pool-replica` — the paper's M_M ModelPool
    read replicas as their own Deployment: each follows the
    coordinator's pool via hash-gated delta pulls and serves the read
    protocol; actors pull through the replica Service first and fail
    over to the coordinator (`--pool-endpoints`).
  * learner:     `--role learner --league-role <role>` — finds the
    coordinator via the injected `LEAGUE_MGR_EP` env var.
  * actor:       `--role actor --league-role <role> [--served]`.
  * inf-server:  `--role infserver --sharded` — the mesh-sharded grouped
    θ+φ forward over the node's accelerator mesh.

Every pod carries liveness/readiness probes backed by the worker
heartbeat plane (`repro.distributed.heartbeat`): roles that bind an RPC
socket (coordinator / learner / inf-server) get tcpSocket probes on it,
and the portless actor Deployment execs the heartbeat probe CLI
(`python -m repro.distributed.heartbeat <coordinator> --timeout 5`) —
the same channel the workers themselves use to tell a slow coordinator
from a dead one (`--heartbeat-timeout`).

The single-host determinism fallback (no cluster) is the same image with
`--league-spec <path> --sync` — the bit-deterministic lockstep loop.
On a TPU cloud the Learner block becomes a JobSet over the pod slice;
the rendered spec is what `kubectl apply` would take.

  PYTHONPATH=src python -m repro.launch.k8s --learners 56 --inf-servers 8 \
      --actors-per-learner 16 | kubectl apply -f -   # (on a real cluster)
"""
from __future__ import annotations

import argparse

# the rendered restart-budget annotations mirror the in-process values so
# the two supervision layers agree: kubelet's crash-loop backoff takes over
# exactly where run_multiprocess's respawn budget and the RPC clients'
# retry deadline leave off
from repro.distributed.transport import RetryPolicy
from repro.launch.distributed import DEFAULT_ACTOR_RESTARTS

SERVICE_TMPL = """\
---
apiVersion: v1
kind: Service
metadata:
  name: {signature}-{role}
  labels: {{app: {signature}, role: {role}}}
spec:
  selector: {{app: {signature}, role: {role}}}
  ports: [{{port: {port}, targetPort: {port}}}]
---
apiVersion: apps/v1
kind: Deployment
metadata:
  name: {signature}-{role}
spec:
  replicas: {replicas}
  selector: {{matchLabels: {{app: {signature}, role: {role}}}}}
  template:
    metadata:
      labels: {{app: {signature}, role: {role}}}
{annotations}    spec:
      nodeSelector: {{pool: {node_pool}}}
      containers:
      - name: {role}
        image: {image}
        command: ["python", "-m", "{module}"]
        args: {args}
        resources:
          requests: {{cpu: "{cpus}"{accel}}}
          limits: {{cpu: "{cpus}"{accel}}}
{probes}        env:
        - {{name: LEAGUE_MGR_EP, value: "tcp://{signature}-coordinator:9003"}}
        - {{name: MODEL_POOL_EP, value: "tcp://{signature}-coordinator:9003"}}
"""

# roles that bind an RPC socket are probed on it (the accept loop IS the
# worker's liveness); portless roles (actors) exec the heartbeat probe
# CLI against the coordinator — an actor whose coordinator is gone or
# wedged exits by heartbeat timeout anyway, and the probe makes kubelet
# restart it promptly so the fleet reattaches when the coordinator
# Service comes back
_TCP_PROBES_TMPL = """\
        readinessProbe:
          tcpSocket: {{port: {port}}}
          initialDelaySeconds: 5
          periodSeconds: 10
          timeoutSeconds: 5
        livenessProbe:
          tcpSocket: {{port: {port}}}
          initialDelaySeconds: 20
          periodSeconds: 10
          timeoutSeconds: 5
          failureThreshold: 3
"""

# the serving-gateway fleet renders as a StatefulSet behind a HEADLESS
# Service: the gateway routes by lineage/occupancy across INDIVIDUAL
# replicas, so it needs the stable per-pod DNS names
# ({signature}-serve-replica-N.{signature}-serve-replica:port), not a
# load-balanced ClusterIP that would hide the fleet behind one VIP
STATEFULSET_TMPL = """\
---
apiVersion: v1
kind: Service
metadata:
  name: {signature}-{role}
  labels: {{app: {signature}, role: {role}}}
spec:
  clusterIP: None
  selector: {{app: {signature}, role: {role}}}
  ports: [{{port: {port}, targetPort: {port}}}]
---
apiVersion: apps/v1
kind: StatefulSet
metadata:
  name: {signature}-{role}
spec:
  serviceName: {signature}-{role}
  replicas: {replicas}
  selector: {{matchLabels: {{app: {signature}, role: {role}}}}}
  template:
    metadata:
      labels: {{app: {signature}, role: {role}}}
{annotations}    spec:
      nodeSelector: {{pool: {node_pool}}}
      containers:
      - name: {role}
        image: {image}
        command: ["python", "-m", "{module}"]
        args: {args}
        resources:
          requests: {{cpu: "{cpus}"{accel}}}
          limits: {{cpu: "{cpus}"{accel}}}
{probes}"""

# timeoutSeconds must cover interpreter startup + the probe's own
# --timeout 5 budget; k8s's 1s default would kill every slow-but-healthy
# probe run and restart the whole actor fleet
_EXEC_PROBE_TMPL = """\
        livenessProbe:
          exec:
            command: ["python", "-m", "repro.distributed.heartbeat",
                      "{coordinator}:9003", "--timeout", "5"]
          initialDelaySeconds: 30
          periodSeconds: 15
          timeoutSeconds: 15
          failureThreshold: 4
"""


def render(*, signature="tleague", image="repro:latest", learners=8,
           inf_servers=2, actors_per_learner=16, pool_replicas=1,
           serving_replicas=0, actor_cpus=4,
           learner_accel="google.com/tpu: 1",
           env="pommerman_lite", arch="tleague-policy-s",
           league_spec="/config/league_spec.json", league_role="main",
           served=True, lr=3e-4):
    """Render the full multiprocess league as k8s Services/Deployments.

    `league_spec` is the LeagueSpec JSON path inside the image (mount it
    via a ConfigMap); `league_role` is the role the rendered learner and
    actor blocks work for — render once per role for a multi-role league.
    `served=True` adds `--served` so actors route policy forwards through
    the sharded inf-server deployment (and only there: the coordinator
    must not also host one, or the two would race for the `inf/shared`
    endpoint). `learners` sizes the ACTOR fleet (learners ×
    actors_per_learner, the paper's co-location ratio); the learner
    Deployment itself is always replicas=1 per role — params are
    single-writer, and M_L data parallelism is inside the pjit step.

    `serving_replicas` > 0 renders the serving-gateway plane: a
    StatefulSet of standalone InfServer replicas (`repro.launch.serve
    --replica`) behind a HEADLESS Service (stable per-pod DNS), plus a
    gateway Deployment (`--gateway`) that fronts the individual replica
    endpoints with lineage routing, occupancy spill, deadline-bucket
    SLO flushes and admission control — external inference consumers
    (the millions-of-users path) connect to the gateway Service on
    9010 with the plain `InfServerClient` protocol. This fleet is
    separate from the league-internal `inf_servers` deployment: league
    actors keep their co-located sharded servers; the gateway fleet
    serves policy queries to the outside.

    `pool_replicas` > 0 renders the paper's M_M ModelPool replica fleet:
    a read-replica Deployment that follows the coordinator's pool via
    hash-gated delta pulls. Actors read pool state with the replica
    Service FIRST and the coordinator as fallback (`--pool-endpoints
    replica,coordinator`); learners keep the coordinator first (their
    post-freeze adopt must see the minted key immediately) with the
    replica as fallback. Writes always land on the coordinator — the
    client pins them regardless of the read path."""
    common = dict(signature=signature, image=image)
    base = ["--env", env, "--arch", arch]
    serve_flag = ["--served"] if served else []

    def fmt(args: list) -> str:
        return "[" + ", ".join(f'"{a}"' for a in args) + "]"

    def tcp_probes(port: int) -> str:
        return _TCP_PROBES_TMPL.format(port=port)

    exec_probe = _EXEC_PROBE_TMPL.format(coordinator=f"{signature}-coordinator")

    # crash-loop budget annotations: kubelet's restartPolicy Always +
    # exponential backoff picks up where the in-process layers stop, and
    # these annotations record the handoff point so an operator reading
    # the pod spec sees the SAME numbers the code enforces
    pol = RetryPolicy()
    restart_annotations = (
        "      annotations:\n"
        f"        repro.dev/in-process-restart-budget: \"{DEFAULT_ACTOR_RESTARTS}\"\n"
        f"        repro.dev/rpc-retry-backoff: "
        f"\"base={pol.base_s}s cap={pol.cap_s}s deadline={pol.deadline_s}s\"\n")

    coord_ep = f"{signature}-coordinator:9003"
    replica_ep = f"{signature}-pool-replica:9008"
    actor_pool_eps = ([replica_ep, coord_ep] if pool_replicas > 0
                      else None)
    learner_pool_eps = ([coord_ep, replica_ep] if pool_replicas > 0
                        else None)

    blocks = []
    # the coordinator must NOT get --served when dedicated inf-server
    # deployments exist: both would register the single `inf/shared`
    # endpoint and early actors would cache whichever won the race —
    # usually the coordinator's unsharded CPU server
    coord_serve = serve_flag if inf_servers == 0 else []
    blocks.append(SERVICE_TMPL.format(
        role="coordinator", port=9003, replicas=1, node_pool="cpu-highmem",
        module="repro.launch.train",
        args=fmt(["--role", "coordinator", "--league-spec", league_spec,
                  "--bind", "0.0.0.0:9003"] + base + coord_serve),
        cpus=8, accel="", probes=tcp_probes(9003), annotations="", **common))
    if pool_replicas > 0:
        # the M_M replica fleet: follows the coordinator's pool via delta
        # pulls, serves the read protocol to actors; restartPolicy Always
        # means a killed replica re-syncs and rejoins, and the actors'
        # failover client covers the gap from the coordinator directly
        blocks.append(SERVICE_TMPL.format(
            role="pool-replica", port=9008, replicas=pool_replicas,
            node_pool="cpu-highmem", module="repro.launch.train",
            args=fmt(["--role", "pool-replica", "--bind", "0.0.0.0:9008",
                      "--advertise", replica_ep] + base),
            cpus=4, accel="", probes=tcp_probes(9008),
            annotations=restart_annotations, **common))
    # ONE learner process per role: the lineage's params are single-writer
    # (see LeagueMgr.end_learning_period) — M_L-way data parallelism lives
    # INSIDE the learner's pjit'd train step over its node's mesh, not in
    # pod replicas. Render once per role for a multi-role league.
    blocks.append(SERVICE_TMPL.format(
        role="learner", port=9005, replicas=1, node_pool="tpu-v5e",
        module="repro.launch.train",
        args=fmt(["--role", "learner", "--league-role", league_role,
                  "--lr", str(lr), "--bind", "0.0.0.0:9005",
                  "--advertise", f"{signature}-learner:9005"] + base
                 + (["--pool-endpoints", ",".join(learner_pool_eps)]
                    if learner_pool_eps else [])),
        cpus=16, accel=", " + learner_accel, probes=tcp_probes(9005),
        annotations="", **common))
    blocks.append(SERVICE_TMPL.format(
        role="inf-server", port=9006, replicas=inf_servers,
        node_pool="tpu-v5e", module="repro.launch.train",
        args=fmt(["--role", "infserver", "--sharded",
                  "--bind", "0.0.0.0:9006",
                  "--advertise", f"{signature}-inf-server:9006"] + base),
        cpus=8, accel=", " + learner_accel, probes=tcp_probes(9006),
        annotations="", **common))
    if serving_replicas > 0:
        # the serving-gateway plane: replica StatefulSet (headless, so
        # the gateway sees individual pods) + the gateway front door
        replica_port, gateway_port = 9009, 9010
        blocks.append(STATEFULSET_TMPL.format(
            role="serve-replica", port=replica_port,
            replicas=serving_replicas, node_pool="tpu-v5e",
            module="repro.launch.serve",
            args=fmt(["--replica", "--bind", f"0.0.0.0:{replica_port}",
                      "--arch", arch, "--env", env]),
            cpus=8, accel=", " + learner_accel,
            probes=tcp_probes(replica_port),
            annotations=restart_annotations, **common))
        replica_eps = ",".join(
            f"{signature}-serve-replica-{i}.{signature}-serve-replica:"
            f"{replica_port}" for i in range(serving_replicas))
        blocks.append(SERVICE_TMPL.format(
            role="gateway", port=gateway_port, replicas=1,
            node_pool="cpu-highmem", module="repro.launch.serve",
            args=fmt(["--gateway", "--bind", f"0.0.0.0:{gateway_port}",
                      "--replica-endpoints", replica_eps,
                      "--router", "lineage"]),
            cpus=8, accel="", probes=tcp_probes(gateway_port),
            annotations=restart_annotations, **common))
    blocks.append(SERVICE_TMPL.format(
        role="actor", port=9007, replicas=learners * actors_per_learner,
        node_pool="cpu", module="repro.launch.train",
        args=fmt(["--role", "actor", "--league-role", league_role]
                 + base + serve_flag
                 + (["--pool-endpoints", ",".join(actor_pool_eps)]
                    if actor_pool_eps else [])),
        cpus=actor_cpus, accel="", probes=exec_probe,
        annotations=restart_annotations, **common))
    return "".join(blocks)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--signature", default="tleague")
    ap.add_argument("--learners", type=int, default=8)
    ap.add_argument("--inf-servers", type=int, default=2)
    ap.add_argument("--actors-per-learner", type=int, default=16)
    ap.add_argument("--pool-replicas", type=int, default=1,
                    help="ModelPool read-replica Deployment size (0 "
                         "renders the legacy coordinator-only read path)")
    ap.add_argument("--serving-replicas", type=int, default=0,
                    help="serving-gateway fleet size: N standalone "
                         "InfServer replicas (StatefulSet, headless "
                         "Service) behind one gateway Deployment (0 "
                         "renders no gateway plane)")
    ap.add_argument("--env", default="pommerman_lite")
    ap.add_argument("--arch", default="tleague-policy-s")
    ap.add_argument("--league-spec", default="/config/league_spec.json")
    ap.add_argument("--league-role", default="main")
    ap.add_argument("--no-served", dest="served", action="store_false")
    args = ap.parse_args()
    print(render(signature=args.signature, learners=args.learners,
                 inf_servers=args.inf_servers,
                 actors_per_learner=args.actors_per_learner,
                 pool_replicas=args.pool_replicas,
                 serving_replicas=args.serving_replicas,
                 env=args.env, arch=args.arch, league_spec=args.league_spec,
                 league_role=args.league_role, served=args.served))


if __name__ == "__main__":
    main()
