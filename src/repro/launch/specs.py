"""input_specs(): ShapeDtypeStruct stand-ins for every model input
(weak-type-correct, shardable, no device allocation) — the dry-run's
contract (deliverable e).

Step kinds per shape (assignment):
  train_4k    -> train_step (learner): trajectory batch; hubert -> MLM batch
  prefill_32k -> prefill (InfServer prefill / encoder forward)
  decode_32k  -> serve_step: ONE token + full KV cache of seq_len
  long_500k   -> serve_step with the sub-quadratic variant (ring-buffer
                 sliding-window cache for attention archs; O(1) SSM state)
Skips (DESIGN.md §4): hubert has no decode step.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, INPUT_SHAPES, InputShape
from repro.models import init_decode_state

NUM_PATCHES = 1024   # vlm stub frontend: patch embeddings per sequence

SDS = jax.ShapeDtypeStruct


def step_kind(cfg: ArchConfig, shape: InputShape) -> str:
    if shape.kind == "train":
        return "mlm_train" if cfg.encoder_only else "train"
    if shape.kind == "prefill":
        return "prefill"
    if cfg.encoder_only:
        return "skip"            # encoder-only: no decode step
    return "decode"


def uses_sliding(cfg: ArchConfig, shape: InputShape) -> bool:
    """long_500k runs the O(window) ring-buffer variant for attention archs."""
    return shape.kind == "decode" and shape.seq_len > 65536


def train_batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.encoder_only:
        return {
            "frame_embeds": SDS((B, S, cfg.d_model), cdt),
            "units": SDS((B, S), jnp.int32),
            "mask": SDS((B, S), jnp.bool_),
        }
    specs: Dict[str, Any] = {}
    s_tok = S
    if cfg.family == "vlm":
        specs["patch_embeds"] = SDS((B, NUM_PATCHES, cfg.d_model), cdt)
        s_tok = S - NUM_PATCHES
    specs["tokens"] = SDS((B, s_tok), jnp.int32)
    for f in ("behavior_logp", "behavior_values", "rewards", "discounts"):
        specs[f] = SDS((B, s_tok), jnp.float32)
    specs["actions"] = SDS((B, s_tok), jnp.int32)
    specs["bootstrap_value"] = SDS((B,), jnp.float32)
    return specs


def prefill_batch_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.encoder_only:
        return {"frame_embeds": SDS((B, S, cfg.d_model), cdt)}
    specs: Dict[str, Any] = {}
    s_tok = S
    if cfg.family == "vlm":
        specs["patch_embeds"] = SDS((B, NUM_PATCHES, cfg.d_model), cdt)
        s_tok = S - NUM_PATCHES
    specs["tokens"] = SDS((B, s_tok), jnp.int32)
    return specs


def decode_specs(cfg: ArchConfig, shape: InputShape) -> Tuple[Any, Any]:
    """Returns (token_specs, state_specs) via eval_shape (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sliding = uses_sliding(cfg, shape)
    state = jax.eval_shape(functools.partial(
        init_decode_state, cfg, B, S, sliding=sliding))
    return SDS((B, 1), jnp.int32), state


def input_specs(cfg: ArchConfig, shape_name: str):
    """(kind, specs) for one (arch, input-shape)."""
    shape = INPUT_SHAPES[shape_name]
    kind = step_kind(cfg, shape)
    if kind in ("train", "mlm_train"):
        return kind, train_batch_specs(cfg, shape)
    if kind == "prefill":
        return kind, prefill_batch_specs(cfg, shape)
    if kind == "decode":
        toks, state = decode_specs(cfg, shape)
        return kind, {"tokens": toks, "state": state}
    return "skip", None
