"""Flash-attention forward kernel (TPU Pallas).

TPU adaptation of the FlashAttention insight (online softmax over KV tiles so
the O(T^2) score matrix never leaves VMEM): the grid is
(batch, q_heads, num_q_blocks, num_kv_blocks) with the KV-block dimension
innermost, so the (block_q, head_dim) fp32 accumulator + running max/sum live
in VMEM scratch across the KV sweep and the MXU sees (block_q x head_dim) @
(head_dim x block_k) matmuls with hardware-aligned tiles (multiples of 128
by default). GQA is handled in the BlockSpec index maps (K/V indexed by
h // group), so no KV repeat ever materializes. Causal, sliding-window and
gemma2 logit-softcap masking are applied in-kernel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, window, cap, block_q, block_k, num_kv_blocks,
                  kv_len):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (innermost)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                    # (bq, d)
    k = k_ref[0, 0].astype(jnp.float32)                    # (bk, d)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len                                  # tail padding
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, 0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, scale, causal=True, window=0, cap=0.0,
                        block_q=128, block_k=128, kv_len=None, interpret=False):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d). Returns (B, H, Tq, d).

    Tq/Tk are padded to block multiples by the ops.py wrapper; `kv_len` is
    the true (unpadded) KV length for tail masking.
    """
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    nq = Tq // block_q
    nk = Tk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        kv_len=kv_len if kv_len is not None else Tk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
