"""Flash-attention forward + backward kernels (TPU Pallas).

TPU adaptation of the FlashAttention insight (online softmax over KV tiles so
the O(T^2) score matrix never leaves VMEM): the forward grid is
(batch, q_heads, num_q_blocks, num_kv_blocks) with the KV-block dimension
innermost, so the (block_q, head_dim) fp32 accumulator + running max/sum live
in VMEM scratch across the KV sweep and the MXU sees (block_q x head_dim) @
(head_dim x block_k) matmuls with hardware-aligned tiles (multiples of 128
by default). GQA is handled in the BlockSpec index maps (K/V indexed by
h // group), so no KV repeat ever materializes. Causal, sliding-window and
gemma2 logit-softcap masking are applied in-kernel.

The backward is the FlashAttention-2 recompute scheme — no O(T^2) residual
is ever stored, only the forward output and the per-row logsumexp:

  preprocess   delta_i = rowsum(dO_i * O_i)                 grid (B, H, nq)
  dq pass      recompute the (bq, bk) score tile, then
               dq_i += ds @ K * scale                       grid (B, H, nq, nk)
  dk/dv pass   same recompute swept the other way:
               dk_j += ds^T @ Q * scale, dv_j += p^T @ dO   grid (B, H, nk, nq)

with ds = p * (dp - delta) and the softcap chain rule ds *= 1 - tanh^2.
Fully-masked tiles (causal blocks above the diagonal, sliding-window blocks
behind the horizon) are skipped with a `pl.when` guard, so a windowed
backward does O(T * window) work like the forward.

The per-tile math lives in `_tile_grads`, which the dq kernel, the dk/dv
kernel AND the blockwise jnp mirror (`ref.attention_ref_bwd`) all call —
interpret-mode backward output is bit-identical to the mirror by
construction, which is what makes the kernel wiring bit-auditable on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref, m_ref, l_ref,
                  *, scale, causal, window, cap, block_q, block_k,
                  num_kv_blocks, kv_len, mixed):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (innermost)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # mixed (inference-only bf16 mode): feed the MXU the input dtype and
    # accumulate fp32 via preferred_element_type — training always upcasts
    q = q_ref[0, 0] if mixed else q_ref[0, 0].astype(jnp.float32)
    k = k_ref[0, 0] if mixed else k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0] if mixed else v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap

    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len                                  # tail padding
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_cur = jnp.max(s, axis=1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = alpha * l_prev + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype) if mixed else p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == num_kv_blocks - 1)
    def _done():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)                 # fully-masked rows
        o_ref[0, 0, ...] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)
        # logsumexp residual for the backward recompute. The l > 0 guard
        # matters: a fully-masked row stores lse = 0, so the backward's
        # p = exp(NEG_INF - 0) is exactly 0 (storing m + log(l) would give
        # exp(NEG_INF - NEG_INF) = 1 and poison dk/dv with ghost weights).
        lse_ref[0, 0, ...] = jnp.where(l > 0.0, m_ref[...] + jnp.log(safe), 0.0)


def flash_attention_fwd(q, k, v, *, scale, causal=True, window=0, cap=0.0,
                        block_q=128, block_k=128, kv_len=None, interpret=False,
                        mixed=False):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d).

    Returns (o (B, H, Tq, d), lse (B, H, Tq) fp32). Tq/Tk are padded to
    block multiples by the ops.py wrapper; `kv_len` is the true (unpadded)
    KV length for tail masking. `mixed` keeps the matmul inputs in the
    arrays' dtype (bf16 serving) with fp32 accumulation.
    """
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    assert H % KV == 0
    G = H // KV
    nq = Tq // block_q
    nk = Tk // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        kv_len=kv_len if kv_len is not None else Tk, mixed=mixed)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tq, d), q.dtype),
            jax.ShapeDtypeStruct((B, H, Tq), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)


# -- backward ------------------------------------------------------------------

def _tile_grads(q, k, v, do, lse, delta, i, j, *, scale, causal, window, cap,
                block_q, block_k, kv_len):
    """Score-tile recompute + dscore for one (q block i, kv block j).

    q, do: (block_q, d) fp32; k, v: (block_k, d) fp32; lse, delta:
    (block_q,) fp32. Returns (p, ds), both (block_q, block_k) fp32.

    This exact function body is executed by the Pallas dq and dk/dv kernels
    AND by the blockwise jnp mirror `ref.attention_ref_bwd` — same
    primitives in the same order — so interpret mode is bit-comparable
    against the mirror.
    """
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if cap:
        t = jnp.tanh(s / cap)
        s = t * cap
    q_pos = i * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    k_pos = j * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = k_pos < kv_len
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, NEG_INF)
    # p is the true softmax weight (masked entries: exp(NEG_INF - lse) = 0;
    # fully-masked rows carry lse = 0 from the forward, same result)
    p = jnp.exp(s - lse[:, None])
    dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    if cap:
        ds = ds * (1.0 - t * t)    # d tanh: masked entries already have ds = 0
    return p, ds


def _tile_live(i, j, *, causal, window, block_q, block_k):
    """False iff tile (i, j) is entirely masked (skippable). i/j may be
    traced program ids or python ints."""
    live = True
    if causal:       # min k_pos > max q_pos: block above the diagonal
        live = (j * block_k) <= (i * block_q + block_q - 1)
    if window:       # min q_pos - max k_pos >= window: behind the horizon
        w_live = (i * block_q) - (j * block_k + block_k - 1) < window
        live = jnp.logical_and(live, w_live) if causal else w_live
    return live


def _bwd_preprocess_kernel(o_ref, do_ref, delta_ref):
    o = o_ref[0, 0].astype(jnp.float32)
    do = do_ref[0, 0].astype(jnp.float32)
    delta_ref[0, 0, ...] = jnp.sum(o * do, axis=1)


def flash_attention_bwd_preprocess(o, do, *, block_q=128, interpret=False):
    """delta = rowsum(dO * O): (B, H, Tq) fp32, the softmax-grad row term."""
    B, H, Tq, d = o.shape
    return pl.pallas_call(
        _bwd_preprocess_kernel,
        grid=(B, H, Tq // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i: (b, h, i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q), lambda b, h, i: (b, h, i)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq), jnp.float32),
        interpret=interpret,
    )(o, do)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   dq_acc, *, scale, causal, window, cap, block_q, block_k,
                   num_kv_blocks, kv_len):
    i = pl.program_id(2)          # q block
    j = pl.program_id(3)          # kv block (innermost: dq accumulates)

    @pl.when(j == 0)
    def _init():
        dq_acc[...] = jnp.zeros_like(dq_acc)

    def _compute():
        k = k_ref[0, 0].astype(jnp.float32)
        _, ds = _tile_grads(
            q_ref[0, 0].astype(jnp.float32), k,
            v_ref[0, 0].astype(jnp.float32),
            do_ref[0, 0].astype(jnp.float32),
            lse_ref[0, 0], delta_ref[0, 0], i, j,
            scale=scale, causal=causal, window=window, cap=cap,
            block_q=block_q, block_k=block_k, kv_len=kv_len)
        dq_acc[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal or window:     # skip tiles that are entirely masked
        pl.when(_tile_live(i, j, causal=causal, window=window,
                           block_q=block_q, block_k=block_k))(_compute)
    else:
        _compute()

    @pl.when(j == num_kv_blocks - 1)
    def _done():
        dq_ref[0, 0, ...] = dq_acc[...]


def flash_attention_bwd_dq(q, k, v, do, lse, delta, *, scale, causal, window,
                           cap, block_q=128, block_k=128, kv_len=None,
                           interpret=False):
    """dq (B, H, Tq, d) fp32. Recomputes each score tile from q/k + lse."""
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Tq // block_q, Tk // block_k
    kernel = functools.partial(
        _bwd_dq_kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, num_kv_blocks=nk,
        kv_len=kv_len if kv_len is not None else Tk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, i, j: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, i, j: (b, h, i)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Tq, d), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal, window,
                    cap, block_q, block_k, num_q_blocks, kv_len):
    j = pl.program_id(2)          # kv block
    i = pl.program_id(3)          # q block (innermost: dk/dv accumulate)

    @pl.when(i == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        p, ds = _tile_grads(
            q, k_ref[0, 0].astype(jnp.float32),
            v_ref[0, 0].astype(jnp.float32), do,
            lse_ref[0, 0], delta_ref[0, 0], i, j,
            scale=scale, causal=causal, window=window, cap=cap,
            block_q=block_q, block_k=block_k, kv_len=kv_len)
        dv_acc[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())),
            preferred_element_type=jnp.float32) * scale

    if causal or window:
        pl.when(_tile_live(i, j, causal=causal, window=window,
                           block_q=block_q, block_k=block_k))(_compute)
    else:
        _compute()

    @pl.when(i == num_q_blocks - 1)
    def _done():
        dk_ref[0, 0, ...] = dk_acc[...]
        dv_ref[0, 0, ...] = dv_acc[...]


def flash_attention_bwd_dkv(q, k, v, do, lse, delta, *, scale, causal, window,
                            cap, block_q=128, block_k=128, kv_len=None,
                            interpret=False):
    """Per-q-head dk, dv: both (B, H, Tk, d) fp32.

    GQA: the kernel keeps one (bk, d) accumulator per *query* head — the
    sequential TPU grid revisits output blocks in grid order, so summing
    the G query heads of a group into one KV-head block would interleave
    other blocks between visits. The ops.py wrapper does the cheap
    (B, KV, G, Tk, d).sum(2) reduction instead.
    """
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Tq // block_q, Tk // block_k
    kernel = functools.partial(
        _bwd_dkv_kernel, scale=scale, causal=causal, window=window, cap=cap,
        block_q=block_q, block_k=block_k, num_q_blocks=nq,
        kv_len=kv_len if kv_len is not None else Tk)
    return pl.pallas_call(
        kernel,
        grid=(B, H, nk, nq),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, j, i: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, j, i: (b, h, i)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, j, i: (b, h, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Tk, d), jnp.float32),
            jax.ShapeDtypeStruct((B, H, Tk, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_k, d), jnp.float32),
            pltpu.VMEM((block_k, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
