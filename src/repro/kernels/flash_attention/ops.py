"""Jit'd wrapper for the flash-attention kernel: padding to block multiples,
GQA layout handling, and a custom_vjp whose backward pass recomputes through
the memory-safe chunked reference (flash backward is a follow-up kernel;
recompute-backward keeps training correct and HBM-light meanwhile)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def flash_attention(q, k, v, scale, causal=True, window=0, cap=0.0,
                    block_q=128, block_k=128, interpret=False):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d) -> (B, H, Tq, d)."""
    Tq, Tk = q.shape[2], k.shape[2]
    bq = min(block_q, max(Tq, 8))
    bk = min(block_k, max(Tk, 8))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    # padded q rows attend to real keys only (kv_len mask) and are sliced off.
    o = flash_attention_fwd(qp, kp, vp, scale=scale, causal=causal,
                            window=window, cap=cap, block_q=bq, block_k=bk,
                            kv_len=Tk, interpret=interpret)
    return o[:, :, :Tq]


def _fwd(q, k, v, scale, causal, window, cap, block_q, block_k, interpret):
    o = flash_attention(q, k, v, scale, causal, window, cap, block_q, block_k,
                        interpret)
    return o, (q, k, v)


def _bwd(scale, causal, window, cap, block_q, block_k, interpret, res, g):
    q, k, v = res

    def f(q, k, v):
        return attention_ref(q, k, v, scale=scale, causal=causal,
                             window=window, cap=cap)

    _, vjp = jax.vjp(f, q, k, v)
    return vjp(g)


flash_attention.defvjp(_fwd, _bwd)
