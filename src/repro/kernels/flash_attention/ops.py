"""Jit'd wrapper for the flash-attention kernels: padding to block multiples,
GQA layout handling, and a custom_vjp whose backward runs the real Pallas
dq/dk/dv kernels (FlashAttention-2 recompute tiling — residuals are just the
forward output and the per-row logsumexp, never an O(T^2) tensor).

Padding safety in the backward: dO is zero on padded q rows, so their delta
and dp vanish and they contribute nothing to dq/dk/dv; padded k rows are
masked by kv_len in the recomputed tile (p = ds = 0). Padded lse entries are
0 from the forward's fully-masked-row guard, which keeps exp() finite."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    flash_attention_bwd_dkv,
    flash_attention_bwd_dq,
    flash_attention_bwd_preprocess,
    flash_attention_fwd,
)


def _pad_to(x, axis, mult):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _run_fwd(q, k, v, scale, causal, window, cap, block_q, block_k, interpret,
             mixed):
    """Pad, launch the forward kernel, slice. Returns (o, lse) at true Tq."""
    Tq, Tk = q.shape[2], k.shape[2]
    bq = min(block_q, max(Tq, 8))
    bk = min(block_k, max(Tk, 8))
    qp = _pad_to(q, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    # padded q rows attend to real keys only (kv_len mask) and are sliced off.
    o, lse = flash_attention_fwd(qp, kp, vp, scale=scale, causal=causal,
                                 window=window, cap=cap, block_q=bq,
                                 block_k=bk, kv_len=Tk, interpret=interpret,
                                 mixed=mixed)
    return o[:, :, :Tq], lse[:, :, :Tq]


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(3, 4, 5, 6, 7, 8, 9, 10, 11, 12))
def flash_attention(q, k, v, scale, causal=True, window=0, cap=0.0,
                    block_q=128, block_k=128, interpret=False,
                    block_q_bwd=None, block_k_bwd=None, mixed=False):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d) -> (B, H, Tq, d).

    block_q_bwd/block_k_bwd size the backward kernels' tiles (their VMEM
    working set differs from the forward's — see
    `dispatch.attention_bwd_blocks`); they default to the forward blocks.
    `mixed` runs the matmuls in the input dtype with fp32 accumulation
    (inference-only; the backward always recomputes in fp32)."""
    o, _ = _run_fwd(q, k, v, scale, causal, window, cap, block_q, block_k,
                    interpret, mixed)
    return o


def _fwd(q, k, v, scale, causal, window, cap, block_q, block_k, interpret,
         block_q_bwd, block_k_bwd, mixed):
    o, lse = _run_fwd(q, k, v, scale, causal, window, cap, block_q, block_k,
                      interpret, mixed)
    return o, (q, k, v, o, lse)


def _bwd(scale, causal, window, cap, block_q, block_k, interpret,
         block_q_bwd, block_k_bwd, mixed, res, g):
    q, k, v, o, lse = res
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q_bwd or block_q, max(Tq, 8))
    bk = min(block_k_bwd or block_k, max(Tk, 8))
    qp = _pad_to(q, 2, bq)
    op = _pad_to(o, 2, bq)
    gp = _pad_to(g, 2, bq)
    lsep = _pad_to(lse, 2, bq)
    kp = _pad_to(k, 2, bk)
    vp = _pad_to(v, 2, bk)
    kw = dict(scale=scale, causal=causal, window=window, cap=cap,
              block_q=bq, block_k=bk, kv_len=Tk, interpret=interpret)
    delta = flash_attention_bwd_preprocess(op, gp, block_q=bq,
                                           interpret=interpret)
    dq = flash_attention_bwd_dq(qp, kp, vp, gp, lsep, delta, **kw)
    dkh, dvh = flash_attention_bwd_dkv(qp, kp, vp, gp, lsep, delta, **kw)
    # GQA: kernels emit per-q-head dk/dv; sum each group's G query heads
    # into its KV head (head h of group (h // G, h % G) — consecutive).
    Tkp = dkh.shape[2]
    dk = dkh.reshape(B, KV, G, Tkp, d).sum(2)
    dv = dvh.reshape(B, KV, G, Tkp, d).sum(2)
    return (dq[:, :, :Tq].astype(q.dtype),
            dk[:, :, :Tk].astype(k.dtype),
            dv[:, :, :Tk].astype(v.dtype))


flash_attention.defvjp(_fwd, _bwd)
