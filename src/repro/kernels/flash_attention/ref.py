"""Pure-jnp oracle for the flash-attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, scale, causal=True, window=0, cap=0.0,
                  kv_len=None):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d). fp32 softmax, GQA by repeat."""
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = jnp.arange(Tq)[:, None]
    kp = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if kv_len is not None:
        mask &= kp < kv_len
    if causal:
        mask &= kp <= qp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)
