"""Pure-jnp oracles + the production CPU fast path for flash-attention.

Three tiers live here:

- `attention_ref` — the full-T^2 oracle: materializes the (Tq, Tk) score
  matrix, GQA by `jnp.repeat`, fp32 softmax. The dispatch layer's
  "reference" tier; what every kernel and fast path is measured against.
- `attention_ref_chunked` — the "fast" tier on hosts without an
  accelerator: lax.scan over query blocks (O(block_q * Tk) live scores),
  and when a causal sliding window is active each block attends to a
  dynamic slice of block_q + window keys instead of all Tk — the same
  tile-skipping the Pallas kernels do with `pl.when` guards.
- `attention_ref_bwd` — the blockwise backward mirror for bit-auditing:
  executes the kernels' `_tile_grads` helper tile-by-tile with the same
  primitives in the same accumulation order as the interpret-mode Pallas
  backward, so tests can `np.array_equal` the two.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, scale, causal=True, window=0, cap=0.0,
                  kv_len=None):
    """q: (B, H, Tq, d); k, v: (B, KV, Tk, d). fp32 softmax, GQA by repeat."""
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if cap:
        s = jnp.tanh(s / cap) * cap
    qp = jnp.arange(Tq)[:, None]
    kp = jnp.arange(Tk)[None, :]
    mask = jnp.ones((Tq, Tk), bool)
    if kv_len is not None:
        mask &= kp < kv_len
    if causal:
        mask &= kp <= qp
    if window:
        mask &= qp - kp < window
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def attention_ref_chunked(q, k, v, *, scale, causal=True, window=0, cap=0.0,
                          kv_len=None, block_q=512):
    """Chunked jnp attention in kernel layout — the CPU "fast" tier.

    Scans query blocks so only an O(block_q, Tk) score block is live, and
    with a causal sliding window each block's keys come from a
    block_q + window dynamic slice (masked-out key blocks are never
    touched — the jnp analogue of the kernels' dead-tile skip). Falls back
    to the one-shot oracle when the sequence doesn't split."""
    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    if Tq <= block_q or Tq % block_q:
        return attention_ref(q, k, v, scale=scale, causal=causal,
                             window=window, cap=cap, kv_len=kv_len)
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    n = Tq // block_q
    qc = q.astype(jnp.float32).reshape(B, H, n, block_q, d).transpose(2, 0, 1, 3, 4)
    span = block_q + window
    windowed = causal and window and span < Tk

    def body(_, xs):
        qi, i = xs
        if windowed:
            start = jnp.clip((i + 1) * block_q - span, 0, Tk - span)
            ks = jax.lax.dynamic_slice_in_dim(kf, start, span, axis=2)
            vs = jax.lax.dynamic_slice_in_dim(vf, start, span, axis=2)
            kp = start + jnp.arange(span)[None, :]
        else:
            ks, vs = kf, vf
            kp = jnp.arange(Tk)[None, :]
        s = jnp.einsum("bhqd,bhkd->bhqk", qi, ks) * scale
        if cap:
            s = jnp.tanh(s / cap) * cap
        qp = i * block_q + jnp.arange(block_q)[:, None]
        mask = jnp.ones((block_q, kp.shape[1]), bool)
        if kv_len is not None:
            mask &= kp < kv_len
        if causal:
            mask &= kp <= qp
        if window:
            mask &= qp - kp < window
        s = jnp.where(mask, s, NEG_INF)
        w = jax.nn.softmax(s, axis=-1)
        return None, jnp.einsum("bhqk,bhkd->bhqd", w, vs)

    _, oc = jax.lax.scan(body, None, (qc, jnp.arange(n)))
    return oc.transpose(1, 2, 0, 3, 4).reshape(B, H, Tq, d).astype(q.dtype)


def attention_ref_bwd(q, k, v, o, lse, do, *, scale, causal=True, window=0,
                      cap=0.0, block_q=128, block_k=128, kv_len=None):
    """Blockwise jnp mirror of the Pallas backward — the bit-audit oracle.

    Inputs must already be padded to block multiples (as ops.py pads before
    launching the kernels). Runs the exact `_tile_grads` tile math the dq
    and dk/dv kernels run — same dot_general dimension numbers, same
    accumulation order (dq over ascending j, dk/dv over ascending i), same
    dead-tile skips — so the interpret-mode kernel outputs are bit-identical
    to these. Returns (dq, dk_per_head, dv_per_head), all fp32, dk/dv per
    *query* head (B, H, Tk, d), i.e. before the GQA group-sum.

    Python-loops over tiles, but each tile's math runs as ONE jitted step
    (the interpret-mode Pallas kernel body is also one jitted program, so
    eager per-primitive evaluation would see different XLA reduction
    fusion and drift by ~1 ulp — jitting the tile recovers bit-identity).
    A test oracle for small shapes, not a production path."""
    import functools

    import numpy as np

    from repro.kernels.flash_attention.kernel import _tile_grads, _tile_live

    B, H, Tq, d = q.shape
    KV, Tk = k.shape[1], k.shape[2]
    G = H // KV
    nq, nk = Tq // block_q, Tk // block_k
    if kv_len is None:
        kv_len = Tk
    f32 = jnp.float32

    @functools.partial(jax.jit, static_argnames=("acc_dk",))
    def _tile_step(qt, kt, vt, dot_t, ot, lset, i, j, dq_acc, dk_acc, dv_acc,
                   acc_dk=True):
        dot = jax.lax.dot_general
        delta = jnp.sum(ot * dot_t, axis=1)   # as _bwd_preprocess_kernel
        p, ds = _tile_grads(
            qt, kt, vt, dot_t, lset, delta, i, j, scale=scale,
            causal=causal, window=window, cap=cap,
            block_q=block_q, block_k=block_k, kv_len=kv_len)
        dq_acc = dq_acc + dot(ds, kt, (((1,), (0,)), ((), ())),
                              preferred_element_type=f32) * scale
        if acc_dk:
            dv_acc = dv_acc + dot(p, dot_t, (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32)
            dk_acc = dk_acc + dot(ds, qt, (((0,), (0,)), ((), ())),
                                  preferred_element_type=f32) * scale
        return dq_acc, dk_acc, dv_acc

    dq = np.zeros((B, H, Tq, d), np.float32)
    dkh = np.zeros((B, H, Tk, d), np.float32)
    dvh = np.zeros((B, H, Tk, d), np.float32)
    for b in range(B):
        for h in range(H):
            tiles_k = [(k[b, h // G, j * block_k:(j + 1) * block_k].astype(f32),
                        v[b, h // G, j * block_k:(j + 1) * block_k].astype(f32))
                       for j in range(nk)]
            for i in range(nq):
                qs = slice(i * block_q, (i + 1) * block_q)
                qt = q[b, h, qs].astype(f32)
                dot_t = do[b, h, qs].astype(f32)
                ot = o[b, h, qs].astype(f32)
                lset = lse[b, h, qs]
                dq_acc = jnp.zeros((block_q, d), f32)
                for j in range(nk):
                    if not bool(_tile_live(i, j, causal=causal, window=window,
                                           block_q=block_q, block_k=block_k)):
                        continue
                    kt, vt = tiles_k[j]
                    ks_ = slice(j * block_k, (j + 1) * block_k)
                    dq_acc, dk_new, dv_new = _tile_step(
                        qt, kt, vt, dot_t, ot, lset,
                        jnp.int32(i), jnp.int32(j), dq_acc,
                        jnp.asarray(dkh[b, h, ks_]), jnp.asarray(dvh[b, h, ks_]))
                    dkh[b, h, ks_] = np.asarray(dk_new)
                    dvh[b, h, ks_] = np.asarray(dv_new)
                dq[b, h, qs] = np.asarray(dq_acc)
    return jnp.asarray(dq), jnp.asarray(dkh), jnp.asarray(dvh)
