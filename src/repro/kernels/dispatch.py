"""Kernel dispatch: route the learner hot ops to their Pallas kernels.

One chokepoint decides, per call, whether an op runs as

  * ``compiled``  — the Pallas kernel lowered for the accelerator
                    (TPU/GPU backends),
  * ``interpret`` — the same kernel body executed by the Pallas
                    interpreter on CPU (bit-accurate wiring check; slow),
  * ``reference`` — the pure-jnp oracle (XLA-fused; the CPU fast path).

The decision is made at *trace time* from static information only (mode
string, default backend, shapes, dtypes), so every dispatch function is
jit-transparent: no traced value ever influences routing, and a jitted
train step caches one executable per (mode, shape) like any other static
argument.

Mode selection (checked in order):

  1. ``force(mode)`` context manager / ``set_mode(mode)`` — explicit
     override, used by tests and benchmarks.
  2. ``REPRO_KERNELS`` environment variable.
  3. default ``auto``.

Modes:

  ``auto``       Pallas on TPU/GPU, reference on CPU. The production
                 setting: tier-1 CPU tests and CPU benchmarks run the
                 XLA-fused references, accelerators get the fused kernels.
  ``pallas``     Pallas everywhere (interpret mode on CPU). For soak
                 testing the kernel path.
  ``interpret``  Pallas interpreter everywhere, even on accelerators.
                 For parity tests.
  ``reference``  jnp references everywhere, even on accelerators. The
                 escape hatch if a kernel misbehaves in production.

Block sizes are selected per shape from a small VMEM budget model (see
``_pick_block``): the largest power of two that fits both the dimension
and the per-block byte budget, floored at the dtype's sublane tile.
"""
from __future__ import annotations

import os
from contextlib import contextmanager

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention as _flash_attention
from repro.kernels.flash_attention.ref import attention_ref as _attention_ref
from repro.kernels.rmsnorm.ops import rmsnorm as _rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref as _rmsnorm_ref
from repro.kernels.vtrace_scan.ops import reverse_discounted_scan as _scan_pallas
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref as _scan_ref

MODES = ("auto", "pallas", "interpret", "reference")

# process-wide so the production escape hatch (set_mode('reference'))
# applies on every thread that dispatches ops, not just the caller's
_forced = None


def mode() -> str:
    """The active dispatch mode (forced > env > 'auto')."""
    if _forced is not None:
        return _forced
    m = os.environ.get("REPRO_KERNELS", "auto")
    return m if m in MODES else "auto"


def set_mode(m) -> None:
    """Force a mode process-wide (None restores env/auto resolution)."""
    global _forced
    assert m is None or m in MODES, f"mode {m!r} not in {MODES}"
    _forced = m


@contextmanager
def force(m):
    """Scoped mode override: ``with dispatch.force('interpret'): ...``.

    Mutates the process-wide mode for the duration of the block (nesting
    restores); not intended for concurrent use from multiple threads —
    tests and benchmarks drive it single-threaded.
    """
    prev = _forced
    set_mode(m)
    try:
        yield
    finally:
        set_mode(prev)


def resolve() -> str:
    """'compiled' | 'interpret' | 'reference' for the current call site."""
    m = mode()
    if m in ("reference", "interpret"):
        return m
    on_accel = jax.default_backend() in ("tpu", "gpu")
    if m == "pallas":
        return "compiled" if on_accel else "interpret"
    return "compiled" if on_accel else "reference"      # auto


def use_pallas() -> bool:
    """True when ops route to the kernel path (compiled or interpret)."""
    return resolve() != "reference"


# -- per-shape block selection -------------------------------------------------

def _sublane_floor(dtype) -> int:
    """Minimum second-to-last tile dim for the dtype (TPU tiling table)."""
    return {jnp.bfloat16: 16, jnp.int8: 32}.get(jnp.dtype(dtype).type, 8)


def _pick_block(n: int, row_bytes: int, *, floor: int = 8, cap: int = 128,
                budget: int = 1 << 21) -> int:
    """Largest power-of-two block <= cap whose rows fit the VMEM budget.

    `n` is the dimension being tiled, `row_bytes` the bytes one row of the
    block occupies in fp32 working precision. Never exceeds the smallest
    power of two covering `n` (a block bigger than the padded input is
    pure padding waste), never goes below `floor`.
    """
    b = floor
    limit = min(cap, max(budget // max(row_bytes, 1), floor))
    while b * 2 <= limit and b < n:
        b *= 2
    return b


def rmsnorm_block(R: int, d: int) -> int:
    return _pick_block(R, d * 4, cap=512)


def attention_blocks(Tq: int, Tk: int, d: int, dtype) -> tuple:
    floor = _sublane_floor(dtype)
    # the fp32 accumulator (block_q, d) plus the (block_q, block_k) score
    # tile dominate VMEM; budget each at ~2 MiB
    bq = _pick_block(Tq, d * 4, floor=floor)
    bk = _pick_block(Tk, max(bq, d) * 4, floor=floor)
    return bq, bk


def scan_block(B: int, T: int) -> int:
    return _pick_block(B, T * 4)


# -- dispatched ops ------------------------------------------------------------

def rmsnorm(x, w, *, eps: float = 1e-6):
    """Fused RMSNorm over the last axis. x: (..., d); w: (d,)."""
    impl = resolve()
    if impl == "reference":
        return _rmsnorm_ref(x, w, eps)
    R = max(1, x.size // x.shape[-1])
    return _rmsnorm_pallas(x, w, eps=eps,
                           block_r=rmsnorm_block(R, x.shape[-1]),
                           interpret=impl == "interpret")


def attention(q, k, v, *, scale, causal=True, window=0, cap=0.0):
    """Fused attention, kernel layout: q (B, H, Tq, d); k, v (B, KV, Tk, d).

    Callers with the model layout (B, T, H, d) transpose at the call site
    (see models/attention.chunked_attend). Backward runs through the
    memory-safe chunked reference (custom_vjp recompute).
    """
    impl = resolve()
    if impl == "reference":
        return _attention_ref(q, k, v, scale=scale, causal=causal,
                              window=window, cap=cap)
    bq, bk = attention_blocks(q.shape[2], k.shape[2], q.shape[3], q.dtype)
    return _flash_attention(q, k, v, scale, causal, window, cap, bq, bk,
                            impl == "interpret")


def reverse_scan(deltas, decays, init=None):
    """y_t = delta_t + decay_t * y_{t+1}, y_T = init. (B, T) -> (B, T) fp32.

    The one primitive behind GAE, TD(lambda), discounted returns and the
    V-trace correction sum (fused over the whole (B, T) minibatch instead
    of a lax.scan over T).
    """
    impl = resolve()
    if init is None:
        init = jnp.zeros((deltas.shape[0],), jnp.float32)
    if impl == "reference":
        return _scan_ref(deltas, decays, init)
    B, T = deltas.shape
    return _scan_pallas(deltas, decays, init, block_b=scan_block(B, T),
                        interpret=impl == "interpret")
