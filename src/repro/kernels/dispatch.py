"""Kernel dispatch: route the learner hot ops to their Pallas kernels.

One chokepoint decides, per call, which tier an op runs as:

  * ``compiled``  — the Pallas kernel lowered for the accelerator
                    (TPU/GPU backends),
  * ``interpret`` — the same kernel body executed by the Pallas
                    interpreter on CPU (bit-accurate wiring check; slow),
  * ``fast``      — the production jnp path on hosts without an
                    accelerator: chunked attention with windowed
                    key-slicing, closed-form-VJP scans. XLA-fused and
                    memory-safe, but algorithmically tiled like the
                    kernels.
  * ``reference`` — the pure-jnp *oracle*: full O(T^2) score matrix,
                    GQA by repeat, autodiff backward. What everything is
                    measured against; never the production path.

The decision is made at *trace time* from static information only (mode
string, default backend, shapes, dtypes), so every dispatch function is
jit-transparent: no traced value ever influences routing.

Trace-time caveat: the mode is NOT part of jax.jit's compilation cache
key (that key is the wrapped function object + argument avals). Jitting
the *same function object* under two different ``force()`` modes silently
reuses whichever executable was traced first. Anything that compares
modes (tests, benchmarks) must build a fresh closure per mode before
jitting — see ``benchmarks/run.py:learner_throughput``. Production code
picks one mode per process, so this never bites outside harnesses.

Mode selection (checked in order):

  1. ``force(mode)`` context manager / ``set_mode(mode)`` — explicit
     override, used by tests and benchmarks.
  2. ``REPRO_KERNELS`` environment variable.
  3. default ``auto``.

Modes:

  ``auto``       Pallas on TPU/GPU, the fast tier on CPU. The production
                 setting.
  ``pallas``     Pallas everywhere (interpret mode on CPU). For soak
                 testing the kernel path.
  ``interpret``  Pallas interpreter everywhere, even on accelerators.
                 For parity tests.
  ``reference``  the oracles everywhere. The measuring stick — and the
                 escape hatch if a kernel misbehaves in production.

Inference-only precision (`REPRO_KERNELS_INFER=bf16`): inside a
``serving()`` scope (the InfServer wraps its jitted act functions in
one) forwards run with bf16 matmul inputs and fp32 accumulation —
the serving fleet gets a cheaper forward without touching training
numerics. Outside a serving scope the flag is inert.

Block sizes are selected per shape from a small VMEM budget model (see
``_pick_block``): the largest power of two that fits both the dimension
and the per-block byte budget, floored at the dtype's sublane tile.
The backward runs under a separate, halved budget
(``attention_bwd_blocks``): the dk/dv accumulators and the score +
dscore tiles double the working set vs the forward.

Every resolution is counted in a process-wide telemetry counter —
``stats()`` returns ``{"op|tier|detail": count}`` so a misrouted
reference fallback shows up in learner/InfServer stats, not just in
benchmarks. Counts are *trace-time* events: under jit an op is counted
once per compilation, not once per step.
"""
from __future__ import annotations

import collections
import os
import threading
from contextlib import contextmanager

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.ops import flash_attention as _flash_attention
from repro.kernels.flash_attention.ref import attention_ref as _attention_ref
from repro.kernels.flash_attention.ref import (
    attention_ref_chunked as _attention_chunked,
)
from repro.kernels.rmsnorm.ops import rmsnorm as _rmsnorm_pallas
from repro.kernels.rmsnorm.ref import rmsnorm_ref as _rmsnorm_ref
from repro.kernels.vtrace_scan.ops import reverse_discounted_scan as _scan_pallas
from repro.kernels.vtrace_scan.ops import (
    reverse_discounted_scan_fast as _scan_fast,
)
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref as _scan_ref

MODES = ("auto", "pallas", "interpret", "reference")
INFER_MODES = ("bf16",)

# process-wide so the production escape hatch (set_mode('reference'))
# applies on every thread that dispatches ops, not just the caller's
_forced = None

# serving scope is per-thread: the InfServer's act path must not flip the
# learner thread's precision
_serving = threading.local()

_stats_lock = threading.Lock()
_stats = collections.Counter()


def mode() -> str:
    """The active dispatch mode (forced > env > 'auto')."""
    if _forced is not None:
        return _forced
    m = os.environ.get("REPRO_KERNELS", "auto")
    return m if m in MODES else "auto"


def set_mode(m) -> None:
    """Force a mode process-wide (None restores env/auto resolution)."""
    global _forced
    assert m is None or m in MODES, f"mode {m!r} not in {MODES}"
    _forced = m


@contextmanager
def force(m):
    """Scoped mode override: ``with dispatch.force('interpret'): ...``.

    Mutates the process-wide mode for the duration of the block (nesting
    restores); not intended for concurrent use from multiple threads —
    tests and benchmarks drive it single-threaded.
    """
    prev = _forced
    set_mode(m)
    try:
        yield
    finally:
        set_mode(prev)


def resolve() -> str:
    """'compiled' | 'interpret' | 'fast' | 'reference' for this call site."""
    m = mode()
    if m in ("reference", "interpret"):
        return m
    on_accel = jax.default_backend() in ("tpu", "gpu")
    if m == "pallas":
        return "compiled" if on_accel else "interpret"
    return "compiled" if on_accel else "fast"           # auto


def use_pallas() -> bool:
    """True when ops route to the kernel path (compiled or interpret)."""
    return resolve() in ("compiled", "interpret")


# -- inference-only precision --------------------------------------------------

@contextmanager
def serving():
    """Marks the enclosed trace as inference-only (the InfServer act path).

    Inside this scope `infer_mode()` reports the `REPRO_KERNELS_INFER`
    setting; outside it always returns None, so training traces can never
    pick up the reduced-precision path. Thread-local: a learner thread
    tracing concurrently is unaffected.
    """
    prev = getattr(_serving, "active", False)
    _serving.active = True
    try:
        yield
    finally:
        _serving.active = prev


def infer_mode():
    """'bf16' inside a serving() scope with REPRO_KERNELS_INFER=bf16,
    else None. Trace-time static, like mode()."""
    if not getattr(_serving, "active", False):
        return None
    m = os.environ.get("REPRO_KERNELS_INFER", "")
    return m if m in INFER_MODES else None


# -- telemetry -----------------------------------------------------------------

def note(op: str, tier: str, detail=()) -> None:
    """Count one dispatch resolution: key = 'op|tier[|detail...]'.

    Public so ops with a native fast path outside this module (e.g. the
    model layer's chunked attention) can register where they routed."""
    key = "|".join((op, tier) + tuple(detail))
    with _stats_lock:
        _stats[key] += 1


def stats(reset: bool = False) -> dict:
    """Snapshot of dispatch resolutions: {'op|tier|detail': count}.

    Counts trace-time events — under jit, one count per compilation (per
    static shape/mode), not per executed step. An unexpected
    'attention|reference|...' key in a production process is the signal
    the escape hatch (or a misroute) is active."""
    with _stats_lock:
        snap = dict(_stats)
        if reset:
            _stats.clear()
    return snap


def stats_reset() -> None:
    with _stats_lock:
        _stats.clear()


# -- per-shape block selection -------------------------------------------------

def _sublane_floor(dtype) -> int:
    """Minimum second-to-last tile dim for the dtype (TPU tiling table)."""
    return {jnp.bfloat16: 16, jnp.int8: 32}.get(jnp.dtype(dtype).type, 8)


def _pick_block(n: int, row_bytes: int, *, floor: int = 8, cap: int = 128,
                budget: int = 1 << 21) -> int:
    """Largest power-of-two block <= cap whose rows fit the VMEM budget.

    `n` is the dimension being tiled, `row_bytes` the bytes one row of the
    block occupies in fp32 working precision. Never exceeds the smallest
    power of two covering `n` (a block bigger than the padded input is
    pure padding waste), never goes below `floor`.
    """
    b = floor
    limit = min(cap, max(budget // max(row_bytes, 1), floor))
    while b * 2 <= limit and b < n:
        b *= 2
    return b


def rmsnorm_block(R: int, d: int) -> int:
    return _pick_block(R, d * 4, cap=512)


def attention_blocks(Tq: int, Tk: int, d: int, dtype) -> tuple:
    floor = _sublane_floor(dtype)
    # the fp32 accumulator (block_q, d) plus the (block_q, block_k) score
    # tile dominate VMEM; budget each at ~2 MiB
    bq = _pick_block(Tq, d * 4, floor=floor)
    bk = _pick_block(Tk, max(bq, d) * 4, floor=floor)
    return bq, bk


def attention_bwd_blocks(Tq: int, Tk: int, d: int, dtype) -> tuple:
    """Block sizes for the backward kernels, under a halved budget.

    The backward working set per tile is roughly double the forward's:
    the dk/dv passes hold TWO (block_k, d) fp32 accumulators, and the
    recompute materializes both the score tile and its gradient
    (p and ds, each (block_q, block_k)) — so each dimension gets a
    1 MiB budget instead of the forward's 2 MiB.
    """
    floor = _sublane_floor(dtype)
    bq = _pick_block(Tq, d * 4, floor=floor, budget=1 << 20)
    # rows of a k-block carry dk+dv accumulator rows (2*d fp32) plus a
    # p and a ds column slice (2*bq fp32)
    bk = _pick_block(Tk, (2 * d + 2 * bq) * 4, floor=floor, budget=1 << 20)
    return bq, bk


def scan_block(B: int, T: int) -> int:
    return _pick_block(B, T * 4)


# -- dispatched ops ------------------------------------------------------------

def rmsnorm(x, w, *, eps: float = 1e-6):
    """Fused RMSNorm over the last axis. x: (..., d); w: (d,)."""
    impl = resolve()
    if impl in ("reference", "fast"):
        note("rmsnorm", impl)
        return _rmsnorm_ref(x, w, eps)
    R = max(1, x.size // x.shape[-1])
    br = rmsnorm_block(R, x.shape[-1])
    note("rmsnorm", impl, (f"br={br}",))
    return _rmsnorm_pallas(x, w, eps=eps, block_r=br,
                           interpret=impl == "interpret")


def attention(q, k, v, *, scale, causal=True, window=0, cap=0.0):
    """Fused attention, kernel layout: q (B, H, Tq, d); k, v (B, KV, Tk, d).

    Callers with the model layout (B, T, H, d) transpose at the call site
    (see models/attention.chunked_attend). On the kernel tiers the
    backward runs the Pallas dq/dk/dv recompute kernels; the fast tier's
    backward is XLA autodiff through the chunked path; the reference tier
    is the full-T^2 oracle, forward and backward.
    """
    impl = resolve()
    inf = infer_mode()
    if impl == "reference":
        note("attention", impl)
        return _attention_ref(q, k, v, scale=scale, causal=causal,
                              window=window, cap=cap)
    if impl == "fast":
        note("attention", impl, ("bf16",) if inf else ())
        if inf == "bf16":
            # input-rounding emulation of the mixed kernel path: CPU has no
            # native bf16 matmul, so cast inputs and compute as usual
            o = _attention_chunked(q.astype(jnp.bfloat16),
                                   k.astype(jnp.bfloat16),
                                   v.astype(jnp.bfloat16), scale=scale,
                                   causal=causal, window=window, cap=cap)
            return o.astype(q.dtype)
        return _attention_chunked(q, k, v, scale=scale, causal=causal,
                                  window=window, cap=cap)
    bq, bk = attention_blocks(q.shape[2], k.shape[2], q.shape[3], q.dtype)
    bqb, bkb = attention_bwd_blocks(q.shape[2], k.shape[2], q.shape[3],
                                    q.dtype)
    mixed = inf == "bf16"
    note("attention", impl,
         (f"bq={bq}", f"bk={bk}", f"bwd={bqb}x{bkb}") +
         (("bf16",) if mixed else ()))
    if mixed:
        q = q.astype(jnp.bfloat16)
        k = k.astype(jnp.bfloat16)
        v = v.astype(jnp.bfloat16)
    return _flash_attention(q, k, v, scale, causal, window, cap, bq, bk,
                            impl == "interpret", bqb, bkb, mixed)


def reverse_scan(deltas, decays, init=None):
    """y_t = delta_t + decay_t * y_{t+1}, y_T = init. (B, T) -> (B, T) fp32.

    The one primitive behind GAE, TD(lambda), discounted returns and the
    V-trace correction sum (fused over the whole (B, T) minibatch instead
    of a lax.scan over T). Every tier's backward is the closed-form
    transpose (the same scan on flipped arrays) except the reference
    oracle, which keeps autodiff-through-lax.scan.
    """
    impl = resolve()
    if init is None:
        init = jnp.zeros((deltas.shape[0],), jnp.float32)
    if impl == "reference":
        note("reverse_scan", impl)
        return _scan_ref(deltas, decays, init)
    if impl == "fast":
        note("reverse_scan", impl)
        return _scan_fast(deltas, decays, init)
    B, T = deltas.shape
    bb = scan_block(B, T)
    note("reverse_scan", impl, (f"bb={bb}",))
    return _scan_pallas(deltas, decays, init, block_b=bb,
                        interpret=impl == "interpret")
