from repro.kernels.vtrace_scan.ops import reverse_discounted_scan
