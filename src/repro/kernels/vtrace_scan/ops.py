"""Jit wrapper: batch padding + dtype promotion for the reverse scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.vtrace_scan.kernel import reverse_discounted_scan_p


def reverse_discounted_scan(deltas, decays, init=None, *, block_b=8,
                            interpret=False):
    B, T = deltas.shape
    if init is None:
        init = jnp.zeros((B,), jnp.float32)
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        deltas = jnp.pad(deltas, ((0, pad), (0, 0)))
        decays = jnp.pad(decays, ((0, pad), (0, 0)))
        init = jnp.pad(init, (0, pad))
    y = reverse_discounted_scan_p(deltas, decays, init, block_b=bb,
                                  interpret=interpret)
    return y[:B]
