"""Jit wrapper: batch padding + dtype promotion for the reverse scan.

Differentiable with a *closed-form* VJP: the recurrence
y_t = delta_t + decay_t * y_{t+1} is linear in (deltas, init), so its
transpose is the same recurrence run the other direction —

    ybar_u = g_u + decay_{u-1} * ybar_{u-1}        (ybar_0 = g_0)
    d_deltas = ybar
    d_decays_u = ybar_u * y_{u+1}                  (y_T = init)
    d_init = ybar_{T-1} * decay_{T-1}

and a forward scan is a reverse scan on flipped arrays, so the backward
reuses the SAME fused kernel (or the same lax.scan on the fast tier).
At 4k-unroll seq-train scale the whole (B, T) V-trace/GAE scan therefore
runs fused end-to-end in both directions — no O(T) recompute through a
reference VJP, no unrolled-graph transpose.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vtrace_scan.kernel import reverse_discounted_scan_p
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref


def _closed_form_bwd(run, deltas, decays, init, y, g):
    """Transpose of the reverse scan via `run` (a (deltas, decays, init) ->
    y reverse-scan implementation) applied to flipped arrays."""
    f32 = jnp.float32
    B = g.shape[0]
    g32 = g.astype(f32)
    dec32 = decays.astype(f32)
    # ybar's recurrence indexes decay_{u-1}: shift right, zero-fill
    shifted = jnp.concatenate([jnp.zeros((B, 1), f32), dec32[:, :-1]], axis=1)
    ybar = jnp.flip(
        run(jnp.flip(g32, 1), jnp.flip(shifted, 1), jnp.zeros((B,), f32)), 1)
    y_next = jnp.concatenate([y[:, 1:], init.astype(f32)[:, None]], axis=1)
    return (ybar.astype(deltas.dtype),
            (ybar * y_next).astype(decays.dtype),
            (ybar[:, -1] * dec32[:, -1]).astype(init.dtype))


def _run(deltas, decays, init, block_b, interpret):
    """Pad the batch to a block multiple, launch the kernel, slice."""
    B = deltas.shape[0]
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        deltas = jnp.pad(deltas, ((0, pad), (0, 0)))
        decays = jnp.pad(decays, ((0, pad), (0, 0)))
        init = jnp.pad(init, (0, pad))
    y = reverse_discounted_scan_p(deltas, decays, init, block_b=bb,
                                  interpret=interpret)
    return y[:B]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _reverse_scan(deltas, decays, init, block_b, interpret):
    return _run(deltas, decays, init, block_b, interpret)


def _fwd(deltas, decays, init, block_b, interpret):
    y = _run(deltas, decays, init, block_b, interpret)
    return y, (deltas, decays, init, y)


def _bwd(block_b, interpret, res, g):
    deltas, decays, init, y = res
    run = lambda d, c, z: _run(d, c, z, block_b, interpret)
    return _closed_form_bwd(run, deltas, decays, init, y, g)


_reverse_scan.defvjp(_fwd, _bwd)


def reverse_discounted_scan(deltas, decays, init=None, *, block_b=8,
                            interpret=False):
    if init is None:
        init = jnp.zeros((deltas.shape[0],), jnp.float32)
    return _reverse_scan(deltas, decays, init, block_b, interpret)


# -- fast tier (no Pallas): same closed-form transpose over the lax.scan ------

@jax.custom_vjp
def reverse_discounted_scan_fast(deltas, decays, init):
    return reverse_discounted_scan_ref(deltas, decays, init)


def _fast_fwd(deltas, decays, init):
    y = reverse_discounted_scan_ref(deltas, decays, init)
    return y, (deltas, decays, init, y)


def _fast_bwd(res, g):
    deltas, decays, init, y = res
    return _closed_form_bwd(reverse_discounted_scan_ref, deltas, decays, init,
                            y, g)


reverse_discounted_scan_fast.defvjp(_fast_fwd, _fast_bwd)
