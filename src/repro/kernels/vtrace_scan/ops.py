"""Jit wrapper: batch padding + dtype promotion for the reverse scan.

Differentiable: forward runs the Pallas kernel, backward recomputes
through the lax.scan reference (custom_vjp) — the recursion's transpose
is itself a scan, so the reference VJP is exact and cheap.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.vtrace_scan.kernel import reverse_discounted_scan_p
from repro.kernels.vtrace_scan.ref import reverse_discounted_scan_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def _reverse_scan(deltas, decays, init, block_b, interpret):
    B, T = deltas.shape
    bb = min(block_b, B)
    pad = (-B) % bb
    if pad:
        deltas = jnp.pad(deltas, ((0, pad), (0, 0)))
        decays = jnp.pad(decays, ((0, pad), (0, 0)))
        init = jnp.pad(init, (0, pad))
    y = reverse_discounted_scan_p(deltas, decays, init, block_b=bb,
                                  interpret=interpret)
    return y[:B]


def _fwd(deltas, decays, init, block_b, interpret):
    return (_reverse_scan(deltas, decays, init, block_b, interpret),
            (deltas, decays, init))


def _bwd(block_b, interpret, res, g):
    deltas, decays, init = res
    _, vjp = jax.vjp(reverse_discounted_scan_ref, deltas, decays, init)
    return vjp(g)


_reverse_scan.defvjp(_fwd, _bwd)


def reverse_discounted_scan(deltas, decays, init=None, *, block_b=8,
                            interpret=False):
    if init is None:
        init = jnp.zeros((deltas.shape[0],), jnp.float32)
    return _reverse_scan(deltas, decays, init, block_b, interpret)
