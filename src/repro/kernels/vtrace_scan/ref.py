"""Pure-jnp oracle: reverse discounted scan via lax.scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def reverse_discounted_scan_ref(deltas, decays, init):
    """y_t = delta_t + decay_t * y_{t+1};  y beyond T-1 is `init`. (B, T)."""

    def body(carry, xs):
        d_t, g_t = xs
        y = d_t + g_t * carry
        return y, y

    _, ys = jax.lax.scan(body, init.astype(jnp.float32),
                         (deltas.T.astype(jnp.float32),
                          decays.T.astype(jnp.float32)), reverse=True)
    return ys.T
