"""Reverse discounted-scan kernel (TPU Pallas).

The learner's "algorithm-specific terms" (paper §3.2: lambda-returns, GAE,
V-trace) all reduce to one primitive:

    y_t = delta_t + decay_t * y_{t+1},   y_T = init

which is sequential in T but embarrassingly parallel in batch. TPU
adaptation: tile the batch across the grid so each (block_b, T) tile sits in
VMEM; the time recursion is a `fori_loop` over VMEM columns — lane-parallel
across the batch tile (the VPU sees (block_b,) vectors), with zero HBM
traffic beyond one read + one write per element. This is the kernelized
form of what the paper's DataServer computes on CPU per minibatch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _scan_kernel(delta_ref, decay_ref, init_ref, y_ref, *, T):
    carry = init_ref[...].astype(jnp.float32)              # (bb,)

    def body(i, carry):
        t = T - 1 - i
        y = delta_ref[:, t].astype(jnp.float32) + decay_ref[:, t].astype(jnp.float32) * carry
        y_ref[:, t] = y.astype(y_ref.dtype)
        return y

    jax.lax.fori_loop(0, T, body, carry)


def reverse_discounted_scan_p(deltas, decays, init, *, block_b=8,
                              interpret=False):
    """deltas, decays: (B, T); init: (B,). Returns y: (B, T)."""
    B, T = deltas.shape
    assert B % block_b == 0

    kernel = functools.partial(_scan_kernel, T=T)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, T), lambda b: (b, 0)),
            pl.BlockSpec((block_b, T), lambda b: (b, 0)),
            pl.BlockSpec((block_b,), lambda b: (b,)),
        ],
        out_specs=pl.BlockSpec((block_b, T), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((B, T), jnp.float32),
        interpret=interpret,
    )(deltas, decays, init)
