"""Pallas TPU kernels for the compute hot spots (validated in interpret
mode on CPU; see each subpackage's ref.py for the pure-jnp oracle):

  flash_attention — fused online-softmax attention (prefill/train), GQA,
                    causal + sliding-window + logit-softcap aware.
  vtrace_scan     — the learner's reverse-time discounted recursion
                    (one primitive covers GAE, TD(lambda) and V-trace).
  rmsnorm         — fused RMS normalization.

`repro.kernels.dispatch` is the production entry point: it routes each op
to the compiled kernel (TPU/GPU), the Pallas interpreter (parity tests),
or the jnp reference (CPU fast path) from one mode switch, and picks
block sizes per shape. models/ and rl/ call through it.
"""
from repro.kernels import dispatch
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.vtrace_scan.ops import reverse_discounted_scan
from repro.kernels.rmsnorm.ops import rmsnorm
