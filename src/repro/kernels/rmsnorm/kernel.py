"""Fused RMSNorm kernel (TPU Pallas).

One read of x per element: mean-square reduction and the scale multiply are
fused in VMEM (XLA emits this as two passes around an HBM round-trip when
the surrounding graph prevents fusion). Rows are tiled (block_r, d) so the
reduction is a lane reduction per row; d is expected to be a multiple of
128 (all assigned archs' d_model are).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)                     # (br, d)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_p(x2d, w, *, eps=1e-6, block_r=128, interpret=False):
    R, d = x2d.shape
    assert R % block_r == 0
    kernel = functools.partial(_rmsnorm_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(R // block_r,),
        in_specs=[
            pl.BlockSpec((block_r, d), lambda r: (r, 0)),
            pl.BlockSpec((d,), lambda r: (0,)),
        ],
        out_specs=pl.BlockSpec((block_r, d), lambda r: (r, 0)),
        out_shape=jax.ShapeDtypeStruct((R, d), x2d.dtype),
        interpret=interpret,
    )(x2d, w)
