"""Jit wrapper: flatten leading dims, pad rows to the block multiple.

Differentiable: the forward runs the Pallas kernel, the backward
recomputes through the pure-jnp reference (custom_vjp), so the kernel can
sit inside a jitted train step's grad path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_p
from repro.kernels.rmsnorm.ref import rmsnorm_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rmsnorm(x, w, eps, block_r, interpret):
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    R = x2.shape[0]
    br = min(block_r, R)
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = rmsnorm_p(x2, w, eps=eps, block_r=br, interpret=interpret)
    return y[:R].reshape(shape)


def _fwd(x, w, eps, block_r, interpret):
    return _rmsnorm(x, w, eps, block_r, interpret), (x, w)


def _bwd(eps, block_r, interpret, res, g):
    x, w = res
    _, vjp = jax.vjp(lambda x, w: rmsnorm_ref(x, w, eps), x, w)
    return vjp(g)


_rmsnorm.defvjp(_fwd, _bwd)


def rmsnorm(x, w, *, eps=1e-6, block_r=128, interpret=False):
    return _rmsnorm(x, w, eps, block_r, interpret)
