"""Jit wrapper: flatten leading dims, pad rows to the block multiple."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.rmsnorm.kernel import rmsnorm_p


def rmsnorm(x, w, *, eps=1e-6, block_r=128, interpret=False):
    shape = x.shape
    d = shape[-1]
    x2 = x.reshape(-1, d)
    R = x2.shape[0]
    br = min(block_r, R)
    pad = (-R) % br
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    y = rmsnorm_p(x2, w, eps=eps, block_r=br, interpret=interpret)
    return y[:R].reshape(shape)
