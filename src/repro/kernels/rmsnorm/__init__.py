from repro.kernels.rmsnorm.ops import rmsnorm
