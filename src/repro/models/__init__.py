from repro.models.transformer import (
    init_params,
    forward_train,
    prefill,
    decode_step,
    init_decode_state,
)
