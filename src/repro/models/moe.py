"""Top-k MoE with sort-based token dispatch (Megablocks-style, TPU-adapted).

Why not the GShard one-hot dispatch einsum: its (tokens, E, C) dispatch tensor
is O(N*E*C) — at kimi-k2 scale (1M tokens, 384 experts) that is tens of TB.
The sort-based route keeps everything O(N*k): argsort token->expert
assignments, compute each token's position within its expert via a histogram
(bincount) + prefix sum, scatter tokens into a dense (E, C, d) buffer
(unique slots -> scatter-set, clean transpose/gradient), batched expert GEMM,
gather back. Under pjit the (E, C, d) buffer shards over the expert/model
axes and the token tensors over data — the reshard between them is the MoE
all-to-all the paper-era systems did by hand.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L


def init_moe(rng, cfg, dtype):
    e = cfg.moe
    ks = jax.random.split(rng, 5)
    d, ff = cfg.d_model, e.d_ff_expert
    scale = d ** -0.5
    p = {
        "router": {"w": L._normal(ks[0], (d, e.num_experts), scale, jnp.float32)},
        "up": L._normal(ks[1], (e.num_experts, d, ff), scale, dtype),
        "gate": L._normal(ks[2], (e.num_experts, d, ff), scale, dtype),
        "down": L._normal(ks[3], (e.num_experts, ff, d), ff ** -0.5, dtype),
    }
    if e.num_shared_experts:
        p["shared"] = L.mlp_init(ks[4], d, cfg.d_ff * e.num_shared_experts, dtype,
                                 gated=cfg.mlp_gated)
    return p


def route_topk(gates, k: int, capacity: int):
    """gates: (N, E) fp32 probabilities. Returns (slot_idx (N,k), weight (N,k),
    keep (N,k), aux_stats). slot_idx indexes an (E*capacity + 1) buffer; the
    last row is the drop bucket."""
    N, E = gates.shape
    topv, topi = jax.lax.top_k(gates, k)                       # (N, k)
    topv = topv / (jnp.sum(topv, -1, keepdims=True) + 1e-9)
    # rank-major flatten: all rank-0 choices first => earlier ranks win capacity
    flat_e = topi.T.reshape(-1)                                # (k*N,)
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.bincount(flat_e, length=E)                    # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_sorted = jnp.arange(k * N, dtype=jnp.int32) - starts[flat_e[order]].astype(jnp.int32)
    pos_flat = jnp.zeros((k * N,), jnp.int32).at[order].set(pos_sorted)
    pos = pos_flat.reshape(k, N).T                             # (N, k)
    keep = pos < capacity
    slot = jnp.where(keep, topi * capacity + pos, E * capacity)
    return slot, topv, keep, counts


PAD_ROWS = 16   # drop-bucket rows; >1 keeps buffer row count mesh-divisible


# §Perf-2: expert-parallel path toggle (set by the launch/step factory; the
# pure-GSPMD path stays the default for tests and the paper-faithful
# baseline). See moe_apply_ep below.
_EXPERT_PARALLEL = False


def set_expert_parallel(on: bool):
    global _EXPERT_PARALLEL
    _EXPERT_PARALLEL = bool(on)


def moe_apply(p, cfg, x):
    """x: (B, T, d) -> (y, aux_loss). Works for T==1 decode too.

    Sharding (§Perf-2): token tensors are pinned to the data axes and the
    (E*C, d) expert buffers to the model (expert) axis — the reshard between
    them is the MoE all-to-all. Without these hints GSPMD resolved the
    scatter/gather dispatch with full all-gathers of the token buffers
    (~15 TB/chip/step at kimi-k2 train_4k)."""
    from repro.distributed.sharding import shard_hint, _HINT_MESH
    if _EXPERT_PARALLEL and _HINT_MESH is not None \
            and cfg.moe.num_experts % _HINT_MESH.shape.get("model", 1) == 0:
        return moe_apply_ep(p, cfg, x, _HINT_MESH)
    e = cfg.moe
    B, T, d = x.shape
    N = B * T
    xf = x.reshape(N, d)
    xf = shard_hint(xf, (("pod", "data"), None))
    E, k = e.num_experts, e.experts_per_token
    capacity = max(int(N * k * e.capacity_factor / E), k)

    logits = (xf.astype(jnp.float32) @ p["router"]["w"])       # (N, E)
    gates = jax.nn.softmax(logits, axis=-1)
    slot, weight, keep, counts = route_topk(gates, k, capacity)

    # scatter tokens into expert buffers: (E*C+PAD, d); drop bucket = row E*C
    buf = jnp.zeros((E * capacity + PAD_ROWS, d), x.dtype)
    tok_rep = jnp.repeat(jnp.arange(N), k)
    buf = buf.at[slot.reshape(-1)].set(xf[tok_rep], mode="drop")
    buf = shard_hint(buf, ("model", None))
    expert_in = buf[: E * capacity].reshape(E, capacity, d)
    expert_in = shard_hint(expert_in, ("model", None, None))

    a = L.act_fn(cfg.activation)
    h = jnp.einsum("ecd,edf->ecf", expert_in, p["up"].astype(x.dtype))
    g = jnp.einsum("ecd,edf->ecf", expert_in, p["gate"].astype(x.dtype))
    out = jnp.einsum("ecf,efd->ecd", a(g) * h, p["down"].astype(x.dtype))
    out = shard_hint(out, ("model", None, None))

    out_flat = jnp.concatenate([out.reshape(E * capacity, d),
                                jnp.zeros((PAD_ROWS, d), x.dtype)], 0)
    out_flat = shard_hint(out_flat, ("model", None))
    gathered = out_flat[slot]                                   # (N, k, d)
    gathered = shard_hint(gathered, (("pod", "data"), None, None))
    w = (weight * keep).astype(x.dtype)
    y = jnp.einsum("nk,nkd->nd", w, gathered)
    y = shard_hint(y, (("pod", "data"), None))

    if "shared" in p:
        y = y + L.mlp(p["shared"], xf, cfg.activation)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    f = counts.astype(jnp.float32) / (N * k)
    pbar = jnp.mean(gates, axis=0)
    aux = e.router_aux_coef * E * jnp.sum(f * pbar)
    return y.reshape(B, T, d), aux


# ===========================================================================
# §Perf-2: explicit expert parallelism (shard_map)
# ===========================================================================

def moe_apply_ep(p, cfg, x, mesh):
    """Expert-parallel MoE via shard_map — the TPU-native dispatch.

    Motivation (EXPERIMENTS.md §Perf-2): GSPMD resolves the sort-based
    scatter/gather dispatch by materializing the global (E*C, d) buffers on
    every chip (~15 TB/chip all-gather at kimi train_4k); sharding hints
    made it *worse* (replicated scatter compute). Here the data movement is
    pinned explicitly:

      - tokens stay sharded over the data axes; routing is computed
        redundantly on each model shard (cheap: one (N_l, E) matmul);
      - each model shard scatters ONLY the tokens routed to its local
        E/M experts into a local (E_l*C, d) buffer (on-chip scatter);
      - expert weights are FSDP over `data`; the fwd all-gathers them over
        `data` (tiled) and autodiff turns that into the reduce-scatter of
        weight grads — exactly the ZeRO-3 schedule;
      - combine = psum over `model` of each shard's weighted outputs:
        2 x (N_l x d) of ICI traffic per layer, the information-theoretic
        floor for expert-parallel MoE (vs. gathering 150 GB buffers).

    Capacity is per-(data-shard, expert): C = max(N_l*k*cf/E, k) — same
    expected load as the global-capacity baseline, slightly different drop
    boundary (documented).
    """
    from jax.sharding import PartitionSpec as P
    e = cfg.moe
    B, T, d = x.shape
    M = mesh.shape.get("model", 1)
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    E, k = e.num_experts, e.experts_per_token
    E_l = E // M
    a = L.act_fn(cfg.activation)

    def local_fn(wr, up, gate, down, shared, xl):
        # xl: (B_l, T, d); up/gate: (E_l, d, ff); down: (E_l, ff, d)
        m_idx = jax.lax.axis_index("model")
        B_l = xl.shape[0]
        N_l = B_l * T
        C = max(int(N_l * k * e.capacity_factor / E), k)
        xf = xl.reshape(N_l, d)

        # ZeRO-3: gather the d-sharded expert weights over data (bwd:
        # reduce-scatter of the weight grads)
        if dp:
            up = jax.lax.all_gather(up, dp, axis=1, tiled=True)
            gate = jax.lax.all_gather(gate, dp, axis=1, tiled=True)
            down = jax.lax.all_gather(down, dp, axis=2, tiled=True)

        logits = xf.astype(jnp.float32) @ wr                  # (N_l, E)
        gates = jax.nn.softmax(logits, axis=-1)
        topv, topi = jax.lax.top_k(gates, k)                  # (N_l, k)
        topv = topv / (jnp.sum(topv, -1, keepdims=True) + 1e-9)

        local_e = topi - m_idx * E_l                          # (N_l, k)
        valid = (local_e >= 0) & (local_e < E_l)
        flat_e = jnp.where(valid, local_e, E_l).T.reshape(-1)  # rank-major
        order = jnp.argsort(flat_e, stable=True)
        counts = jnp.bincount(flat_e, length=E_l + 1)
        starts = jnp.cumsum(counts) - counts
        pos_sorted = (jnp.arange(k * N_l, dtype=jnp.int32)
                      - starts[flat_e[order]].astype(jnp.int32))
        pos = jnp.zeros((k * N_l,), jnp.int32).at[order].set(pos_sorted)
        pos = pos.reshape(k, N_l).T
        keep = valid & (pos < C)
        slot = jnp.where(keep, local_e * C + pos, E_l * C)

        buf = jnp.zeros((E_l * C + PAD_ROWS, d), xl.dtype)
        tok_rep = jnp.repeat(jnp.arange(N_l), k)
        buf = buf.at[slot.reshape(-1)].set(xf[tok_rep], mode="drop")
        expert_in = buf[: E_l * C].reshape(E_l, C, d)

        h = jnp.einsum("ecd,edf->ecf", expert_in, up.astype(xl.dtype))
        g = jnp.einsum("ecd,edf->ecf", expert_in, gate.astype(xl.dtype))
        out = jnp.einsum("ecf,efd->ecd", a(g) * h, down.astype(xl.dtype))

        out_flat = jnp.concatenate([out.reshape(E_l * C, d),
                                    jnp.zeros((PAD_ROWS, d), xl.dtype)], 0)
        gathered = out_flat[slot]                              # local gather
        w = (topv * keep).astype(xl.dtype)
        y = jnp.einsum("nk,nkd->nd", w, gathered)
        y = jax.lax.psum(y, "model")                           # combine

        if shared is not None:
            sh_up, sh_gate, sh_down = shared
            # shared expert: TP over model on the hidden dim
            hs = xf @ sh_up.astype(xl.dtype)
            gs = a(xf @ sh_gate.astype(xl.dtype))
            ys = (gs * hs) @ sh_down.astype(xl.dtype)
            y = y + jax.lax.psum(ys, "model")

        # load-balance aux: GLOBAL load fraction x GLOBAL mean gate prob —
        # average f and pbar over data BEFORE the product, else the aux
        # picks up the cross-shard covariance and diverges from the
        # baseline's sum_e f_e * p_e
        f_local = counts[:E_l].astype(jnp.float32) / (N_l * k)
        pbar = jnp.mean(gates, axis=0)                         # (E,) full
        p_local = jax.lax.dynamic_slice_in_dim(pbar, m_idx * E_l, E_l)
        if dp:
            f_local = jax.lax.pmean(f_local, dp)
            p_local = jax.lax.pmean(p_local, dp)
        aux = e.router_aux_coef * E * jnp.sum(f_local * p_local)
        aux = jax.lax.psum(aux, "model")
        return y.reshape(B_l, T, d), aux

    shared_in = None
    shared_spec = None
    if "shared" in p:
        sh = p["shared"]
        shared_in = (sh["up"]["w"], sh["gate"]["w"], sh["down"]["w"])
        # hidden dim of the shared expert TP-sharded over model
        shared_spec = (P(None, "model"), P(None, "model"), P("model", None))

    # version-tolerant: jax.shard_map (check_vma) landed after 0.4.x, where
    # the API lives in jax.experimental.shard_map (check_rep)
    if hasattr(jax, "shard_map"):
        _smap, _no_check = jax.shard_map, {"check_vma": False}
    else:
        from jax.experimental.shard_map import shard_map as _smap
        _no_check = {"check_rep": False}
    fn = _smap(
        local_fn, mesh=mesh,
        in_specs=(P(), P("model", dp if dp else None, None),
                  P("model", dp if dp else None, None),
                  P("model", None, dp if dp else None),
                  shared_spec, P(dp if dp else None, None, None)),
        out_specs=(P(dp if dp else None, None, None), P()),
        **_no_check)
    return fn(p["router"]["w"], p["up"], p["gate"], p["down"], shared_in, x)
