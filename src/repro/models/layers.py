"""Shared primitive layers: norms, RoPE, MLPs, embeddings.

Plain-pytree params (no flax in env). Convention: every layer has
`init_<layer>(rng, ...) -> params` and `<layer>(params, x, ...) -> y`.
Compute runs in cfg.compute_dtype with fp32 norm/softmax internals.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import dispatch


def _normal(rng, shape, scale, dtype):
    return (scale * jax.random.truncated_normal(rng, -2.0, 2.0, shape, jnp.float32)).astype(dtype)


def dense_init(rng, d_in, d_out, dtype, bias=False, scale=None):
    scale = scale if scale is not None else d_in ** -0.5
    p = {"w": _normal(rng, (d_in, d_out), scale, dtype)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


# -- norms -------------------------------------------------------------------

def rmsnorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(p, x, eps=1e-6):
    return dispatch.rmsnorm(x, p["scale"], eps=eps)


def layernorm_init(d, dtype):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(p, x, eps=1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


def norm_init(kind, d, dtype):
    return rmsnorm_init(d, dtype) if kind == "rmsnorm" else layernorm_init(d, dtype)


def norm_apply(kind, p, x):
    return rmsnorm(p, x) if kind == "rmsnorm" else layernorm(p, x)


# -- rotary embeddings ---------------------------------------------------------

def rope_freqs(head_dim, theta):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta):
    """x: (..., T, H, hd); positions: broadcastable to (..., T)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                                  # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs      # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                            # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- MLPs ----------------------------------------------------------------------

def act_fn(name):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu,
            "gelu_tanh": lambda x: jax.nn.gelu(x, approximate=True)}[name]


def mlp_init(rng, d_model, d_ff, dtype, gated=True, bias=False):
    ks = jax.random.split(rng, 3)
    p = {"up": dense_init(ks[0], d_model, d_ff, dtype, bias),
         "down": dense_init(ks[1], d_ff, d_model, dtype, bias)}
    if gated:
        p["gate"] = dense_init(ks[2], d_model, d_ff, dtype, bias)
    return p


def mlp(p, x, activation="silu"):
    a = act_fn(activation)
    h = dense(p["up"], x)
    if "gate" in p:
        h = a(dense(p["gate"], x)) * h
    else:
        h = a(h)
    return dense(p["down"], h)


# -- embedding -----------------------------------------------------------------

def embed_init(rng, vocab, d_model, dtype):
    return {"table": _normal(rng, (vocab, d_model), 1.0, dtype)}


def embed(p, tokens, compute_dtype, scale=False):
    x = p["table"].astype(compute_dtype)[tokens]
    if scale:
        x = x * jnp.asarray(x.shape[-1] ** 0.5, compute_dtype)
    return x


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap
