"""Attention-free sequence mixers: RWKV6 (Finch) and Mamba-style S6.

RWKV6 [arXiv:2404.05892] — data-dependent decay linear attention:
    S_t = diag(w_t) S_{t-1} + k_t^T v_t        (per head, S: hs x hs)
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
with token-shift "ddlerp" mixing (low-rank data-dependent interpolation of
x_t and x_{t-1} per projection target) and a LoRA'd decay w_t.

Mamba/S6 (for hymba's parallel SSM heads):
    h_t = exp(dt*A) h_{t-1} + dt * B_t x_t ;  y_t = C_t h_t + D x_t
with a short causal conv in front and a silu gate.

Both expose a train-time `lax.scan` over time and an O(1) single-step decode
with explicit recurrent state — the reason these archs run `long_500k`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers as L

RWKV_TARGETS = ("r", "k", "v", "w", "g")


# ===========================================================================
# RWKV6 time mix
# ===========================================================================

def init_rwkv_time_mix(rng, cfg, dtype):
    d = cfg.d_model
    hs = cfg.ssm.head_size
    H = d // hs
    r = cfg.ssm.lora_rank
    ks = iter(jax.random.split(rng, 24))
    p = {
        "mu_x": jnp.zeros((d,), dtype),          # base mix for the lora input
        "lora_a": L._normal(next(ks), (d, len(RWKV_TARGETS) * r), 0.01, dtype),
        "lora_b": L._normal(next(ks), (len(RWKV_TARGETS), r, d), 0.01, dtype),
        "mu": jnp.zeros((len(RWKV_TARGETS), d), dtype),
        "w_base": jnp.broadcast_to(
            jnp.linspace(-6.0, -0.5, d).astype(dtype), (d,)),  # per-channel decay bias
        "u": L._normal(next(ks), (H, hs), 0.3, dtype),          # bonus ("first token")
        "wr": L.dense_init(next(ks), d, d, dtype),
        "wk": L.dense_init(next(ks), d, d, dtype),
        "wv": L.dense_init(next(ks), d, d, dtype),
        "wg": L.dense_init(next(ks), d, d, dtype),
        "wo": L.dense_init(next(ks), d, d, dtype),
        "ln_out": L.layernorm_init(hs, dtype),   # per-head groupnorm
    }
    return p


def _rwkv_mix(p, x, x_prev):
    """ddlerp: per-target data-dependent interpolation of x and x_prev.
    x, x_prev: (B, T, d) -> dict target -> (B, T, d)."""
    xx = x_prev - x
    base = x + xx * p["mu_x"].astype(x.dtype)
    r = p["lora_a"].shape[1] // len(RWKV_TARGETS)
    z = jnp.tanh(base @ p["lora_a"].astype(x.dtype))           # (B,T,5r)
    z = z.reshape(*z.shape[:-1], len(RWKV_TARGETS), r)
    dyn = jnp.einsum("btnr,nrd->btnd", z, p["lora_b"].astype(x.dtype))
    mixed = {}
    for i, t in enumerate(RWKV_TARGETS):
        m = p["mu"][i].astype(x.dtype) + dyn[..., i, :]
        mixed[t] = x + xx * m
    return mixed


def _rwkv_head_step(r_t, k_t, v_t, w_t, u, S):
    """One step of the per-head recurrence.
    r,k,v: (B,H,hs); w: (B,H,hs) decay in (0,1); u: (H,hs); S: (B,H,hs,hs)."""
    kv = k_t[..., :, None] * v_t[..., None, :]                 # (B,H,hs,hs)
    y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
    S = w_t[..., :, None] * S + kv
    return y, S


def rwkv_time_mix(p, cfg, x, x_prev_init, S_init):
    """Full-sequence scan. x: (B, T, d). Returns (y, (x_last, S_last))."""
    B, T, d = x.shape
    hs = cfg.ssm.head_size
    H = d // hs
    x_prev = jnp.concatenate([x_prev_init[:, None], x[:, :-1]], axis=1)
    m = _rwkv_mix(p, x, x_prev)
    r = L.dense(p["wr"], m["r"]).reshape(B, T, H, hs)
    k = L.dense(p["wk"], m["k"]).reshape(B, T, H, hs)
    v = L.dense(p["wv"], m["v"]).reshape(B, T, H, hs)
    g = jax.nn.silu(L.dense(p["wg"], m["g"]))
    w = jnp.exp(-jnp.exp((p["w_base"].astype(jnp.float32)
                          + m["w"].astype(jnp.float32)))).reshape(B, T, H, hs)

    u = p["u"].astype(jnp.float32)

    def body(S, xs):
        r_t, k_t, v_t, w_t = xs
        y, S = _rwkv_head_step(r_t.astype(jnp.float32), k_t.astype(jnp.float32),
                               v_t.astype(jnp.float32), w_t, u, S)
        return S, y

    xs = (r.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), w.transpose(1, 0, 2, 3))
    S_last, ys = jax.lax.scan(body, S_init.astype(jnp.float32), xs)
    y = ys.transpose(1, 0, 2, 3)                               # (B,T,H,hs)
    y = L.layernorm(p["ln_out"], y.astype(x.dtype))
    y = (y.reshape(B, T, d) * g)
    return L.dense(p["wo"], y), (x[:, -1], S_last)


def rwkv_time_mix_step(p, cfg, x, state):
    """Single-token decode. x: (B, 1, d); state=(x_prev (B,d), S (B,H,hs,hs))."""
    x_prev, S = state
    y, (x_last, S2) = rwkv_time_mix(p, cfg, x, x_prev, S)
    return y, (x_last, S2)


def init_rwkv_state(cfg, batch, dtype):
    d = cfg.d_model
    hs = cfg.ssm.head_size
    return (jnp.zeros((batch, d), dtype),
            jnp.zeros((batch, d // hs, hs, hs), jnp.float32))


# -- RWKV channel mix (its FFN, also token-shifted) ---------------------------

def init_rwkv_channel_mix(rng, cfg, dtype):
    ks = jax.random.split(rng, 2)
    return {
        "mu_k": jnp.zeros((cfg.d_model,), dtype),
        "wk": L.dense_init(ks[0], cfg.d_model, cfg.d_ff, dtype),
        "wv": L.dense_init(ks[1], cfg.d_ff, cfg.d_model, dtype),
    }


def rwkv_channel_mix(p, cfg, x, x_prev_init):
    x_prev = jnp.concatenate([x_prev_init[:, None], x[:, :-1]], axis=1)
    xk = x + (x_prev - x) * p["mu_k"].astype(x.dtype)
    k = jnp.square(jax.nn.relu(L.dense(p["wk"], xk)))
    return L.dense(p["wv"], k), x[:, -1]


# ===========================================================================
# Mamba / S6 (hymba's SSM heads)
# ===========================================================================

def init_mamba(rng, cfg, dtype):
    d = cfg.d_model
    s = cfg.ssm
    di = s.expand * d
    N = s.state_size
    dt_rank = s.dt_rank or max(1, -(-d // 16))
    ks = iter(jax.random.split(rng, 8))
    return {
        "in_proj": L.dense_init(next(ks), d, 2 * di, dtype),
        "conv_w": L._normal(next(ks), (s.conv_kernel, di), 0.5, dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": L.dense_init(next(ks), di, dt_rank + 2 * N, dtype),
        "dt_proj": L.dense_init(next(ks), dt_rank, di, dtype, bias=True),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (di, N))).astype(dtype),
        "D": jnp.ones((di,), dtype),
        "out_proj": L.dense_init(next(ks), di, d, dtype),
    }


def _mamba_conv_full(p, x):
    """Causal depthwise conv over (B, T, di) via explicit taps."""
    K = p["conv_w"].shape[0]
    w = p["conv_w"].astype(x.dtype)
    y = x * w[K - 1]
    for i in range(1, K):
        shifted = jnp.pad(x[:, :-i], ((0, 0), (i, 0), (0, 0)))
        y = y + shifted * w[K - 1 - i]
    return y + p["conv_b"].astype(x.dtype)


def mamba_apply(p, cfg, x, state=None):
    """x: (B, T, d). state=None for train; (conv_buf (B,K-1,di), h (B,di,N))
    for decode (T==1). Returns (y, new_state)."""
    B, T, d = x.shape
    s = cfg.ssm
    N = s.state_size
    dt_rank = p["dt_proj"]["w"].shape[0]
    zx = L.dense(p["in_proj"], x)
    z, xin = jnp.split(zx, 2, axis=-1)                         # (B,T,di) each
    di = xin.shape[-1]
    K = p["conv_w"].shape[0]

    if state is None:
        xc = _mamba_conv_full(p, xin)
        conv_buf_out = xin[:, -(K - 1):] if T >= K - 1 else jnp.pad(
            xin, ((0, 0), (K - 1 - T, 0), (0, 0)))
        h0 = jnp.zeros((B, di, N), jnp.float32)
    else:
        conv_buf, h0 = state
        window = jnp.concatenate([conv_buf, xin], axis=1)      # (B,K,di)
        xc = jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))[:, None]
        xc = xc + p["conv_b"].astype(x.dtype)
        conv_buf_out = window[:, 1:]
    xc = jax.nn.silu(xc)

    proj = L.dense(p["x_proj"], xc)
    dt_in, Bc, Cc = jnp.split(proj, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(L.dense(p["dt_proj"], dt_in)).astype(jnp.float32)  # (B,T,di)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))               # (di,N)
    # §Perf: dA/dBx are formed PER STEP inside the scan body — materializing
    # the (B,T,di,N) tensors cost ~2x13.4 GiB/layer at prefill_32k and made
    # hymba the worst memory-roofline pair (EXPERIMENTS.md §Perf-3).
    dtx = dt * xc.astype(jnp.float32)                          # (B,T,di)

    def body(h, xs):
        dt_t, dtx_t, B_t, C_t = xs                             # (B,di),(B,di),(B,N),(B,N)
        dA_t = jnp.exp(dt_t[..., None] * A)                    # (B,di,N)
        h = dA_t * h + dtx_t[..., None] * B_t[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, C_t)
        return h, y

    xs = (dt.transpose(1, 0, 2), dtx.transpose(1, 0, 2),
          Bc.astype(jnp.float32).transpose(1, 0, 2),
          Cc.astype(jnp.float32).transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(body, h0, xs)
    y = ys.transpose(1, 0, 2).astype(x.dtype)                  # (B,T,di)
    y = y + xc * p["D"].astype(x.dtype)
    y = y * jax.nn.silu(z)
    return L.dense(p["out_proj"], y), (conv_buf_out, h_last)


def init_mamba_state(cfg, batch, dtype):
    di = cfg.ssm.expand * cfg.d_model
    K = cfg.ssm.conv_kernel
    return (jnp.zeros((batch, K - 1, di), dtype),
            jnp.zeros((batch, di, cfg.ssm.state_size), jnp.float32))
