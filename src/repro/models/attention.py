"""GQA attention: full-sequence (chunked, memory-safe at 32k+) and cached decode.

Features used by the assigned archs: grouped-query attention, per-head
qk-norm (qwen3), attention logit softcapping (gemma2), sliding-window masks
(gemma2 local layers; the long-context variant for every dense arch), and a
ring-buffer KV cache so `long_500k` decode holds O(window) state.

The pure-jnp paths here are also the oracle the Pallas kernels are tested
against (`repro/kernels/flash_attention/ref.py` wraps them).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import dispatch
from repro.models import layers as L

NEG_INF = -2.0 ** 30  # large-negative that survives bf16/softcap fine


def init_attention(rng, cfg, dtype):
    ks = jax.random.split(rng, 6)
    p = {
        "wq": L.dense_init(ks[0], cfg.d_model, cfg.q_dim, dtype, cfg.attn_bias),
        "wk": L.dense_init(ks[1], cfg.d_model, cfg.kv_dim, dtype, cfg.attn_bias),
        "wv": L.dense_init(ks[2], cfg.d_model, cfg.kv_dim, dtype, cfg.attn_bias),
        "wo": L.dense_init(ks[3], cfg.q_dim, cfg.d_model, dtype, bias=False),
    }
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(cfg.head_dim, dtype)
        p["k_norm"] = L.rmsnorm_init(cfg.head_dim, dtype)
    return p


def _project_qkv(p, cfg, x, positions):
    B, T, _ = x.shape
    q = L.dense(p["wq"], x).reshape(B, T, cfg.num_heads, cfg.head_dim)
    k = L.dense(p["wk"], x).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    v = L.dense(p["wv"], x).reshape(B, T, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = L.rmsnorm(p["q_norm"], q)
        k = L.rmsnorm(p["k_norm"], k)
    q = L.apply_rope(q, positions, cfg.rope_theta)
    k = L.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attend(q, k, v, q_pos, k_pos, *, causal, window, cap, scale, k_valid=None):
    """q: (B,Tq,H,hd)  k,v: (B,Tk,KV,hd)  -> (B,Tq,H,hd). fp32 softmax."""
    B, Tq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Tq, KV, G, hd)
    s = jnp.einsum("btkgh,bskh->bkgts", qg, k).astype(jnp.float32) * scale
    s = L.softcap(s, cap)
    mask = jnp.ones((B, 1, 1, Tq, k.shape[1]), bool)
    qp = q_pos[:, None, None, :, None]
    kp = k_pos[:, None, None, None, :]
    if causal:
        mask &= kp <= qp
    if window:
        mask &= qp - kp < window
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgts,bskh->btkgh", w.astype(v.dtype), v)
    return o.reshape(B, Tq, H, hd)


def chunked_attend(q, k, v, q_pos, k_pos, *, causal, window, cap, scale,
                   q_chunk=512, unroll=False, _infer_cast=True):
    """Memory-safe attention: `lax.scan` over query chunks so only an
    O(q_chunk * T) score block is ever live (the pure-jnp stand-in for the
    Pallas flash kernel; also its oracle).

    §Perf: when a causal sliding window is active and the sequence is long,
    each query chunk only attends to a dynamic slice of q_chunk+window keys
    instead of all T — the masked-out key blocks were pure waste (this cut
    hymba prefill_32k attention work ~T/(q_chunk+window) = 21x; see
    EXPERIMENTS.md §Perf-3).

    When the kernel dispatch layer routes to Pallas (TPU/GPU, or forced
    interpret/pallas mode), the whole call lowers to the flash-attention
    kernel instead: online softmax over KV tiles in VMEM, GQA via the
    BlockSpec index maps, backward through the Pallas dq/dk/dv recompute
    kernels. Under `force('reference')` it lowers to the full-T^2 oracle
    through the same dispatch seam (the measuring stick — O(T^2) memory).
    Callers here pass per-row contiguous positions (arange + offset) for
    both q_pos and k_pos, which is exactly the index-based masking the
    kernel applies."""
    B, T, H, hd = q.shape
    impl = dispatch.resolve()
    if impl != "fast":
        o = dispatch.attention(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), scale=scale, causal=causal,
            window=window, cap=cap)
        return o.transpose(0, 2, 1, 3)
    infer_bf16 = _infer_cast and dispatch.infer_mode() == "bf16"
    if _infer_cast:        # the bf16 re-entry already counted itself
        dispatch.note("attention", "fast", (f"q_chunk={q_chunk}",) +
                      (("bf16",) if infer_bf16 else ()))
    if infer_bf16:
        # inference-only reduced precision: bf16 inputs, fp32 softmax as
        # usual inside _attend (input-rounding emulation of the mixed
        # kernel path; CPU has no native bf16 matmul to accumulate in)
        out_dtype = q.dtype
        q, k, v = (x.astype(jnp.bfloat16) for x in (q, k, v))
        o = chunked_attend(q, k, v, q_pos, k_pos, causal=causal,
                           window=window, cap=cap, scale=scale,
                           q_chunk=q_chunk, unroll=unroll, _infer_cast=False)
        return o.astype(out_dtype)
    if T <= q_chunk or T % q_chunk:
        return _attend(q, k, v, q_pos, k_pos, causal=causal, window=window,
                       cap=cap, scale=scale)
    n = T // q_chunk
    qc = q.reshape(B, n, q_chunk, H, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(B, n, q_chunk).transpose(1, 0, 2)
    Lw = q_chunk + window
    windowed = causal and window and Lw < T

    def body(carry, xs):
        qi, pi, idx = xs
        if windowed:
            start = jnp.clip((idx + 1) * q_chunk - Lw, 0, T - Lw)
            ks = jax.lax.dynamic_slice_in_dim(k, start, Lw, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, Lw, axis=1)
            kp = jax.lax.dynamic_slice_in_dim(k_pos, start, Lw, axis=1)
        else:
            ks, vs, kp = k, v, k_pos
        oi = _attend(qi, ks, vs, pi, kp, causal=causal, window=window,
                     cap=cap, scale=scale)
        return carry, oi

    idxs = jnp.arange(n)
    if unroll:
        ocs = [body(None, (qc[i], pc[i], idxs[i]))[1] for i in range(n)]
        oc = jnp.stack(ocs)
    else:
        _, oc = jax.lax.scan(body, None, (qc, pc, idxs))
    return oc.transpose(1, 0, 2, 3, 4).reshape(B, T, H, hd)


def full_attention(p, cfg, x, positions, *, layer_type="global", q_chunk=512,
                   unroll=False):
    """Full-sequence attention, scanned over query chunks (no O(T^2) buffer).

    layer_type: 'global' (full causal), 'local' (sliding window), or the
    config-level sliding_window if set. Encoder-only archs are bidirectional.
    """
    B, T, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.sliding_window if (layer_type == "local" and cfg.sliding_window) else 0
    o = chunked_attend(q, k, v, positions, positions, causal=not cfg.encoder_only,
                       window=window, cap=cfg.attn_logit_softcap,
                       scale=cfg.head_dim ** -0.5, q_chunk=q_chunk, unroll=unroll)
    return L.dense(p["wo"], o.reshape(B, T, cfg.q_dim))


# -- decode with (ring-buffer) KV cache ---------------------------------------

def init_kv_cache(cfg, batch, cache_len, dtype, prefilled: int = 0):
    """Cache of `cache_len` slots. `prefilled` marks how many are valid
    (dry-run decode shapes prefill the whole cache)."""
    k = jnp.zeros((batch, cache_len, cfg.num_kv_heads, cfg.head_dim), dtype)
    v = jnp.zeros_like(k)
    if prefilled:
        pos = jnp.broadcast_to(jnp.arange(cache_len, dtype=jnp.int32), (batch, cache_len))
        length = jnp.full((batch,), prefilled, jnp.int32)
    else:
        pos = jnp.full((batch, cache_len), -1, jnp.int32)
        length = jnp.zeros((batch,), jnp.int32)
    return {"k": k, "v": v, "pos": pos, "length": length}


def decode_attention(p, cfg, x, cache, *, layer_type="global", window_override=0,
                     uniform=False):
    """One-token decode. x: (B, 1, d). Returns (y, new_cache).

    The new k/v is written at slot (length mod cache_len) — a ring buffer:
    with window_override=W and cache_len=W this is O(W) memory at any
    sequence length (the sub-quadratic long_500k variant).

    `uniform=True` (all rows at the same position — the serving dry-run
    case) writes via dynamic_update_slice instead of a batched scatter:
    GSPMD keeps the cache sharding intact (the scatter forced an
    "involuntary full rematerialization" = replicate + repartition of the
    whole multi-GiB cache each step; see EXPERIMENTS.md §Perf-1).
    """
    B, T, _ = x.shape
    assert T == 1
    t = cache["length"]                              # (B,) current position
    q, k, v = _project_qkv(p, cfg, x, t[:, None])
    W = cache["k"].shape[1]
    slot = (t % W).astype(jnp.int32)
    if uniform:
        s0 = slot[0]
        z = jnp.int32(0)
        new_k = jax.lax.dynamic_update_slice(cache["k"], k, (z, s0, z, z))
        new_v = jax.lax.dynamic_update_slice(cache["v"], v, (z, s0, z, z))
        new_pos = jax.lax.dynamic_update_slice(cache["pos"], t[:, None], (z, s0))
    else:
        b_idx = jnp.arange(B)
        new_k = cache["k"].at[b_idx, slot].set(k[:, 0])
        new_v = cache["v"].at[b_idx, slot].set(v[:, 0])
        new_pos = cache["pos"].at[b_idx, slot].set(t)

    window = window_override or (cfg.sliding_window if layer_type == "local" else 0)
    k_valid = new_pos >= 0
    o = _attend(q, new_k, new_v, t[:, None], new_pos,
                causal=True, window=window, cap=cfg.attn_logit_softcap,
                scale=cfg.head_dim ** -0.5, k_valid=k_valid)
    y = L.dense(p["wo"], o.reshape(B, 1, cfg.q_dim))
    new_cache = {"k": new_k, "v": new_v, "pos": new_pos, "length": t + 1}
    return y, new_cache
