"""Transformer assembly for every assigned arch family.

Layer stacks are `lax.scan` over params stacked on a leading "repeat" axis —
compile-time/HLO-size critical for the 88–94-layer dry-runs. The repeat unit
is `cfg.layer_pattern` (gemma2 scans ('local','global') pairs); kimi-k2's
leading dense layer lives in a separate scanned prefix stack.

Three entry points (the learner / InfServer steps of the TLeague mapping):
  forward_train(params, cfg, batch)        -> (logits, values, aux)
  prefill(params, cfg, batch, cache_len)   -> (logits_last, values_last, state)
  decode_step(params, cfg, tokens, state)  -> (logits, values, state)
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.models import attention as A
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S


# ===========================================================================
# init
# ===========================================================================

def _init_dense_unit(rng, cfg, dtype, with_moe: bool):
    """One repeat unit for attention-bearing families."""
    n = len(cfg.layer_pattern)
    subs = []
    for j in range(n):
        ks = iter(jax.random.split(jax.random.fold_in(rng, j), 8))
        sub = {
            "attn_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
            "attn": A.init_attention(next(ks), cfg, dtype),
            "mlp_norm": L.norm_init(cfg.norm, cfg.d_model, dtype),
        }
        if cfg.family == "hybrid":
            sub["mamba"] = S.init_mamba(next(ks), cfg, dtype)
            sub["attn_out_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            sub["ssm_out_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            sub["fuse_beta"] = jnp.ones((2,), dtype)
        if with_moe:
            sub["moe"] = M.init_moe(next(ks), cfg, dtype)
        else:
            sub["mlp"] = L.mlp_init(next(ks), cfg.d_model, cfg.d_ff, dtype,
                                    gated=cfg.mlp_gated)
        if cfg.post_block_norms:
            sub["post_attn_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
            sub["post_mlp_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
        subs.append(sub)
    return {f"sub{j}": s for j, s in enumerate(subs)}


def _init_rwkv_unit(rng, cfg, dtype):
    ks = jax.random.split(rng, 2)
    return {"sub0": {
        "tm_norm": L.layernorm_init(cfg.d_model, dtype),
        "time_mix": S.init_rwkv_time_mix(ks[0], cfg, dtype),
        "cm_norm": L.layernorm_init(cfg.d_model, dtype),
        "channel_mix": S.init_rwkv_channel_mix(ks[1], cfg, dtype),
    }}


def _n_repeats(cfg):
    n_unit = len(cfg.layer_pattern)
    fkd = cfg.moe.first_k_dense if cfg.moe else 0
    n = cfg.num_layers - fkd
    assert n % n_unit == 0, (cfg.name, cfg.num_layers, cfg.layer_pattern)
    return n // n_unit


def init_params(rng, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ks = iter(jax.random.split(rng, 8))
    p: Dict[str, Any] = {"embed": L.embed_init(next(ks), cfg.vocab_size, cfg.d_model, dtype)}

    if cfg.family == "ssm":
        unit_fn = lambda r: _init_rwkv_unit(r, cfg, dtype)
    else:
        unit_fn = lambda r: _init_dense_unit(r, cfg, dtype, with_moe=cfg.moe is not None)

    reps = _n_repeats(cfg)
    p["blocks"] = jax.vmap(unit_fn)(jax.random.split(next(ks), reps))
    if cfg.moe and cfg.moe.first_k_dense:
        dense_fn = lambda r: _init_dense_unit(r, cfg, dtype, with_moe=False)
        p["dense_prefix"] = jax.vmap(dense_fn)(
            jax.random.split(next(ks), cfg.moe.first_k_dense))

    p["final_norm"] = L.norm_init(cfg.norm, cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(next(ks), cfg.d_model, cfg.vocab_size, dtype)
    p["value_head"] = {
        "h": L.dense_init(next(ks), cfg.d_model, cfg.value_head_hidden, dtype, bias=True),
        "out": L.dense_init(next(ks), cfg.value_head_hidden, 1, dtype, bias=True),
    }
    return p


# ===========================================================================
# sublayer application
# ===========================================================================

def _apply_unit_full(cfg, unit, x, positions, q_chunk=512, unroll=False):
    """Full-sequence (train) pass of one repeat unit. Returns (x, aux)."""
    aux = jnp.float32(0.0)
    if cfg.family == "ssm":
        sub = unit["sub0"]
        B = x.shape[0]
        zprev = jnp.zeros((B, cfg.d_model), x.dtype)
        h = L.layernorm(sub["tm_norm"], x)
        S0 = S.init_rwkv_state(cfg, B, x.dtype)[1]
        y, _ = S.rwkv_time_mix(sub["time_mix"], cfg, h, zprev, S0)
        x = x + y
        h = L.layernorm(sub["cm_norm"], x)
        y, _ = S.rwkv_channel_mix(sub["channel_mix"], cfg, h, zprev)
        return x + y, aux

    for j, lt in enumerate(cfg.layer_pattern):
        sub = unit[f"sub{j}"]
        h = L.norm_apply(cfg.norm, sub["attn_norm"], x)
        attn_out = A.full_attention(sub["attn"], cfg, h, positions,
                                    layer_type=lt, q_chunk=q_chunk,
                                    unroll=unroll)
        if cfg.family == "hybrid":
            ssm_out, _ = S.mamba_apply(sub["mamba"], cfg, h)
            beta = sub["fuse_beta"].astype(x.dtype)
            attn_out = (0.5 * (
                beta[0] * L.norm_apply(cfg.norm, sub["attn_out_norm"], attn_out)
                + beta[1] * L.norm_apply(cfg.norm, sub["ssm_out_norm"], ssm_out))
            ).astype(x.dtype)
        if cfg.post_block_norms:
            attn_out = L.norm_apply(cfg.norm, sub["post_attn_norm"], attn_out)
        x = x + attn_out
        h = L.norm_apply(cfg.norm, sub["mlp_norm"], x)
        if "moe" in sub:
            y, a = M.moe_apply(sub["moe"], cfg, h)
            aux = aux + a
        else:
            y = L.mlp(sub["mlp"], h, cfg.activation)
        if cfg.post_block_norms:
            y = L.norm_apply(cfg.norm, sub["post_mlp_norm"], y)
        x = x + y
    return x, aux


def _apply_unit_step(cfg, unit, x, cache, positions, window_override=0,
                     uniform=False):
    """Single-token decode pass of one repeat unit. Returns (x, new_cache)."""
    if cfg.family == "ssm":
        sub = unit["sub0"]
        x_tm, Sst, x_cm = cache["tm_prev"], cache["tm_S"], cache["cm_prev"]
        h = L.layernorm(sub["tm_norm"], x)
        y, (x_tm2, S2) = S.rwkv_time_mix_step(sub["time_mix"], cfg, h, (x_tm, Sst))
        x = x + y
        h = L.layernorm(sub["cm_norm"], x)
        y, x_cm2 = S.rwkv_channel_mix(sub["channel_mix"], cfg, h, x_cm)
        x = x + y
        return x, {"tm_prev": x_tm2, "tm_S": S2, "cm_prev": x_cm2}

    new_cache = {}
    for j, lt in enumerate(cfg.layer_pattern):
        sub = unit[f"sub{j}"]
        h = L.norm_apply(cfg.norm, sub["attn_norm"], x)
        attn_out, kv2 = A.decode_attention(sub["attn"], cfg, h, cache[f"kv{j}"],
                                           layer_type=lt,
                                           window_override=window_override,
                                           uniform=uniform)
        new_cache[f"kv{j}"] = kv2
        if cfg.family == "hybrid":
            ssm_out, st2 = S.mamba_apply(sub["mamba"], cfg, h,
                                         state=(cache[f"conv{j}"], cache[f"ssm{j}"]))
            new_cache[f"conv{j}"], new_cache[f"ssm{j}"] = st2
            beta = sub["fuse_beta"].astype(x.dtype)
            attn_out = (0.5 * (
                beta[0] * L.norm_apply(cfg.norm, sub["attn_out_norm"], attn_out)
                + beta[1] * L.norm_apply(cfg.norm, sub["ssm_out_norm"], ssm_out))
            ).astype(x.dtype)
        if cfg.post_block_norms:
            attn_out = L.norm_apply(cfg.norm, sub["post_attn_norm"], attn_out)
        x = x + attn_out
        h = L.norm_apply(cfg.norm, sub["mlp_norm"], x)
        if "moe" in sub:
            y, _ = M.moe_apply(sub["moe"], cfg, h)
        else:
            y = L.mlp(sub["mlp"], h, cfg.activation)
        if cfg.post_block_norms:
            y = L.norm_apply(cfg.norm, sub["post_mlp_norm"], y)
        x = x + y
    return x, new_cache


# ===========================================================================
# embedding / heads
# ===========================================================================

def embed_inputs(params, cfg, batch):
    """batch: {'tokens': (B,T) int32} and/or modality embeddings per the
    assignment carve-out: {'patch_embeds': (B,P,d)} (vlm) or
    {'frame_embeds': (B,T,d)} (audio)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    parts = []
    if "patch_embeds" in batch:
        parts.append(batch["patch_embeds"].astype(cdt))
    if "frame_embeds" in batch:
        parts.append(batch["frame_embeds"].astype(cdt))
    if "tokens" in batch and batch["tokens"] is not None:
        parts.append(L.embed(params["embed"], batch["tokens"], cdt, cfg.embed_scale))
    x = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    B, T = x.shape[0], x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return x, positions


def heads(params, cfg, x):
    h = L.norm_apply(cfg.norm, params["final_norm"], x)
    if cfg.tie_embeddings:
        logits = h @ params["embed"]["table"].astype(h.dtype).T
    else:
        logits = L.dense(params["lm_head"], h)
    logits = L.softcap(logits.astype(jnp.float32), cfg.final_logit_softcap)
    vh = jax.nn.tanh(L.dense(params["value_head"]["h"], h))
    values = L.dense(params["value_head"]["out"], vh)[..., 0].astype(jnp.float32)
    return logits, values


# ===========================================================================
# entry points
# ===========================================================================

def _maybe_scan(fn, carry, xs, unroll: bool):
    """lax.scan, or a traced python loop when `unroll` (used by the dry-run
    to make XLA cost analysis see every repeat — while-loop bodies are
    otherwise counted once, not x trip-count)."""
    if not unroll:
        return jax.lax.scan(fn, carry, xs)
    R = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for r in range(R):
        carry, y = fn(carry, jax.tree.map(lambda a: a[r], xs))
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


def forward_train(params, cfg, batch, q_chunk=512, remat=False, unroll=False):
    """Returns (logits (B,T,V) fp32, values (B,T) fp32, aux scalar).

    remat=True checkpoints each scanned repeat unit (activation memory
    O(sqrt-ish): one unit's activations live at a time in the backward)."""
    x, positions = embed_inputs(params, cfg, batch)

    def scan_fn(carry, unit):
        x, aux = carry
        x, a = _apply_unit_full(cfg, unit, x, positions, q_chunk=q_chunk,
                                unroll=unroll)
        return (x, aux + a), None

    if remat:
        scan_fn = jax.checkpoint(scan_fn)

    aux = jnp.float32(0.0)
    if "dense_prefix" in params:
        (x, aux), _ = _maybe_scan(scan_fn, (x, aux), params["dense_prefix"], unroll)
    (x, aux), _ = _maybe_scan(scan_fn, (x, aux), params["blocks"], unroll)
    logits, values = heads(params, cfg, x)
    return logits, values, aux


def _init_unit_cache(cfg, batch, cache_len, dtype, prefilled=0):
    if cfg.family == "ssm":
        xp, Sst = S.init_rwkv_state(cfg, batch, dtype)
        return {"tm_prev": xp, "tm_S": Sst, "cm_prev": xp}
    c = {}
    for j in range(len(cfg.layer_pattern)):
        c[f"kv{j}"] = A.init_kv_cache(cfg, batch, cache_len, dtype, prefilled)
        if cfg.family == "hybrid":
            conv, h = S.init_mamba_state(cfg, batch, dtype)
            c[f"conv{j}"], c[f"ssm{j}"] = conv, h
    return c


def init_decode_state(cfg, batch, seq_len, *, sliding=False, prefilled=None):
    """State for `decode_step`. sliding=True uses the O(window) ring buffer
    (the sub-quadratic long_500k variant)."""
    assert not cfg.encoder_only, f"{cfg.name} is encoder-only: no decode step"
    dtype = jnp.dtype(cfg.compute_dtype)
    cache_len = min(seq_len, cfg.long_context_window) if sliding else seq_len
    pref = seq_len if prefilled is None else prefilled
    pref = min(pref, cache_len)
    reps = _n_repeats(cfg)

    def one(_):
        return _init_unit_cache(cfg, batch, cache_len, dtype, prefilled=pref)

    state = {"blocks": jax.vmap(one)(jnp.arange(reps))}
    if cfg.moe and cfg.moe.first_k_dense:
        state["dense_prefix"] = jax.vmap(one)(jnp.arange(cfg.moe.first_k_dense))
    # ring-buffer semantics: `length` is the absolute next position even when
    # the cache only holds the last `cache_len` entries.
    state["length"] = jnp.full((batch,), seq_len, jnp.int32)
    return state


def decode_step(params, cfg, tokens, state, *, window=0, unroll=False,
                uniform=False):
    """tokens: (B, 1) int32 (or embeds dict). `window` (static) > 0 enables
    sliding-window masking — pair with a ring-buffer cache of that size for
    the sub-quadratic long_500k variant. Returns (logits, values, state)."""
    batch = tokens if isinstance(tokens, dict) else {"tokens": tokens}
    cdt = jnp.dtype(cfg.compute_dtype)
    if "tokens" in batch:
        x = L.embed(params["embed"], batch["tokens"], cdt, cfg.embed_scale)
    else:
        x = batch["patch_embeds"].astype(cdt)

    def scan_fn(x, xs):
        unit, cache = xs
        if isinstance(cache, dict):
            cache = dict(cache)
            for key, sub in cache.items():
                if isinstance(sub, dict) and "length" in sub:
                    sub = dict(sub)
                    sub["length"] = state["length"]
                    cache[key] = sub
        x, new_cache = _apply_unit_step(cfg, unit, x, cache, None,
                                        window_override=window,
                                        uniform=uniform)
        return x, new_cache

    new_state = dict(state)
    if "dense_prefix" in params:
        x, nc = _maybe_scan(scan_fn, x, (params["dense_prefix"], state["dense_prefix"]),
                            unroll)
        new_state["dense_prefix"] = nc
    x, nc = _maybe_scan(scan_fn, x, (params["blocks"], state["blocks"]), unroll)
    new_state["blocks"] = nc
    new_state["length"] = state["length"] + 1
    logits, values = heads(params, cfg, x)
    return logits, values, new_state


def prefill(params, cfg, batch, *, sliding=False, q_chunk=512, unroll=False,
            reserve=64):
    """Full forward + build decode state from the computed K/V.

    `reserve` extra cache slots keep subsequent decode_steps from ring-
    overwriting the oldest prefilled keys (slot t % cache_len).
    Returns (logits (B,T,V), values, decode_state)."""
    x, positions = embed_inputs(params, cfg, batch)
    B, T = x.shape[0], x.shape[1]
    dtype = jnp.dtype(cfg.compute_dtype)
    cache_len = min(T, cfg.long_context_window) if sliding else T + reserve

    def unit_prefill(x, unit):
        cache = {}
        if cfg.family == "ssm":
            sub = unit["sub0"]
            zprev = jnp.zeros((B, cfg.d_model), x.dtype)
            h = L.layernorm(sub["tm_norm"], x)
            S0 = S.init_rwkv_state(cfg, B, x.dtype)[1]
            y, (xtm, Slast) = S.rwkv_time_mix(sub["time_mix"], cfg, h, zprev, S0)
            x = x + y
            h = L.layernorm(sub["cm_norm"], x)
            y, xcm = S.rwkv_channel_mix(sub["channel_mix"], cfg, h, zprev)
            x = x + y
            return x, {"tm_prev": xtm, "tm_S": Slast, "cm_prev": xcm}
        for j, lt in enumerate(cfg.layer_pattern):
            sub = unit[f"sub{j}"]
            h = L.norm_apply(cfg.norm, sub["attn_norm"], x)
            q, k, v = A._project_qkv(sub["attn"], cfg, h, positions)
            window = cfg.sliding_window if (lt == "local" and cfg.sliding_window) else 0
            o = A.chunked_attend(q, k, v, positions, positions,
                                 causal=not cfg.encoder_only, window=window,
                                 cap=cfg.attn_logit_softcap,
                                 scale=cfg.head_dim ** -0.5, q_chunk=q_chunk,
                                 unroll=unroll)
            attn_out = L.dense(sub["attn"]["wo"], o.reshape(B, T, cfg.q_dim))
            kc = A.init_kv_cache(cfg, B, cache_len, dtype, prefilled=0)
            tail = slice(T - cache_len, T)
            slot = positions[:, tail] % cache_len
            bi = jnp.arange(B)[:, None]
            kc["k"] = kc["k"].at[bi, slot].set(k[:, tail])
            kc["v"] = kc["v"].at[bi, slot].set(v[:, tail])
            kc["pos"] = kc["pos"].at[bi, slot].set(positions[:, tail])
            kc["length"] = jnp.full((B,), T, jnp.int32)
            cache[f"kv{j}"] = kc
            if cfg.family == "hybrid":
                ssm_out, st = S.mamba_apply(sub["mamba"], cfg, h)
                cache[f"conv{j}"], cache[f"ssm{j}"] = st
                beta = sub["fuse_beta"].astype(x.dtype)
                attn_out = (0.5 * (
                    beta[0] * L.norm_apply(cfg.norm, sub["attn_out_norm"], attn_out)
                    + beta[1] * L.norm_apply(cfg.norm, sub["ssm_out_norm"], ssm_out))
                ).astype(x.dtype)
            if cfg.post_block_norms:
                attn_out = L.norm_apply(cfg.norm, sub["post_attn_norm"], attn_out)
            x = x + attn_out
            h = L.norm_apply(cfg.norm, sub["mlp_norm"], x)
            if "moe" in sub:
                y, _ = M.moe_apply(sub["moe"], cfg, h)
            else:
                y = L.mlp(sub["mlp"], h, cfg.activation)
            if cfg.post_block_norms:
                y = L.norm_apply(cfg.norm, sub["post_mlp_norm"], y)
            x = x + y
        return x, cache

    state = {}
    if "dense_prefix" in params:
        x, nc = _maybe_scan(unit_prefill, x, params["dense_prefix"], unroll)
        state["dense_prefix"] = nc
    x, nc = _maybe_scan(unit_prefill, x, params["blocks"], unroll)
    state["blocks"] = nc
    state["length"] = jnp.full((B,), T, jnp.int32)
    logits, values = heads(params, cfg, x)
    return logits, values, state
