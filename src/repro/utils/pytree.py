"""Small pytree helpers used across the framework (no flax/optax in env)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_count_params(tree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))


def tree_copy(tree):
    """Deep-copy every array leaf (jax or numpy); immutable leaves pass
    through. The defensive snapshot used wherever a pytree crosses an
    ownership boundary (ModelPool pulls, PBT exploits, seed stashes) so a
    later donating train step can never delete a shared buffer."""
    return jax.tree.map(lambda x: x.copy() if hasattr(x, "copy") else x, tree)


def tree_zeros_like(tree, dtype=None):
    return jax.tree.map(lambda x: jnp.zeros(x.shape, dtype or x.dtype), tree)


def tree_cast(tree, dtype):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, tree)


def tree_global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_scale(tree, s):
    return jax.tree.map(lambda x: x * s, tree)


def tree_lerp(a, b, t):
    """a + t * (b - a), used for polyak-style parameter mixing."""
    return jax.tree.map(lambda x, y: x + t * (y - x), a, b)
