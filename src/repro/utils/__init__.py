from repro.utils.pytree import (
    tree_count_params,
    tree_bytes,
    tree_zeros_like,
    tree_cast,
    tree_global_norm,
    tree_add,
    tree_scale,
    tree_lerp,
)
from repro.utils.registry import Registry
