"""A tiny name->factory registry (envs, archs, game managers, losses)."""
from __future__ import annotations

from typing import Callable, Dict, Generic, TypeVar

T = TypeVar("T")


class Registry(Generic[T]):
    def __init__(self, kind: str):
        self.kind = kind
        self._items: Dict[str, T] = {}

    def register(self, name: str, item: T | None = None):
        if item is not None:
            self._items[name] = item
            return item

        def deco(fn: T) -> T:
            self._items[name] = fn
            return fn

        return deco

    def get(self, name: str) -> T:
        if name not in self._items:
            raise KeyError(f"unknown {self.kind} {name!r}; known: {sorted(self._items)}")
        return self._items[name]

    def __contains__(self, name: str) -> bool:
        return name in self._items

    def names(self):
        return sorted(self._items)
