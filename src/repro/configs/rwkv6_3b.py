"""rwkv6-3b [ssm] — Finch: 32L d_model=2560 (attn-free) d_ff=8960
vocab=65536, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="rwkv6-3b",
    family="ssm",
    source="arXiv:2404.05892 (RWKV-6 Finch)",
    num_layers=32,
    d_model=2560,
    num_heads=40,          # d_model / head_size
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    ssm=SSMConfig(kind="rwkv6", head_size=64, lora_rank=64),
    norm="layernorm",
    param_dtype="float32",
)

ARCHS.register("rwkv6-3b", CONFIG)
