"""The paper's own policy-net scale (TPolicies §3.5): small nets used for the
actual CPU-runnable league training (examples, integration tests).

TLeague's ViZDoom/Pommerman nets are conv+LSTM; our env observations are
tokenized (DESIGN.md §4), so the equivalent sequence policy is a small
transformer. Registered alongside the assigned archs so the whole system is
exercised end-to-end at laptop scale with the same code paths.
"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig

# action/observation vocab for the bundled envs (see repro/envs):
# env obs tokens + action tokens share one table.
POLICY_S = ArchConfig(
    name="tleague-policy-s",
    family="dense",
    source="arXiv:2011.12895 (TLeague, TPolicies-scale policy net)",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    rope_theta=10_000.0,
    param_dtype="float32",
    value_head_hidden=64,
    max_position=2048,
)

POLICY_M = ArchConfig(
    name="tleague-policy-m",
    family="dense",
    source="arXiv:2011.12895 (TLeague)",
    num_layers=4,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    rope_theta=10_000.0,
    param_dtype="float32",
    value_head_hidden=128,
    max_position=2048,
)

ARCHS.register("tleague-policy-s", POLICY_S)
ARCHS.register("tleague-policy-m", POLICY_M)
