"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention + mamba heads per layer.
[arXiv:2411.13676]

Hymba runs sliding-window attention on all but 3 layers (the SSM path
carries global context); we use SWA on every layer => sub-quadratic,
`long_500k` runs natively. Meta-tokens are omitted (orthogonal to the
parallel-heads contribution).
"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="hymba-1.5b",
    family="hybrid",
    source="arXiv:2411.13676 (Hymba)",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    sliding_window=1024,
    layer_pattern=("local",),
    ssm=SSMConfig(kind="mamba", state_size=16, expand=2, conv_kernel=4),
    long_context_window=1024,   # ring KV == SWA window (long_500k decode)
    param_dtype="float32",
)

ARCHS.register("hymba-1.5b", CONFIG)
