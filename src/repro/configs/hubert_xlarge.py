"""hubert-xlarge [audio] — 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (k-means units), encoder-only (w2v2 arch). [arXiv:2106.07447]

Per the carve-out, the mel-spectrogram + conv feature extractor is a stub:
`input_specs` provides frame embeddings. Encoder-only => no decode step
(decode_32k / long_500k skipped; see DESIGN.md). `train_4k` is masked-unit
prediction, `prefill_32k` is the batched encoder forward (the InfServer role
for an encoder).
"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge",
    family="audio",
    source="arXiv:2106.07447 (HuBERT X-Large)",
    num_layers=48,
    d_model=1280,
    num_heads=16,
    num_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab_size=504,
    encoder_only=True,
    frontend="audio",
    norm="layernorm",
    activation="gelu",
    mlp_gated=False,
    param_dtype="float32",
)

ARCHS.register("hubert-xlarge", CONFIG)
