"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B family]"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B (235B-A22B scaling)",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    qk_norm=True,
    moe=MoEConfig(
        num_experts=128,
        experts_per_token=8,
        d_ff_expert=1536,
    ),
    rope_theta=1_000_000.0,
    param_dtype="bfloat16",
)

ARCHS.register("qwen3-moe-235b-a22b", CONFIG)
