"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
vocab=163840, MoE 384 experts top-8, 1 shared expert, first layer dense.
[arXiv:2501.kimi2 — trillion-param MoE, paper-table entry]"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    source="arXiv:2501.kimi2 (Kimi K2)",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=2048,                     # per-assignment: expert/shared hidden
    vocab_size=163840,
    moe=MoEConfig(
        num_experts=384,
        experts_per_token=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        first_k_dense=1,
    ),
    rope_theta=50_000.0,
    param_dtype="bfloat16",
)

ARCHS.register("kimi-k2-1t-a32b", CONFIG)
