"""Assigned-architecture registry: `get_arch(name)`, `list_archs()`.

Every entry cites its source (model card / paper) and exactly matches the
assignment table. `<cfg>.smoke()` is the reduced same-family variant for CPU
smoke tests; full configs are exercised via the dry-run only.
"""
from repro.configs.base import ArchConfig, MoEConfig, SSMConfig, InputShape, INPUT_SHAPES
from repro.utils.registry import Registry

ARCHS: Registry = Registry("arch")

from repro.configs import (  # noqa: E402  (registration imports)
    qwen3_8b,
    mistral_large_123b,
    command_r_35b,
    pixtral_12b,
    rwkv6_3b,
    hubert_xlarge,
    gemma2_2b,
    kimi_k2_1t_a32b,
    qwen3_moe_235b_a22b,
    hymba_1p5b,
    tleague_nets,
)


def get_arch(name: str) -> ArchConfig:
    return ARCHS.get(name)


def list_archs():
    return ARCHS.names()
