"""gemma2-2b [dense] — 26L d_model=2304 8H (GQA kv=4) d_ff=9216
vocab=256000; local+global alternating, logit softcap. [arXiv:2408.00118]"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    source="arXiv:2408.00118 (Gemma 2)",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    sliding_window=4096,
    layer_pattern=("local", "global"),
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norms=True,
    tie_embeddings=True,
    embed_scale=True,
    activation="gelu_tanh",
    param_dtype="float32",
)

ARCHS.register("gemma2-2b", CONFIG)
