"""pixtral-12b [vlm] — 40L d_model=5120 32H (GQA kv=8) d_ff=14336
vocab=131072; pixtral-ViT frontend + mistral-nemo decoder.
[hf:mistralai/Pixtral-12B-2409]

Per the assignment carve-out the ViT frontend is a stub: `input_specs`
provides precomputed patch embeddings of the right shape; this config is the
language/decoder transformer that consumes them.
"""
from repro.configs import ARCHS
from repro.configs.base import ArchConfig

NUM_PATCHES = 1024  # stub frontend: 32x32 patch grid per image

CONFIG = ArchConfig(
    name="pixtral-12b",
    family="vlm",
    source="hf:mistralai/Pixtral-12B-2409",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=131072,
    rope_theta=1_000_000_000.0,
    frontend="vision",
    param_dtype="bfloat16",
)

ARCHS.register("pixtral-12b", CONFIG)
