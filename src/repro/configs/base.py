"""Architecture / input-shape config system.

Every assigned architecture gets one `ArchConfig` in `repro/configs/<id>.py`
citing its source. `smoke()` returns the reduced same-family variant used by
CPU smoke tests; the full config is exercised only by the dry-run
(ShapeDtypeStructs, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int            # top-k
    d_ff_expert: int                  # hidden dim per expert
    num_shared_experts: int = 0       # kimi-k2 style always-on shared expert(s)
    capacity_factor: float = 1.25     # train-time token capacity per expert
    router_aux_coef: float = 0.01     # load-balance loss weight
    first_k_dense: int = 0            # leading dense (non-MoE) layers


@dataclass(frozen=True)
class SSMConfig:
    kind: str = "rwkv6"               # 'rwkv6' | 'mamba'
    head_size: int = 64               # rwkv6 per-head dim
    state_size: int = 16              # mamba N (ssm_state)
    expand: int = 2                   # mamba d_inner = expand * d_model
    conv_kernel: int = 4              # mamba causal-conv width
    dt_rank: int = 0                  # 0 -> ceil(d_model/16)
    lora_rank: int = 64               # rwkv6 data-dependent-decay lora rank


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    source: str                       # citation per assignment

    num_layers: int = 0
    d_model: int = 0
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0
    d_ff: int = 0
    vocab_size: int = 0

    # attention features
    qk_norm: bool = False
    attn_bias: bool = False
    rope_theta: float = 10_000.0
    attn_logit_softcap: float = 0.0   # gemma2: 50.0
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    sliding_window: int = 0           # 0 = full attention
    layer_pattern: Tuple[str, ...] = ("global",)  # repeat unit, e.g. ("local","global")

    # families
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder_only: bool = False        # hubert: bidirectional, no decode step
    frontend: Optional[str] = None    # 'audio'|'vision': embeddings provided by stub

    # misc
    post_block_norms: bool = False    # gemma2: extra norm after attn/mlp outputs
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    activation: str = "silu"
    mlp_gated: bool = True            # GLU-style MLP
    tie_embeddings: bool = False
    embed_scale: bool = False         # gemma-style sqrt(d_model) embed scaling
    max_position: int = 1 << 20

    # dtype policy
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # RL heads
    value_head_hidden: int = 256

    # long-context variant: if >0, decode/prefill use this sliding window
    # (ring-buffer KV cache) — the sub-quadratic variant for long_500k.
    long_context_window: int = 4096

    use_pallas: bool = False          # route attention through the Pallas kernel

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # -- derived ------------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + heads)."""
        d, L = self.d_model, self.num_layers
        n = self.vocab_size * d                     # embed
        if not self.tie_embeddings:
            n += self.vocab_size * d                # lm head
        per_layer = 0
        if self.family == "ssm" and self.ssm and self.ssm.kind == "rwkv6":
            heads = d // self.ssm.head_size
            r = self.ssm.lora_rank
            per_layer += 4 * d * d + d * d          # r,k,v,o(g)
            per_layer += 6 * (d * r + r * d)        # ddlerp loras (approx)
            per_layer += heads * self.ssm.head_size * 2
            per_layer += d * self.d_ff * 2          # rwkv channel-mix
        else:
            per_layer += d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
            if self.family == "hybrid" and self.ssm:
                di = self.ssm.expand * d
                per_layer += d * 2 * di + di * d + di * (2 * self.ssm.state_size + 32)
            if self.moe is not None:
                e = self.moe
                moe_ff = 3 * d * e.d_ff_expert if self.mlp_gated else 2 * d * e.d_ff_expert
                per_layer += e.num_experts * moe_ff + d * e.num_experts
                per_layer += e.num_shared_experts * 3 * d * self.d_ff
            else:
                per_layer += (3 if self.mlp_gated else 2) * d * self.d_ff
        n += L * per_layer
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        d, L, e = self.d_model, self.num_layers, self.moe
        full = self.param_count()
        moe_ff = (3 if self.mlp_gated else 2) * d * e.d_ff_expert
        n_moe_layers = L - e.first_k_dense
        inactive = n_moe_layers * (e.num_experts - e.experts_per_token) * moe_ff
        return full - inactive

    def smoke(self) -> "ArchConfig":
        """Reduced same-family variant: <=2 layers, d_model<=512, <=4 experts."""
        d = min(self.d_model, 256)
        heads = min(self.num_heads, 4)
        kv = max(1, min(self.num_kv_heads, heads, 2))
        hd = 64
        kw = dict(
            name=self.name + "-smoke",
            num_layers=max(2, len(self.layer_pattern)),
            d_model=d,
            num_heads=heads,
            num_kv_heads=kv,
            head_dim=hd,
            d_ff=min(self.d_ff, 512),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            long_context_window=128,
            max_position=4096,
        )
        if self.moe is not None:
            # capacity_factor high enough that smoke routing never drops:
            # consistency tests (prefill == train fwd) need drop-free MoE.
            kw["moe"] = replace(
                self.moe, num_experts=4, experts_per_token=2, d_ff_expert=128,
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                first_k_dense=min(self.moe.first_k_dense, 1),
                capacity_factor=8.0,
            )
        if self.ssm is not None:
            kw["ssm"] = replace(self.ssm, head_size=32, lora_rank=16)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


def dtype_of(name: str):
    return jnp.dtype(name)
