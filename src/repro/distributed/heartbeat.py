"""Coordinator liveness: heartbeats so workers can tell slow from dead.

The transport's failure signal (`TransportError`) only fires when the
kernel reports the peer gone (RST / closed socket). A coordinator that
is *wedged* — SIGSTOPped, deadlocked, or on the far side of a network
partition — keeps its sockets open and workers block forever inside
`recv`. The heartbeat channel closes that gap:

* **`Heartbeat`** — a monotonic beat counter the coordinator's beater
  thread bumps every `interval_s`. Served as `ctrl.ping` it is the
  liveness signal: a busy-but-alive coordinator still advances it (the
  beater thread needs only the GIL), a dead or frozen one cannot.
* **`HeartbeatMonitor`** — a worker-side thread with its OWN short-
  timeout RPC connection (so a slow bulk transfer on the main connection
  never starves the probe). The coordinator is declared dead only when
  the counter fails to ADVANCE for `timeout_s` — an unreachable server
  and a frozen one look identical, a merely slow one does not. On
  death it runs `on_dead` (typically: set a stop flag and close the
  worker's blocked RPC clients, which turns their in-flight `recv` into
  a `TransportError` the worker already treats as clean shutdown).
* **`probe`** / `python -m repro.distributed.heartbeat ADDR` — a
  one-shot liveness check (exit 0 alive / 1 dead) that the k8s renderer
  wires into pod liveness probes.
* **`BeatRegistry`** — the coordinator-side inverse: per-WORKER beat
  counters (actors beat through the ctrl plane on every segment and
  while waiting out backpressure), classified into alive vs stale by
  wall age. This is the signal that feeds the lease reaper: a stale
  actor's outstanding task lease is reaped and re-issued, an alive
  actor's lease deadline is pushed out. The same slow-vs-dead
  discrimination as the monitor — a SIGSTOPped actor that resumes
  beating goes back to alive (but any lease reaped during the stall
  stays reaped: generations never un-reap).

The same `Heartbeat` object doubles as the in-process channel: the
league runtime's coordinator thread beats it, and worker threads call
`stalled(timeout_s)` instead of running monitor threads.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Dict, List, Optional, Tuple


class Heartbeat:
    """A thread-safe beat counter with wall-age bookkeeping."""

    def __init__(self):
        self._lock = threading.Lock()
        self._n = 0
        self._t = time.monotonic()
        self._beater: Optional[threading.Thread] = None
        self._beater_stop = threading.Event()

    def beat(self) -> int:
        with self._lock:
            self._n += 1
            self._t = time.monotonic()
            return self._n

    def ping(self) -> int:
        """The RPC-served read: current beat count."""
        with self._lock:
            return self._n

    def age_s(self) -> float:
        with self._lock:
            return time.monotonic() - self._t

    def stalled(self, timeout_s: float) -> bool:
        """True when no beat landed for `timeout_s` — the in-process
        worker's dead-coordinator test."""
        return self.age_s() > timeout_s

    # -- background beater ---------------------------------------------------
    def start_beating(self, interval_s: float = 1.0) -> "Heartbeat":
        """Bump the counter from a daemon thread every `interval_s`.
        Idempotent; `stop_beating` (or process exit) ends it."""
        if self._beater is None:
            self._beater_stop.clear()
            self._beater = threading.Thread(
                target=self._beat_loop, args=(interval_s,),
                name="heartbeat-beater", daemon=True)
            self._beater.start()
        return self

    def _beat_loop(self, interval_s: float):
        while not self._beater_stop.wait(interval_s):
            self.beat()

    def stop_beating(self) -> None:
        if self._beater is not None:
            self._beater_stop.set()
            self._beater.join(timeout=5.0)
            self._beater = None


class BeatRegistry:
    """Per-worker beat counters, the coordinator-side liveness ledger.

    `beat(name)` is cheap enough to ride every ctrl-plane report; `ages()`
    snapshots wall age per worker; `split(stale_s)` partitions into
    (alive, stale) name lists. A worker never beats itself out of the
    registry — `forget(name)` removes one deliberately (e.g. after its
    process was reaped and respawned under a new name)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._beats: Dict[str, Tuple[int, float]] = {}   # name -> (count, t)

    def beat(self, name: str) -> int:
        with self._lock:
            n = self._beats.get(name, (0, 0.0))[0] + 1
            self._beats[name] = (n, time.monotonic())
            return n

    def ages(self) -> Dict[str, float]:
        now = time.monotonic()
        with self._lock:
            return {name: now - t for name, (_, t) in self._beats.items()}

    def split(self, stale_s: float) -> Tuple[List[str], List[str]]:
        """(alive, stale) worker names at the `stale_s` age threshold."""
        alive, stale = [], []
        for name, age in self.ages().items():
            (alive if age <= stale_s else stale).append(name)
        return alive, stale

    def forget(self, name: str) -> None:
        with self._lock:
            self._beats.pop(name, None)

    def __len__(self):
        with self._lock:
            return len(self._beats)


class HeartbeatMonitor(threading.Thread):
    """Watch a remote heartbeat over the worker's own probe connection.

    Declares the peer dead when `ping` fails to advance for `timeout_s`
    (transport errors count as no-advance: the monitor keeps retrying —
    a restarting coordinator that comes back within the window is never
    declared dead). `on_dead` runs exactly once, then the thread exits.
    """

    def __init__(self, address: str, *, interval_s: float = 1.0,
                 timeout_s: float = 10.0, ns: str = "ctrl",
                 on_dead: Optional[Callable[[], None]] = None):
        super().__init__(name=f"heartbeat-monitor@{address}", daemon=True)
        from repro.distributed.transport import RpcClient

        self.address = address
        self.interval_s = interval_s
        self.timeout_s = timeout_s
        self.dead = False
        self._ns = ns
        self._on_dead = on_dead
        self._halt = threading.Event()
        # short socket timeout: a wedged peer must not wedge the probe
        self._client = RpcClient(address, timeout=max(2.0, interval_s),
                                 connect_retries=1, retry_delay_s=0.05)

    def run(self):
        last_n: Optional[int] = None
        last_advance = time.monotonic()
        while not self._halt.is_set():
            try:
                n = self._client.call(f"{self._ns}.ping")
                if n != last_n:
                    last_n = n
                    last_advance = time.monotonic()
            except Exception:             # noqa: BLE001 — ANY probe failure
                # (TransportError, RemoteError from a version-skewed peer
                # without ctrl.ping, decode errors) counts as no-advance
                # and is retried: the monitor thread must never die
                # silently, or the worker loses wedge detection entirely
                pass
            if time.monotonic() - last_advance > self.timeout_s:
                self.dead = True
                try:
                    if self._on_dead is not None:
                        self._on_dead()
                finally:
                    self._client.close()
                return
            self._halt.wait(self.interval_s)
        self._client.close()

    def stop(self) -> None:
        self._halt.set()


def probe(address: str, *, timeout_s: float = 5.0, ns: str = "ctrl") -> bool:
    """One-shot liveness check: True iff `ns.ping` answers within
    `timeout_s`. The k8s exec-probe entrypoint."""
    from repro.distributed.transport import RpcClient

    client = RpcClient(address, timeout=timeout_s, connect_retries=1,
                       retry_delay_s=0.05)
    try:
        client.call(f"{ns}.ping")
        return True
    except Exception:                            # noqa: BLE001 — probe is binary
        return False
    finally:
        client.close()


def main() -> int:
    import argparse

    ap = argparse.ArgumentParser(
        description="liveness probe against a coordinator heartbeat")
    ap.add_argument("address", help="coordinator host:port")
    ap.add_argument("--timeout", type=float, default=5.0)
    args = ap.parse_args()
    addr = args.address.removeprefix("tcp://")
    return 0 if probe(addr, timeout_s=args.timeout) else 1


if __name__ == "__main__":
    raise SystemExit(main())
