from repro.distributed.sharding import (
    param_shardings, batch_shardings, state_shardings, data_axes,
)
