from repro.distributed.sharding import (
    param_shardings, batch_shardings, state_shardings, data_axes,
    serving_param_shardings, stacked_param_shardings, obs_batch_sharding,
    grouped_obs_sharding,
)
