"""Sharding rules: logical param/activation axes -> mesh PartitionSpecs.

Baseline layout (DESIGN.md §5) on mesh (data=16, model=16) [+ pod=2]:
  - batch over ('pod','data') — trajectory/data parallelism (M_L learners)
  - tensor parallelism over 'model': attention q-heads / FFN hidden / MoE
    experts / vocab
  - FSDP over 'data' for the big 2D weights (the >=100B archs don't fit
    replicated): the weight's contraction dim shards over 'data' and GSPMD
    all-gathers/reduce-scatters around each use — exactly the ZeRO-3
    pattern, which here replaces the paper's Horovod full allreduce.

Every rule checks divisibility and drops the axis when it doesn't divide
(gemma2's 8 q-heads vs model=16 -> heads replicated; hubert's vocab 504 ->
head replicated) so every (arch x shape x mesh) lowers.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def data_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


_HINT_MESH: Mesh | None = None


def set_hint_mesh(mesh: Mesh | None):
    """Register the mesh that in-graph `shard_hint`s resolve against (the
    `with mesh:` context is not introspectable at trace time). Called by the
    dry-run step factory and the distributed train driver; leaving it None
    (CPU tests, single device) makes every hint a no-op."""
    global _HINT_MESH
    _HINT_MESH = mesh


def shard_hint(x, spec_pref):
    """Best-effort in-graph sharding constraint (used inside model code, e.g.
    the MoE dispatch — EXPERIMENTS.md §Perf-2). `spec_pref` holds one entry
    per dim: None | axis name | tuple of axis names; entries are filtered by
    the axes present in the hint mesh and by divisibility."""
    m = _HINT_MESH
    if m is None:
        return x
    sizes = dict(m.shape)
    spec = []
    for dim, pref in zip(x.shape, spec_pref):
        if pref is None:
            spec.append(None)
            continue
        axes = (pref,) if isinstance(pref, str) else tuple(pref)
        axes = tuple(a for a in axes if a in sizes)
        n = int(np.prod([sizes[a] for a in axes])) if axes else 1
        spec.append(axes if (axes and dim % n == 0) else None)
    try:
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(m, P(*[a if a is None or isinstance(a, str)
                                    else tuple(a) for a in spec])))
    except Exception:
        return x


def _axis_size(mesh, axes):
    if axes is None:
        return 1
    if isinstance(axes, str):
        return mesh.shape[axes]
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fit(mesh, shape, wanted):
    """Keep only axes that divide their dim; wanted: tuple of (axis|None)."""
    out = []
    for dim, ax in zip(shape, wanted):
        if ax is None:
            out.append(None)
        elif dim % _axis_size(mesh, ax) == 0:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def _rule(mesh, name: str, shape, fsdp: bool, stacked: bool):
    """PartitionSpec for one param leaf. `stacked` = leading layer-stack dim."""
    dp = data_axes(mesh)
    lead = (None,) if stacked else ()
    core = shape[1:] if stacked else shape
    nd = len(core)

    def spec(*axes):
        return _fit(mesh, shape, lead + tuple(axes))

    d_ax = dp if fsdp else None     # contraction-dim FSDP axis

    if nd == 3 and ("moe/up" in name or "moe/gate" in name):
        return spec("model", d_ax, None)          # (E, d, ff)
    if nd == 3 and "moe/down" in name:
        return spec("model", None, d_ax)          # (E, ff, d)
    if "embed/table" in name:
        return _fit(mesh, shape, ("model", dp if fsdp else None))
    if nd == 2 and "lm_head" in name:
        return spec(d_ax, "model")
    if nd == 2:
        # column-parallel in-projections, row-parallel out-projections
        if any(t in name for t in ("/wo/", "down")) or name.endswith("wo/w"):
            return spec("model", d_ax)
        if any(t in name for t in ("wq", "wk", "wv", "up", "gate", "wr",
                                   "wg", "in_proj", "x_proj", "lora_a",
                                   "router")):
            return spec(d_ax, "model")
        return spec(d_ax, "model")
    # 1D/scalars and anything exotic: replicated
    return P(*((None,) * len(shape)))


def param_shardings(param_shapes: Any, cfg, mesh: Mesh, *, fsdp: bool = True):
    """param_shapes: pytree of ShapeDtypeStruct (jax.eval_shape(init_params)).
    Block stacks (params['blocks'], 'dense_prefix') have a leading repeat dim.
    Returns a pytree of NamedSharding."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(param_shapes)
    out = []
    for path, leaf in flat:
        name = _path_str(path)
        stacked = name.startswith(("blocks/", "dense_prefix/"))
        spec = _rule(mesh, name, leaf.shape, fsdp, stacked)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_shardings(batch_shapes: Any, mesh: Mesh):
    """Leading dim = global batch -> shard over ('pod','data') when it
    divides (long_500k's batch=1 stays replicated)."""
    dp = data_axes(mesh)

    def one(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = _fit(mesh, leaf.shape, (dp,) + (None,) * (leaf.ndim - 1))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shapes)


def serving_param_shardings(param_shapes: Any, cfg, mesh: Mesh):
    """Serving layout for the InfServer's hosted params: pure tensor
    parallelism over 'model' (attention heads / FFN hidden / vocab split
    exactly as `param_shardings`), but NO FSDP — a forward-only server
    re-gathering ZeRO-3 shards on every request would trade its latency
    for memory it doesn't need. Data axes carry the request batch instead
    (`obs_batch_sharding`)."""
    return param_shardings(param_shapes, cfg, mesh, fsdp=False)


def stacked_param_shardings(shardings: Any, mesh: Mesh):
    """Shardings for the grouped θ+φ forward's (M, ...) stacked pytree:
    the model-group axis M stays unsharded (it is vmapped, and M is tiny —
    the learner plus a few frozen opponents), every trailing dim keeps the
    per-model serving spec."""
    def one(ns: NamedSharding):
        return NamedSharding(mesh, P(*((None,) + tuple(ns.spec))))
    return jax.tree.map(one, shardings,
                        is_leaf=lambda x: isinstance(x, NamedSharding))


def obs_batch_sharding(mesh: Mesh, rows: int) -> NamedSharding:
    """Data-parallel layout for a (rows, L) observation batch: rows over
    the ('pod','data') axes when they divide (the continuous batch is
    padded to a power-of-two bucket, so any power-of-two data axis
    divides), replicated otherwise."""
    dp = data_axes(mesh)
    return NamedSharding(mesh, _fit(mesh, (rows,), (dp,)))


def grouped_obs_sharding(mesh: Mesh, rows: int) -> NamedSharding:
    """Layout for the grouped (M, S, L) observation tensor: model-group
    dim replicated (vmapped), the per-model batch S data-parallel."""
    dp = data_axes(mesh)
    spec = _fit(mesh, (1, rows), (None, dp))
    return NamedSharding(mesh, spec)


def state_shardings(state_shapes: Any, cfg, mesh: Mesh,
                    *, shard_cache_len: bool = False):
    """Decode-state shardings. KV caches are (R, B, W, KV, hd): batch over
    data axes; KV heads over 'model' when divisible, else optionally the
    cache length W over 'model' (`shard_cache_len` — the context-parallel
    variant), else replicated on 'model'."""
    dp = data_axes(mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(state_shapes)
    out = []
    for path, leaf in flat:
        name = _path_str(path)
        if leaf.ndim == 5 and ("/k" in name or "/v" in name):
            r, b, w, kv, hd = leaf.shape
            if kv % mesh.shape["model"] == 0:
                spec = _fit(mesh, leaf.shape, (None, dp, None, "model", None))
            elif shard_cache_len:
                spec = _fit(mesh, leaf.shape, (None, dp, "model", None, None))
            else:
                spec = _fit(mesh, leaf.shape, (None, dp, None, None, None))
        elif "tm_S" in name and leaf.ndim == 4:      # rwkv state (R,B,H,hs,hs)->4 after stack? keep general
            spec = _fit(mesh, leaf.shape, (None, dp, "model", None))
        elif "tm_S" in name and leaf.ndim == 5:
            spec = _fit(mesh, leaf.shape, (None, dp, "model", None, None))
        elif "ssm" in name and leaf.ndim == 4:        # mamba h (R,B,di,N)
            spec = _fit(mesh, leaf.shape, (None, dp, "model", None))
        elif "conv" in name and leaf.ndim == 4:       # conv buf (R,B,K-1,di)
            spec = _fit(mesh, leaf.shape, (None, dp, None, "model"))
        elif leaf.ndim >= 2:
            spec = _fit(mesh, leaf.shape, (None, dp) + (None,) * (leaf.ndim - 2))
        elif leaf.ndim == 1:
            spec = _fit(mesh, leaf.shape, (dp,))
        else:
            spec = P()
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(treedef, out)
