"""Process-boundary transport for the league seams (§3.3 / §3.4).

The paper connects LeagueMgr, ModelPool, Learner, Actor and InfServer with
ZeroMQ so each module can live in its own process on a hybrid cluster.
This module is that transport layer for the PR 3 thread seams: a small
length-prefixed **msgpack-over-TCP RPC** (msgpack when available — it is a
dev extra — with a pickle fallback for bare installs; both are
trusted-cluster protocols, not internet-facing ones) plus thin
client/server wrappers that mirror the in-process seam APIs exactly:

  * `ModelPoolClient`   — pull / push / pull_attr / freeze / keys
  * `LeagueMgrClient`   — request_task / report_result / should_freeze /
                          end_learning_period / pool_winrate / league_state
  * `InfServerClient`   — submit / flush / get (ticket ids travel as ints)
                          / update_params / ensure_model / evict_model
  * `DataServerClient`  — put / put_when_room / wait_ready / throughput

Every pytree that crosses the wire is freshly deserialized in the
receiving process, so a remote WRITER can never corrupt local buffers.
Note the read-side contract did tighten with the param plane:
`ModelPoolClient.pull` keeps a local version cache and returns it BY
REFERENCE (read-only, like a `copy=False` local pull) — pass
`copy=True` before feeding a remote pull to a donating train step,
exactly as in-process callers must.

Wire format: 1 codec byte + 8-byte big-endian length, then one msgpack
(or pickle) message. Requests are `{"m": "ns.method", "a": [...], "k":
{...}}`; replies `{"ok": result}` or `{"err": message, "tb": traceback}`
— a remote exception re-raises client-side as `RemoteError` with the
server traceback attached, and a dead peer raises `TransportError` (the
killed-server path the transport tests exercise).

**Streaming transfer (the param plane):** any ndarray leaf at or above
`_CHUNK_THRESHOLD` bytes is NOT serialized into the msgpack frame.
The frame carries a tiny `{"__nds__": [index, dtype, shape]}` stub
(codec byte gains the 0x80 stream flag) and the raw leaf buffers follow
the frame as length-prefixed blobs, sent and received in bounded
`_CHUNK_BYTES` slices. A 100 MB pytree therefore never exists as one
giant msgpack frame on either side: the sender streams the live array
buffers (no serialization copy of the bulk data) and the receiver
assembles each leaf zero-copy via `np.frombuffer` over its own
bytearray. A peer that dies mid-blob raises `TransportError`, exactly
like one that dies mid-frame. `chunking(...)` overrides the
threshold/slice size per process (the param_plane benchmark's
monolithic-vs-chunked axis); the pickle fallback codec never streams.

`serve_league` is the one-call server: it namespaces one LeagueMgr (and
its ModelPool, and optionally an InfServer) behind a single `RpcServer`
socket — the layout `launch/train.py --role coordinator` binds.
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import json
import os
import random
import socket
import struct
import threading
import time
import traceback
from types import SimpleNamespace
from typing import Any, Dict, Hashable, Iterable, List, Optional, Tuple, Union

import numpy as np

from repro.core.types import (FreezeGate, Hyperparam, MatchResult, ModelKey,
                              Task)
from repro.params.cache import CachedPuller
from repro.params.manifest import (NotModified, ParamDelta, ParamManifest,
                                   apply_delta)  # noqa: F401 — apply_delta
# is re-exported: delta consumers (benchmarks, tools) reach it as
# transport.apply_delta next to the wire types it pairs with
from repro.utils.pytree import tree_copy

try:
    import msgpack
    CODEC = "msgpack"
except ImportError:                              # bare install: no dev extras
    import pickle
    CODEC = "pickle"


class TransportError(ConnectionError):
    """The peer is gone (refused, reset, or closed mid-message)."""


class RetryableError(TransportError):
    """A NON-idempotent call failed after the request may have reached the
    server (`report_result`, `put_when_room`, ...): the transport cannot
    know whether the side effect happened, so it refuses to blindly
    resend. The caller resolves the ambiguity at the protocol layer —
    lease/generation guards make a duplicate `report_result` harmless
    (the reaped generation is dropped server-side), and a duplicated or
    lost trajectory segment is just data. Subclasses TransportError so
    legacy `except TransportError` shutdown paths keep working."""


class RemoteError(RuntimeError):
    """The remote method raised; `.remote_tb` carries the server traceback."""

    def __init__(self, message: str, remote_tb: str = ""):
        super().__init__(message)
        self.remote_tb = remote_tb


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff with a cap and a total deadline.

    N actors respawned together against a restarting pool must not
    thundering-herd it: each client's delay sequence is base * 2^i capped
    at `cap_s`, each multiplied by an independent uniform jitter in
    [0.5, 1.5], and the whole retry loop gives up once `deadline_s` of
    wall time (or `max_attempts` attempts) is spent."""
    base_s: float = 0.1
    cap_s: float = 2.0
    max_attempts: int = 50
    deadline_s: Optional[float] = 5.0

    def delays(self, rng: random.Random):
        """Yield the sleep before each RE-attempt (attempt 0 is free);
        exhaustion means give up. Deadline accounting includes the time
        the attempts themselves burned (monotonic clock, not just the
        sleeps)."""
        t0 = time.monotonic()
        for i in range(max(0, self.max_attempts - 1)):
            d = min(self.cap_s, self.base_s * (2.0 ** i))
            d *= rng.uniform(0.5, 1.5)
            if self.deadline_s is not None:
                left = self.deadline_s - (time.monotonic() - t0)
                if left <= 0:
                    return
                d = min(d, left)
            yield d


# -- codec -------------------------------------------------------------------
# msgpack handles scalars/strings/bytes/lists/dicts natively; everything the
# league protocol adds rides extension dicts: ndarrays (dtype/shape/bytes),
# tuples (strict_types makes them reach `default`, so round-trips preserve
# tuple-ness — pytree treedefs survive), and the §3.3 message dataclasses.

_DATACLASSES = {c.__name__: c for c in
                (ModelKey, Hyperparam, FreezeGate, Task, MatchResult,
                 ParamManifest, ParamDelta, NotModified)}

# streaming-transfer knobs: ndarray leaves >= _CHUNK_THRESHOLD bytes ride
# out-of-band after the frame, sent/received in _CHUNK_BYTES slices
_CHUNK_THRESHOLD = 256 * 1024
_CHUNK_BYTES = 1 << 20
_STREAM_FLAG = 0x80


@contextlib.contextmanager
def chunking(threshold: Optional[int] = None, chunk_bytes: Optional[int] = None):
    """Temporarily override the streaming knobs for THIS process's sends
    (`threshold=None` keeps the current value; `threshold=0` streams
    every leaf, a huge threshold forces monolithic frames). The
    param_plane benchmark's chunked-vs-monolithic axis."""
    global _CHUNK_THRESHOLD, _CHUNK_BYTES
    old = (_CHUNK_THRESHOLD, _CHUNK_BYTES)
    if threshold is not None:
        _CHUNK_THRESHOLD = threshold
    if chunk_bytes is not None:
        _CHUNK_BYTES = chunk_bytes
    try:
        yield
    finally:
        _CHUNK_THRESHOLD, _CHUNK_BYTES = old


def _make_encoder(blobs: Optional[List[np.ndarray]]):
    """msgpack `default` hook; with a `blobs` collector, large ndarrays
    are hoisted out of the frame and replaced by an index stub."""
    def enc(o):
        if isinstance(o, tuple):
            return {"__t__": list(o)}
        if isinstance(o, np.ndarray):
            if blobs is not None and o.nbytes >= _CHUNK_THRESHOLD:
                a = np.ascontiguousarray(o)
                blobs.append(a)
                return {"__nds__": [len(blobs) - 1, a.dtype.str,
                                    list(a.shape)]}
            return {"__nd__": [o.dtype.str, list(o.shape),
                               np.ascontiguousarray(o).tobytes()]}
        if isinstance(o, np.generic):
            return o.item()
        if dataclasses.is_dataclass(o) and type(o).__name__ in _DATACLASSES:
            return {"__dc__": type(o).__name__,
                    "f": {f.name: getattr(o, f.name)
                          for f in dataclasses.fields(o)}}
        if hasattr(o, "__array__"):              # jax.Array and friends
            return enc(np.asarray(o))
        raise TypeError(
            f"cannot serialize {type(o)!r} over the league transport")
    return enc


def _make_decoder(blobs: Optional[List[bytearray]]):
    def dec(d):
        if "__t__" in d and len(d) == 1:
            return tuple(d["__t__"])
        if "__nd__" in d and len(d) == 1:
            dt, shape, buf = d["__nd__"]
            return np.frombuffer(buf, dtype=np.dtype(dt)).reshape(shape).copy()
        if "__nds__" in d and len(d) == 1:
            if blobs is None:
                raise TransportError(
                    "frame references streamed blobs but none followed")
            i, dt, shape = d["__nds__"]
            # zero-copy: the bytearray was recv'd directly into place and
            # is owned exclusively by this message
            return np.frombuffer(blobs[i], dtype=np.dtype(dt)).reshape(shape)
        if "__dc__" in d:
            return _DATACLASSES[d["__dc__"]](**d["f"])
        return d
    return dec


_CODEC_MSGPACK, _CODEC_PICKLE = 1, 2
_CODEC_ID = _CODEC_MSGPACK if CODEC == "msgpack" else _CODEC_PICKLE


def packb(obj, blobs: Optional[List[np.ndarray]] = None) -> bytes:
    """Serialize one message. With a `blobs` list (msgpack codec only),
    large ndarray leaves are appended to it instead of being copied into
    the returned frame — the streaming path `send_msg` uses."""
    if CODEC == "msgpack":
        return msgpack.packb(obj, default=_make_encoder(blobs),
                             strict_types=True, use_bin_type=True)
    return pickle.dumps(obj)


def unpackb(buf: bytes, codec_id: Optional[int] = None,
            blobs: Optional[List[bytearray]] = None):
    """Decode with the codec the MESSAGE was packed with (every frame
    carries a codec byte), defaulting to this process's codec. A
    msgpack-encoded frame from a peer on a bare install (no msgpack) is a
    clear error instead of a garbled pickle failure; pickle frames decode
    anywhere (pickle is stdlib)."""
    codec_id = _CODEC_ID if codec_id is None else codec_id
    if codec_id == _CODEC_MSGPACK:
        if CODEC != "msgpack":
            raise TransportError(
                "peer sent a msgpack frame but msgpack is not installed "
                "here (pip install msgpack, or run all peers bare)")
        return msgpack.unpackb(buf, object_hook=_make_decoder(blobs),
                               raw=False, strict_map_key=False)
    if codec_id == _CODEC_PICKLE:
        import pickle as _pickle
        return _pickle.loads(buf)
    raise TransportError(f"unknown wire codec id {codec_id}")


# -- framing -----------------------------------------------------------------
# 1-byte codec id + 8-byte big-endian length, then the payload. The codec
# byte makes a mixed msgpack/pickle deployment either work (pickle frames
# decode anywhere) or fail with a message that names the problem. The
# 0x80 bit of the codec byte flags a streamed message: a 4-byte blob
# count follows the payload, then each blob as 8-byte length + raw bytes.
def send_msg(sock: socket.socket, obj) -> None:
    blobs: Optional[List[np.ndarray]] = [] if CODEC == "msgpack" else None
    payload = packb(obj, blobs)
    streamed = bool(blobs)
    try:
        sock.sendall(struct.pack(
            ">BQ", _CODEC_ID | (_STREAM_FLAG if streamed else 0),
            len(payload)) + payload)
        if streamed:
            sock.sendall(struct.pack(">I", len(blobs)))
            for arr in blobs:
                mv = memoryview(arr).cast("B")
                sock.sendall(struct.pack(">Q", len(mv)))
                # bounded slices: the bulk buffer is handed to the kernel
                # piecewise, never serialized into one giant frame
                for off in range(0, len(mv), _CHUNK_BYTES):
                    sock.sendall(mv[off:off + _CHUNK_BYTES])
    except OSError as e:
        raise TransportError(f"send failed: {e}") from e


def recv_msg(sock: socket.socket):
    header = _recv_exactly(sock, 9)
    codec_byte, n = struct.unpack(">BQ", header)
    codec_id = codec_byte & ~_STREAM_FLAG
    payload = _recv_exactly(sock, n)
    blobs: Optional[List[bytearray]] = None
    if codec_byte & _STREAM_FLAG:
        (count,) = struct.unpack(">I", _recv_exactly(sock, 4))
        blobs = []
        for _ in range(count):
            (ln,) = struct.unpack(">Q", _recv_exactly(sock, 8))
            blobs.append(_recv_into(sock, ln))
    return unpackb(payload, codec_id, blobs)


def _recv_exactly(sock: socket.socket, n: int) -> bytes:
    chunks = []
    while n:
        try:
            chunk = sock.recv(min(n, 1 << 20))
        except OSError as e:
            raise TransportError(f"recv failed: {e}") from e
        if not chunk:
            raise TransportError("peer closed the connection")
        chunks.append(chunk)
        n -= len(chunk)
    return b"".join(chunks)


def _recv_into(sock: socket.socket, n: int) -> bytearray:
    """Receive exactly `n` raw bytes into one preallocated buffer in
    bounded slices — the zero-copy landing pad for a streamed blob. A
    peer that dies mid-blob surfaces as TransportError here."""
    buf = bytearray(n)
    mv = memoryview(buf)
    off = 0
    while off < n:
        try:
            k = sock.recv_into(mv[off:off + min(_CHUNK_BYTES, n - off)])
        except OSError as e:
            raise TransportError(f"recv failed mid-chunk: {e}") from e
        if k == 0:
            raise TransportError(
                f"peer closed the connection mid-chunk ({off}/{n} bytes)")
        off += k
    return buf


def parse_addr(addr: str) -> Tuple[str, int]:
    """'host:port' -> (host, port)."""
    host, _, port = addr.rpartition(":")
    return host or "127.0.0.1", int(port)


# -- chaos harness ------------------------------------------------------------
# Server-side fault injection for the chaos smoke and the fault_recovery
# benchmark: a seeded FaultPlan decides, per incoming request, whether the
# connection drops before dispatch (request lost), after dispatch (reply
# lost — the ambiguity RetryableError models), gets delayed, or dies
# mid-streamed-chunk. Deterministic given (rules, seed, request order per
# rule); ships across process boundaries as JSON via REPRO_FAULT_PLAN.

@dataclasses.dataclass
class FaultRule:
    """One injection rule. `match` is an fnmatch pattern over the wire
    method name (`"pool.*"`, `"*.pull_if_changed"`, `"*"`); `kind` is
    `drop` (close before dispatch), `drop_reply` (dispatch, then close
    instead of replying), `delay` (sleep `delay_s`, then behave), or
    `close_mid_chunk` (send a truncated reply — for streamed replies,
    half of the first blob — then close). Fires with probability `p`, at
    most `max_times` times."""
    match: str
    kind: str
    p: float = 1.0
    delay_s: float = 0.05
    max_times: Optional[int] = None
    fired: int = 0

    _KINDS = ("drop", "drop_reply", "delay", "close_mid_chunk")

    def __post_init__(self):
        assert self.kind in self._KINDS, \
            f"unknown fault kind {self.kind!r}; pick from {self._KINDS}"


class FaultPlan:
    """A seeded set of FaultRules a `RpcServer` consults per request."""

    def __init__(self, rules: Iterable[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def decide(self, method: str) -> Optional[FaultRule]:
        """First matching rule that fires for this request, else None."""
        with self._lock:
            for rule in self.rules:
                if not fnmatch.fnmatchcase(method, rule.match):
                    continue
                if rule.max_times is not None and rule.fired >= rule.max_times:
                    continue
                if rule.p < 1.0 and self._rng.random() >= rule.p:
                    continue
                rule.fired += 1
                return rule
        return None

    def stats(self) -> dict:
        with self._lock:
            return {f"{r.match}:{r.kind}": r.fired for r in self.rules}

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed, "rules": [
            {"match": r.match, "kind": r.kind, "p": r.p,
             "delay_s": r.delay_s, "max_times": r.max_times}
            for r in self.rules]})

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        return cls([FaultRule(**r) for r in d.get("rules", [])],
                   seed=d.get("seed", 0))

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULT_PLAN") -> Optional["FaultPlan"]:
        """The cross-process seam: a parent (the chaos smoke) plants the
        plan in the environment; `run_coordinator` installs it on its
        server at startup."""
        s = os.environ.get(var)
        return cls.from_json(s) if s else None


def _send_truncated(sock: socket.socket, obj) -> None:
    """Send a deliberately incomplete reply (the close_mid_chunk fault):
    for streamed messages, the header + payload + half of the first blob;
    otherwise half of the frame itself. The peer sees TransportError
    mid-message, exactly like a server dying mid-transfer."""
    blobs: Optional[List[np.ndarray]] = [] if CODEC == "msgpack" else None
    payload = packb(obj, blobs)
    streamed = bool(blobs)
    header = struct.pack(
        ">BQ", _CODEC_ID | (_STREAM_FLAG if streamed else 0), len(payload))
    if streamed:
        sock.sendall(header + payload)
        sock.sendall(struct.pack(">I", len(blobs)))
        mv = memoryview(blobs[0]).cast("B")
        sock.sendall(struct.pack(">Q", len(mv)))
        sock.sendall(mv[:max(1, len(mv) // 2)])
    else:
        frame = header + payload
        sock.sendall(frame[:max(9, len(frame) // 2)])


# -- server ------------------------------------------------------------------
class RpcServer:
    """Serve the public surface of named objects over one TCP socket.

    `objects` maps a namespace to a backend object; a request for
    `"ns.name"` resolves `getattr(objects[ns], name)` — called with the
    request args when callable, returned as a snapshot value otherwise
    (so plain attributes like `LeagueMgr.frozen_pool` are readable
    remotely). Dunder/private names never resolve. One handler thread per
    connection; the backend objects' own locks provide the concurrency
    contract, exactly as they do for in-process threads."""

    def __init__(self, objects: Dict[str, Any], host: str = "127.0.0.1",
                 port: int = 0, fault_plan: Optional[FaultPlan] = None):
        self._objects = {ns: o for ns, o in objects.items() if o is not None}
        self.fault_plan = fault_plan
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self._sock.settimeout(0.2)              # accept-loop stop poll
        self._stop = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()

    @property
    def address(self) -> str:
        host, port = self._sock.getsockname()
        return f"{host}:{port}"

    def start(self) -> "RpcServer":
        if self._accept_thread is not None:      # idempotent
            return self
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"rpc-accept@{self.address}",
            daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        try:
            while not self._stop.is_set():
                try:
                    req = recv_msg(conn)
                except TransportError:
                    return
                rule = (self.fault_plan.decide(req.get("m", ""))
                        if self.fault_plan is not None else None)
                if rule is not None:
                    if rule.kind == "drop":
                        return                 # request lost, never dispatched
                    if rule.kind == "delay":
                        time.sleep(rule.delay_s)
                reply = self._dispatch(req)
                if rule is not None and rule.kind == "drop_reply":
                    return                     # executed, reply lost
                if rule is not None and rule.kind == "close_mid_chunk":
                    with contextlib.suppress(OSError):
                        _send_truncated(conn, reply)
                    return
                try:
                    send_msg(conn, reply)
                except TransportError:
                    return                     # peer gone mid-reply
                except Exception as e:         # noqa: BLE001 — result didn't
                    # serialize (packb raises before any bytes hit the
                    # socket): ship the failure as a RemoteError instead of
                    # dropping the connection, which clients would misread
                    # as a server shutdown
                    send_msg(conn, {"err": f"unserializable reply: "
                                           f"{type(e).__name__}: {e}",
                                    "tb": traceback.format_exc()})
        finally:
            conn.close()

    def _dispatch(self, req) -> dict:
        try:
            ns, _, name = req["m"].partition(".")
            if name.startswith("_") or not name:
                raise AttributeError(f"{req['m']!r} is not a public method")
            target = getattr(self._objects[ns], name)
            result = (target(*req.get("a", ()), **req.get("k", {}))
                      if callable(target) else target)
            return {"ok": result}
        except Exception as e:                   # noqa: BLE001 — shipped back
            return {"err": f"{type(e).__name__}: {e}",
                    "tb": traceback.format_exc()}

    def close(self) -> None:
        self._stop.set()
        self._sock.close()
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()


# -- client ------------------------------------------------------------------
class RpcClient:
    """One connection, serialized request/reply calls (thread-safe via a
    lock — give each worker thread its own client for parallel calls).

    Failure handling (the robustness plane):

    * `address` may be one endpoint, a comma-separated list, or a list —
      a failed attempt rotates to the next endpoint, so a `ModelPoolClient`
      handed `[replica, primary]` survives either dying.
    * connect failures and IDEMPOTENT call failures retry under the
      jittered-exponential-backoff `RetryPolicy` (pass `idempotent=True`
      to `call` — the seam wrappers do for `pull_if_changed`,
      `request_task`, `has_model`, `ping` and other pure reads).
    * a NON-idempotent call that fails after the request was (possibly)
      sent raises `RetryableError`: the side effect may have happened, so
      the caller must resolve it at the protocol layer instead of the
      transport resending blind.
    * `abort()` (another thread) poisons the client: the in-flight call
      wakes with TransportError and NO further retry — a heartbeat
      monitor that declared the peer dead must not fight a 5s backoff.

    `connect_retries`/`retry_delay_s` are the legacy knobs: they map onto
    `RetryPolicy(max_attempts=connect_retries, base_s=retry_delay_s,
    deadline_s=connect_retries * retry_delay_s)`, preserving the old
    worst-case wait while replacing the fixed sleep with jittered
    backoff."""

    def __init__(self, address: Union[str, Iterable[str]],
                 timeout: Optional[float] = None,
                 connect_retries: int = 50, retry_delay_s: float = 0.1,
                 retry: Optional[RetryPolicy] = None,
                 seed: Optional[int] = None):
        if isinstance(address, str):
            self._endpoints = [a.strip() for a in address.split(",") if a.strip()]
        else:
            self._endpoints = list(address)
        assert self._endpoints, "RpcClient needs at least one endpoint"
        self._ep_i = 0
        self._timeout = timeout
        self._retry = retry or RetryPolicy(
            base_s=retry_delay_s, max_attempts=max(1, connect_retries),
            deadline_s=max(1, connect_retries) * retry_delay_s)
        self._rng = random.Random(seed)
        self._sock: Optional[socket.socket] = None
        self._lock = threading.Lock()
        self._aborted = False

    @property
    def address(self) -> str:
        """The CURRENT endpoint (rotates on failover)."""
        return self._endpoints[self._ep_i]

    @property
    def endpoints(self) -> Tuple[str, ...]:
        return tuple(self._endpoints)

    def _connect_once(self) -> socket.socket:
        """One connection attempt to the current endpoint; no retries here
        — `call` owns the retry/rotate/backoff loop."""
        if self._sock is None:
            host, port = parse_addr(self.address)
            try:
                sock = socket.create_connection((host, port), timeout=10.0)
            except OSError as e:
                raise TransportError(
                    f"cannot connect to {self.address}: {e}") from e
            sock.settimeout(self._timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            self._sock = sock
        return self._sock

    def _rotate(self) -> None:
        if len(self._endpoints) > 1:
            self._ep_i = (self._ep_i + 1) % len(self._endpoints)

    def call(self, method: str, *args, idempotent: bool = False, **kwargs):
        with self._lock:
            delays = self._retry.delays(self._rng)
            last: Optional[TransportError] = None
            while True:
                if self._aborted:
                    raise last or TransportError(
                        f"client for {self.address} was aborted")
                sent = False
                try:
                    sock = self._connect_once()
                    sent = True          # bytes may hit the wire from here on
                    send_msg(sock, {"m": method, "a": list(args), "k": kwargs})
                    reply = recv_msg(sock)
                    break
                except TransportError as e:
                    self.close_locked()
                    last = e
                    if self._aborted:
                        raise
                    if sent and not idempotent:
                        raise RetryableError(
                            f"{method} may or may not have executed on "
                            f"{self.address}: {e}") from e
                    try:
                        delay = next(delays)
                    except StopIteration:
                        raise TransportError(
                            f"cannot reach any of {self._endpoints} "
                            f"for {method}: {last}") from last
                    self._rotate()
                    if delay > 0:
                        time.sleep(delay)
        if "err" in reply:
            raise RemoteError(reply["err"], reply.get("tb", ""))
        return reply["ok"]

    def close_locked(self) -> None:
        if self._sock is not None:
            self._sock.close()
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self.close_locked()

    def abort(self) -> None:
        """Force-close from ANOTHER thread: `shutdown` wakes a caller
        blocked inside `recv` (it raises TransportError there), which a
        plain `close` does not on Linux. Poisons the client against
        further retries. Deliberately lock-free — the blocked caller is
        holding the lock."""
        self._aborted = True
        sock = self._sock
        if sock is not None:
            try:
                sock.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            try:
                sock.close()
            except OSError:
                pass


class _NamespaceClient:
    """Shared plumbing: bind an RpcClient (or address/endpoint-list) to
    one namespace. `_get` marks the call idempotent — safe to resend with
    backoff and to fail over across endpoints."""

    def __init__(self, client, ns: str):
        self._c = client if isinstance(client, RpcClient) else RpcClient(client)
        self._ns = ns

    def _call(self, name: str, *args, **kwargs):
        return self._c.call(f"{self._ns}.{name}", *args, **kwargs)

    def _get(self, name: str, *args, **kwargs):
        return self._c.call(f"{self._ns}.{name}", *args, idempotent=True,
                            **kwargs)

    def ping(self) -> bool:
        """Idempotent liveness probe against the namespace's server; True
        when any method on it answers (the remote `ping` if it exists)."""
        try:
            self._get("ping")
        except RemoteError:
            pass                       # server is up, ns just has no ping
        return True

    def close(self) -> None:
        self._c.close()

    def abort(self) -> None:
        """Wake a blocked in-flight call with TransportError (see
        `RpcClient.abort`)."""
        self._c.abort()


# -- seam wrappers -----------------------------------------------------------
class ModelPoolClient(_NamespaceClient):
    """Remote `repro.core.ModelPool` with a LOCAL VERSION CACHE: `pull`
    sends the cached version number, and the server answers with a
    `NotModified` tag (cache hit — zero param bytes move), the changed
    leaves only (grafted onto the cached copy), or the full pytree
    (first pull / prehistoric cache). Callers written against the plain
    pool API therefore get hash-gated delta pulls for free.

    Cache-hit and delta pulls return the cached object BY REFERENCE —
    read-only by contract, like a `copy=False` local pull. Pass
    `copy=True` (the Learner's post-freeze adopt does) for a private
    deep copy the caller may feed to a donating train step. Every array
    that does cross the wire lands in fresh buffers, so corruption by a
    remote writer remains impossible by construction."""

    def __init__(self, client, ns: str = "pool", write_client=None):
        super().__init__(client, ns)
        # the cache logic itself lives in CachedPuller (it drives our raw
        # pull_if_changed below); this class only adds the lock and the
        # copy-on-request semantics
        self._puller = CachedPuller(self)
        self._cache_lock = threading.Lock()
        # reads may fail over across replicas (`client` can be an endpoint
        # list), but WRITES must land on the primary: a separate pinned
        # connection when the read path is replicated
        self._w = (write_client if (write_client is None or
                                    isinstance(write_client, RpcClient))
                   else RpcClient(write_client))

    def _write(self, name: str, *args, **kwargs):
        if self._w is not None:
            return self._w.call(f"{self._ns}.{name}", *args, **kwargs)
        return self._call(name, *args, **kwargs)

    def _read(self, name: str, *args, **kwargs):
        """Keyed read with replica-lag fallback: a replica that hasn't
        synced a freshly-minted key yet answers `RemoteError(KeyError)`
        — the server is alive, so endpoint failover never triggers.
        When a pinned primary exists, retry the read there; the primary
        minted the key, so it always has it."""
        try:
            return self._get(name, *args, **kwargs)
        except RemoteError as e:
            if self._w is None or not str(e).startswith("KeyError"):
                raise
            return self._w.call(f"{self._ns}.{name}", *args, **kwargs)

    def pull(self, key: ModelKey, copy: Optional[bool] = None):
        with self._cache_lock:
            params = self._puller.get(key)
        return tree_copy(params) if copy else params

    def drop(self, key: ModelKey) -> None:
        """Evict `key` from the local version cache (a model-sized
        allocation): callers that pull a key once and then sync through
        their own CachedPuller should drop it so two copies aren't
        pinned for the process lifetime."""
        with self._cache_lock:
            self._puller.drop(key)

    def clear_cache(self) -> None:
        with self._cache_lock:
            self._puller.clear()

    def pull_if_changed(self, key: ModelKey,
                        have_version: Optional[int] = None,
                        copy: Optional[bool] = None, have_hashes=None):
        """The raw protocol call (no client-side caching — `CachedPuller`
        or `pull` own the cache). `copy` is accepted for signature
        compatibility; remote arrays are fresh by construction.
        `have_hashes` rides through to the pool's cross-key content
        addressing: leaves the caller already holds (under any key) come
        back as hash references instead of bytes."""
        if have_hashes is None:
            return self._read("pull_if_changed", key, have_version)
        return self._read("pull_if_changed", key, have_version,
                          have_hashes=sorted(have_hashes))

    def manifest(self, key: ModelKey) -> ParamManifest:
        return self._read("manifest", key)

    def version(self, key: ModelKey) -> int:
        return self._read("version", key)

    def push(self, key: ModelKey, params, step: int = 0) -> None:
        self._write("push", key, params, step=step)

    def pull_attr(self, key: ModelKey) -> dict:
        return self._read("pull_attr", key)

    def freeze(self, key: ModelKey) -> None:
        self._write("freeze", key)

    def keys(self):
        return self._get("keys")

    def __contains__(self, key: ModelKey) -> bool:
        return key in self.keys()

    @property
    def membership_version(self) -> int:
        return self._get("membership_version")

    def close(self) -> None:
        super().close()
        if self._w is not None:
            self._w.close()

    def abort(self) -> None:
        super().abort()
        if self._w is not None:
            self._w.abort()


class LeagueMgrClient(_NamespaceClient):
    """Remote `repro.core.LeagueMgr` — the Actor/Learner-facing slice of
    the league protocol (request_task/report_result on the actor side,
    should_freeze/end_learning_period on the learner side). `model_pool`
    is a `ModelPoolClient` over the same connection, so code written
    against the in-process LeagueMgr (`league.model_pool.pull(...)`) runs
    unchanged against the remote one."""

    def __init__(self, client, ns: str = "league", pool_ns: str = "pool",
                 pool_endpoints: Optional[Union[str, Iterable[str]]] = None):
        super().__init__(client, ns)
        if pool_endpoints:
            # replicated read path: pulls fail over across the endpoint
            # list; writes (push/freeze) stay pinned to the coordinator's
            # authoritative pool over this client's own connection
            self.model_pool = ModelPoolClient(
                RpcClient(pool_endpoints), ns=pool_ns, write_client=self._c)
        else:
            self.model_pool = ModelPoolClient(self._c, ns=pool_ns)

    def request_task(self, agent_id: str = "main",
                     actor_id: Optional[str] = None) -> Task:
        # idempotent by lease design: a duplicate issue is just an extra
        # lease the reaper collects once its TTL lapses
        if actor_id is None:
            return self._get("request_task", agent_id)
        return self._get("request_task", agent_id, actor_id=actor_id)

    def request_learner_task(self, agent_id: str = "main") -> Task:
        return self._get("request_learner_task", agent_id)

    def report_result(self, result: MatchResult) -> None:
        # NOT idempotent: double-recording an outcome skews the payoff
        # matrix — an ambiguous failure surfaces as RetryableError and the
        # lease generation guard makes the caller's choice safe either way
        self._call("report_result", result)

    def pool_winrate(self, agent_id: str) -> Tuple[float, float]:
        return tuple(self._get("pool_winrate", agent_id))

    def should_freeze(self, agent_id: str, steps: int) -> Optional[str]:
        return self._get("should_freeze", agent_id, steps)

    def end_learning_period(self, agent_id: str, params,
                            reason: str = "period") -> ModelKey:
        return self._call("end_learning_period", agent_id, params,
                          reason=reason)

    def league_state(self) -> dict:
        return self._get("league_state")

    def lease_state(self) -> dict:
        return self._get("lease_state")

    @property
    def frozen_pool(self):
        return list(self._get("frozen_pool"))

    @property
    def agents(self):
        """Remote agent registry shaped like the in-process
        `LeagueMgr.agents` just enough for `Learner.current_key`
        (`league.agents[aid].current`). Lazy: indexing returns a view
        whose `.current` is ONE small `current_model_key` RPC — not a
        full `league_state` dump, which Learner.learn would otherwise
        trigger on every published step."""
        return _RemoteAgents(self)

    def close(self) -> None:
        self.model_pool.close()      # may own a separate replica connection
        super().close()

    def abort(self) -> None:
        self.model_pool.abort()
        super().abort()


class _RemoteAgents:
    def __init__(self, league: "LeagueMgrClient"):
        self._league = league

    def __getitem__(self, agent_id: str) -> SimpleNamespace:
        key = self._league._get("current_model_key", agent_id)
        return SimpleNamespace(current=key)


class RemoteTicket:
    """Client-side future for a submitted batch; mirrors `infserver.Ticket`
    (the integer ticket id is what actually crossed the wire)."""
    __slots__ = ("tid", "model", "rows", "_client")

    def __init__(self, tid: int, model, rows: int, client: "InfServerClient"):
        self.tid, self.model, self.rows, self._client = tid, model, rows, client

    def done(self) -> bool:
        return self._client.poll(self.tid)

    def result(self):
        return self._client.get(self)

    def __int__(self) -> int:
        return self.tid

    def __repr__(self):
        return f"RemoteTicket({self.tid}, model={self.model!r}, rows={self.rows})"


class InfServerBackend:
    """Server-side adapter: `infserver.Ticket` holds a live server
    reference, so over the wire only its integer id travels. `submit`
    returns the id, `get` accepts it back, `poll` is the non-blocking
    probe.

    Outstanding tickets are bounded (`max_outstanding`): a client that
    submits and then dies would otherwise leak its ticket — and, once
    flushed, its result arrays — forever in a long-lived serving process.
    Beyond the cap the oldest unfetched ticket is discarded server-side
    (its later `get` raises KeyError, which a live client would see as a
    RemoteError rather than silent wrong data)."""

    def __init__(self, server, max_outstanding: int = 4096):
        self._server = server
        self._max_outstanding = max_outstanding
        self._tickets: Dict[int, Any] = {}       # insertion-ordered
        self._lock = threading.Lock()

    def submit(self, obs, model: Hashable = None,
               deadline_s: Optional[float] = None) -> int:
        # `deadline_s` is accepted so a gateway-aware client can talk to
        # a single server unchanged; a lone InfServer is size-bucketed
        # only, so the hint is ignored rather than raised on.
        t = self._server.submit(np.asarray(obs), model=model)
        with self._lock:
            self._tickets[t.tid] = t
            while len(self._tickets) > self._max_outstanding:
                stale = next(iter(self._tickets))
                self._server.discard(self._tickets.pop(stale))
        return t.tid

    def poll(self, tid: int) -> bool:
        with self._lock:
            t = self._tickets.get(tid)
        return bool(t is not None and t.done())

    def get(self, tid: int):
        with self._lock:
            t = self._tickets.pop(tid)
        a, logp, v = self._server.get(t)
        return np.asarray(a), np.asarray(logp), np.asarray(v)

    def flush(self) -> None:
        self._server.flush()

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        self._server.update_params(params, key=key,
                                   content_hash=content_hash,
                                   version=version)

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        self._server.ensure_model(key, params, content_hash=content_hash)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        self._server.register_model(key, params, content_hash=content_hash,
                                    version=version)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        return self._server.has_model(key, content_hash=content_hash)

    def evict_model(self, key: Hashable) -> bool:
        return self._server.evict_model(key)

    def stats(self) -> dict:
        return self._server.stats()

    def telemetry(self) -> dict:
        return self._server.telemetry()


class InfServerClient(_NamespaceClient):
    """Remote `repro.infserver.InfServer` speaking the same
    submit/flush/get protocol as the in-process server, so
    `build_served_rollout` (and therefore a served Actor) can run against
    either without knowing which it has."""

    def __init__(self, client, ns: str = "inf"):
        super().__init__(client, ns)

    def submit(self, obs: np.ndarray, model: Hashable = None,
               deadline_s: Optional[float] = None) -> RemoteTicket:
        """`deadline_s` rides along only when set: a plain
        `InfServerBackend` has no deadline notion (size-bucketed only),
        a `serving.GatewayBackend` feeds it to the SLO pump."""
        obs = np.asarray(obs)
        if deadline_s is None:
            tid = self._call("submit", obs, model=model)
        else:
            tid = self._call("submit", obs, model=model,
                             deadline_s=deadline_s)
        return RemoteTicket(tid, model, obs.shape[0], self)

    def poll(self, tid) -> bool:
        return self._get("poll", int(tid))

    def get(self, ticket):
        return tuple(self._call("get", int(ticket)))

    def flush(self) -> None:
        self._call("flush")

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        """Hash-gated hot-swap over RPC: with a `content_hash`, a cheap
        `has_model` probe runs first and the params are NOT shipped when
        the server already hosts that exact content — the common case
        for every actor but the first to refresh a route."""
        if content_hash is not None and self._get("has_model", key,
                                                  content_hash):
            return
        self._call("update_params", params, key=key,
                   content_hash=content_hash, version=version)

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        """Idempotent route setup; with a `content_hash` the params only
        cross the wire when the route is absent or stale."""
        if content_hash is not None and self._get("has_model", key,
                                                  content_hash):
            return
        self._call("ensure_model", key, params, content_hash=content_hash)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        self._call("register_model", key, params, content_hash=content_hash,
                   version=version)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        return self._get("has_model", key, content_hash)

    def evict_model(self, key: Hashable) -> bool:
        return self._call("evict_model", key)

    def stats(self) -> dict:
        """Full server telemetry across the seam — `InfServer.stats()`
        verbatim (occupancy, per-batch latency, swap + dispatch
        counters). The gateway's router reads the cheap `telemetry()`
        probe instead at steady state; this is the operator view."""
        return self._get("stats")

    def telemetry(self) -> dict:
        """The high-cadence occupancy/latency probe (see
        `InfServer.telemetry`) — the routing signal crossing the RPC
        seam."""
        return self._get("telemetry")


class DataServerClient(_NamespaceClient):
    """Remote `repro.learners.DataServer` put-side: the Actor→Learner data
    seam. The DataServer lives in the Learner's process (the paper
    embeds it there); Actors connect here to ship segments. Backpressure
    crosses the boundary: `put_when_room` blocks server-side under the
    ring's condition variable and returns False on timeout exactly like
    the in-process call."""

    def __init__(self, client, ns: str = "data"):
        super().__init__(client, ns)

    def put(self, traj) -> None:
        self._call("put", traj)

    def put_when_room(self, traj, timeout: Optional[float] = None) -> bool:
        return self._call("put_when_room", traj, timeout=timeout)

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        return self._call("wait_ready", timeout=timeout)

    def ready(self) -> bool:
        return self._get("ready")

    def throughput(self) -> dict:
        return self._get("throughput")

    def last_sample_info(self):
        return self._call("last_sample_info")

    def update_priorities(self, slots, priorities, gen=None) -> int:
        """Prioritized-replay write-back over the wire: a remote learner
        (or a priority-computing sidecar) echoes the sampled slots and
        generations back with fresh priorities; the server drops updates
        for rows the ring has overwritten since."""
        return self._call("update_priorities", slots, priorities, gen=gen)


# -- one-call league server ---------------------------------------------------
def serve_league(league, inf_server=None, *, extra: Optional[Dict[str, Any]] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 fault_plan: Optional[FaultPlan] = None) -> RpcServer:
    """Put a LeagueMgr (namespace `league`), its ModelPool (`pool`) and
    optionally an InfServer (`inf`, ticket ids over the wire) behind one
    started RpcServer. `extra` adds more namespaces (the multiprocess
    driver's `ctrl` plane). `fault_plan` arms the chaos harness on every
    namespace. Close the returned server to tear down."""
    objects: Dict[str, Any] = {"league": league, "pool": league.model_pool}
    if inf_server is not None:
        objects["inf"] = InfServerBackend(inf_server)
    objects.update(extra or {})
    return RpcServer(objects, host=host, port=port,
                     fault_plan=fault_plan).start()
