"""Process-boundary transport for the league seams (§3.3 / §3.4).

The paper connects LeagueMgr, ModelPool, Learner, Actor and InfServer with
ZeroMQ so each module can live in its own process on a hybrid cluster.
This module is that transport layer: a length-prefixed **msgpack-over-TCP
RPC** (msgpack when available — it is a dev extra — with a pickle fallback
for bare installs; both are trusted-cluster protocols, not internet-facing
ones) plus thin client/server wrappers that mirror the in-process seam
APIs exactly:

  * `ModelPoolClient`   — pull / push / pull_attr / freeze / keys
  * `LeagueMgrClient`   — request_task / report_result / should_freeze /
                          end_learning_period / pool_winrate / league_state
  * `InfServerClient`   — submit / flush / get (ticket ids travel as ints)
                          / update_params / ensure_model / evict_model
  * `DataServerClient`  — put / put_when_room / wait_ready / throughput

**Pipelining (protocol v2):** a client opens with a `__hello__` frame
carrying its protocol version and host boot id. A v2 server acks, and
from then on every request frame carries a request id (`"i"`); the
client keeps up to `max_inflight` requests on the wire at once and a
reader thread matches out-of-order replies to `_Future`s. `call` is
submit-then-await-one (unchanged semantics), `call_async` returns the
future, and `notify` is one-way fire-and-forget (frames tagged `"n"` get
no reply at all — telemetry/priority/beat traffic stops paying a round
trip). The server dispatches each connection's requests on a small
thread pool so a slow method does not head-of-line-block the rest. A
legacy peer simply errors the hello (old servers) or never sends one
(old clients); both sides then fall back to the strict serial
one-in-flight protocol, so mixed deployments negotiate down cleanly.

**Same-host shared-memory fast path:** when the hello exchange shows
both peers on the same host (identical boot ids) and shm is enabled, the
client creates a `multiprocessing.shared_memory` ring and registers it
with a `__shm__` frame. Large ndarray blobs (the streamed leaves below)
are then written into the ring and the wire carries a 17-byte
(tag, offset, length) stub instead of the bytes; the ring never wraps a
blob across its physical end and falls back to inline TCP bytes whenever
it is full, so TCP remains the universal fallback. The ring is
client→server only (puts and obs submits are the asymmetric bulk);
replies always travel TCP. A producer that dies unlinks its segment via
its own resource tracker — the consumer just sees the connection drop.

Every pytree that crosses the wire is freshly deserialized in the
receiving process, so a remote WRITER can never corrupt local buffers.
Note the read-side contract did tighten with the param plane:
`ModelPoolClient.pull` keeps a local version cache and returns it BY
REFERENCE (read-only, like a `copy=False` local pull) — pass
`copy=True` before feeding a remote pull to a donating train step,
exactly as in-process callers must.

Wire format: 1 codec byte + 8-byte big-endian length, then one msgpack
(or pickle) message. Requests are `{"m": "ns.method", "a": [...], "k":
{...}}` (+ `"i"` under v2, + `"n": 1` for notifies); replies `{"ok":
result}` or `{"err": message, "tb": traceback}` (+ the echoed `"i"`) — a
remote exception re-raises client-side as `RemoteError` with the server
traceback attached, and a dead peer raises `TransportError` (the
killed-server path the transport tests exercise).

**Streaming transfer (the param plane):** any ndarray leaf at or above
`_CHUNK_THRESHOLD` bytes is NOT serialized into the msgpack frame.
The frame carries a tiny `{"__nds__": [index, dtype, shape]}` stub
(codec byte gains the 0x80 stream flag) and the raw leaf buffers follow
the frame as length-prefixed blobs, sent and received in bounded
`_CHUNK_BYTES` slices (or as shm stubs on a negotiated ring, above).
A 100 MB pytree therefore never exists as one giant msgpack frame on
either side: the sender streams the live array buffers and the receiver
assembles each leaf zero-copy via `np.frombuffer` over its own
bytearray. A peer that dies mid-blob raises `TransportError`, exactly
like one that dies mid-frame. `chunking(...)` overrides the
threshold/slice size per process; the pickle fallback codec never
streams. Frame payloads land in a per-connection growable scratch
buffer (`recv_into`, no per-frame bytes allocation); blob buffers are
fresh per message because the decoded arrays alias them.

`serve_league` is the one-call server: it namespaces one LeagueMgr (and
its ModelPool, and optionally an InfServer) behind a single `RpcServer`
socket — the layout `launch/train.py --role coordinator` binds.

Env knobs: `REPRO_PIPELINE=0` forces the serial v1 protocol,
`REPRO_SHM=0` disables the shm fast path, `REPRO_SHM_MB` sizes the ring
(default 16).
"""
from __future__ import annotations

import contextlib
import dataclasses
import fnmatch
import json
import os
import random
import socket
import struct
import threading
import time
import traceback
from concurrent.futures import ThreadPoolExecutor
from types import SimpleNamespace
from typing import Any, Dict, Hashable, Iterable, List, Optional, Tuple, Union

import numpy as np

try:                                       # NumPy 2.0 moved byte_bounds
    from numpy.lib.array_utils import byte_bounds as _byte_bounds
except ImportError:                        # pragma: no cover — NumPy 1.x
    _byte_bounds = np.byte_bounds

from repro.core.types import (FreezeGate, Hyperparam, MatchResult, ModelKey,
                              Task)
from repro.params.cache import CachedPuller
from repro.params.manifest import (NotModified, ParamDelta, ParamManifest,
                                   apply_delta)  # noqa: F401 — apply_delta
# is re-exported: delta consumers (benchmarks, tools) reach it as
# transport.apply_delta next to the wire types it pairs with
from repro.utils.pytree import tree_copy

try:
    import msgpack
    CODEC = "msgpack"
except ImportError:                              # bare install: no dev extras
    import pickle
    CODEC = "pickle"


class TransportError(ConnectionError):
    """The peer is gone (refused, reset, or closed mid-message). An
    instance with `.unsent = True` guarantees the request never reached
    the wire — always safe to retry."""


class RetryableError(TransportError):
    """A NON-idempotent call failed after the request may have reached the
    server (`report_result`, `put_when_room`, ...): the transport cannot
    know whether the side effect happened, so it refuses to blindly
    resend. The caller resolves the ambiguity at the protocol layer —
    lease/generation guards make a duplicate `report_result` harmless
    (the reaped generation is dropped server-side), and a duplicated or
    lost trajectory segment is just data. Subclasses TransportError so
    legacy `except TransportError` shutdown paths keep working."""


class RemoteError(RuntimeError):
    """The remote method raised; `.remote_tb` carries the server traceback."""

    def __init__(self, message: str, remote_tb: str = ""):
        super().__init__(message)
        self.remote_tb = remote_tb


class _IdleTimeout(Exception):
    """Internal: the socket timed out between frames (no header byte yet).
    The pipelined reader treats this as 'keep waiting' when nothing is in
    flight and as a dead peer when replies are owed."""


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Jittered exponential backoff with a cap and a total deadline.

    N actors respawned together against a restarting pool must not
    thundering-herd it: each client's delay sequence is base * 2^i capped
    at `cap_s`, each multiplied by an independent uniform jitter in
    [0.5, 1.5], and the whole retry loop gives up once `deadline_s` of
    wall time (or `max_attempts` attempts) is spent."""
    base_s: float = 0.1
    cap_s: float = 2.0
    max_attempts: int = 50
    deadline_s: Optional[float] = 5.0

    def delays(self, rng: random.Random):
        """Yield the sleep before each RE-attempt (attempt 0 is free);
        exhaustion means give up. Deadline accounting includes the time
        the attempts themselves burned (monotonic clock, not just the
        sleeps)."""
        t0 = time.monotonic()
        for i in range(max(0, self.max_attempts - 1)):
            d = min(self.cap_s, self.base_s * (2.0 ** i))
            d *= rng.uniform(0.5, 1.5)
            if self.deadline_s is not None:
                left = self.deadline_s - (time.monotonic() - t0)
                if left <= 0:
                    return
                d = min(d, left)
            yield d


# -- protocol constants -------------------------------------------------------
_PROTO = 2                     # this build speaks pipelined v2, serial v1
_HELLO_METHOD = "__hello__"    # v2 opener: a legacy server errors it, which
                               # IS the negotiate-down signal
_SHM_METHOD = "__shm__"        # ring registration (same-host fast path)


def _env_flag(name: str, default: bool) -> bool:
    v = os.environ.get(name)
    if v is None:
        return default
    return v.strip().lower() not in ("0", "false", "no", "off", "")


_PIPELINE_ENABLED = _env_flag("REPRO_PIPELINE", True)
_SHM_ENABLED = _env_flag("REPRO_SHM", True)
_SHM_DEFAULT_MB = float(os.environ.get("REPRO_SHM_MB", "16") or 16)


def _host_boot_id() -> str:
    """Same-host detection for the shm negotiation: two processes on one
    machine read the same kernel boot id; containers with private /proc
    fall back to hostname+MAC, which still only matches same-host."""
    try:
        with open("/proc/sys/kernel/random/boot_id") as f:
            return f.read().strip()
    except OSError:
        import uuid
        return f"{socket.gethostname()}-{uuid.getnode():x}"


_BOOT_ID = _host_boot_id()


# -- codec -------------------------------------------------------------------
# msgpack handles scalars/strings/bytes/lists/dicts natively; everything the
# league protocol adds rides extension dicts: ndarrays (dtype/shape/bytes),
# tuples (strict_types makes them reach `default`, so round-trips preserve
# tuple-ness — pytree treedefs survive), and the §3.3 message dataclasses.

_DATACLASSES = {c.__name__: c for c in
                (ModelKey, Hyperparam, FreezeGate, Task, MatchResult,
                 ParamManifest, ParamDelta, NotModified)}

# streaming-transfer knobs: ndarray leaves >= _CHUNK_THRESHOLD bytes ride
# out-of-band after the frame, sent/received in _CHUNK_BYTES slices
_CHUNK_THRESHOLD = 256 * 1024
_CHUNK_BYTES = 1 << 20
_STREAM_FLAG = 0x80


@contextlib.contextmanager
def chunking(threshold: Optional[int] = None, chunk_bytes: Optional[int] = None):
    """Temporarily override the streaming knobs for THIS process's sends
    (`threshold=None` keeps the current value; `threshold=0` streams
    every leaf, a huge threshold forces monolithic frames). The
    param_plane benchmark's chunked-vs-monolithic axis."""
    global _CHUNK_THRESHOLD, _CHUNK_BYTES
    old = (_CHUNK_THRESHOLD, _CHUNK_BYTES)
    if threshold is not None:
        _CHUNK_THRESHOLD = threshold
    if chunk_bytes is not None:
        _CHUNK_BYTES = chunk_bytes
    try:
        yield
    finally:
        _CHUNK_THRESHOLD, _CHUNK_BYTES = old


def _make_encoder(blobs: Optional[List[np.ndarray]]):
    """msgpack `default` hook; with a `blobs` collector, large ndarrays
    are hoisted out of the frame and replaced by an index stub."""
    def enc(o):
        if isinstance(o, tuple):
            return {"__t__": list(o)}
        if isinstance(o, np.ndarray):
            if blobs is not None and o.nbytes >= _CHUNK_THRESHOLD:
                a = np.ascontiguousarray(o)
                blobs.append(a)
                return {"__nds__": [len(blobs) - 1, a.dtype.str,
                                    list(a.shape)]}
            return {"__nd__": [o.dtype.str, list(o.shape),
                               np.ascontiguousarray(o).tobytes()]}
        if isinstance(o, np.generic):
            return o.item()
        if dataclasses.is_dataclass(o) and type(o).__name__ in _DATACLASSES:
            return {"__dc__": type(o).__name__,
                    "f": {f.name: getattr(o, f.name)
                          for f in dataclasses.fields(o)}}
        if hasattr(o, "__array__"):              # jax.Array and friends
            return enc(np.asarray(o))
        raise TypeError(
            f"cannot serialize {type(o)!r} over the league transport")
    return enc


def _make_decoder(blobs: Optional[List[bytearray]]):
    def dec(d):
        if "__t__" in d and len(d) == 1:
            return tuple(d["__t__"])
        if "__nd__" in d and len(d) == 1:
            dt, shape, buf = d["__nd__"]
            return np.frombuffer(buf, dtype=np.dtype(dt)).reshape(shape).copy()
        if "__nds__" in d and len(d) == 1:
            if blobs is None:
                raise TransportError(
                    "frame references streamed blobs but none followed")
            i, dt, shape = d["__nds__"]
            # zero-copy: the bytearray was recv'd directly into place and
            # is owned exclusively by this message
            return np.frombuffer(blobs[i], dtype=np.dtype(dt)).reshape(shape)
        if "__dc__" in d:
            return _DATACLASSES[d["__dc__"]](**d["f"])
        return d
    return dec


_CODEC_MSGPACK, _CODEC_PICKLE = 1, 2
_CODEC_ID = _CODEC_MSGPACK if CODEC == "msgpack" else _CODEC_PICKLE


def packb(obj, blobs: Optional[List[np.ndarray]] = None) -> bytes:
    """Serialize one message. With a `blobs` list (msgpack codec only),
    large ndarray leaves are appended to it instead of being copied into
    the returned frame — the streaming path `send_msg` uses."""
    if CODEC == "msgpack":
        return msgpack.packb(obj, default=_make_encoder(blobs),
                             strict_types=True, use_bin_type=True)
    return pickle.dumps(obj)


def unpackb(buf, codec_id: Optional[int] = None,
            blobs: Optional[List[bytearray]] = None):
    """Decode with the codec the MESSAGE was packed with (every frame
    carries a codec byte), defaulting to this process's codec. A
    msgpack-encoded frame from a peer on a bare install (no msgpack) is a
    clear error instead of a garbled pickle failure; pickle frames decode
    anywhere (pickle is stdlib). `buf` may be a memoryview into a reused
    scratch buffer — both codecs copy what they keep."""
    codec_id = _CODEC_ID if codec_id is None else codec_id
    if codec_id == _CODEC_MSGPACK:
        if CODEC != "msgpack":
            raise TransportError(
                "peer sent a msgpack frame but msgpack is not installed "
                "here (pip install msgpack, or run all peers bare)")
        return msgpack.unpackb(buf, object_hook=_make_decoder(blobs),
                               raw=False, strict_map_key=False)
    if codec_id == _CODEC_PICKLE:
        import pickle as _pickle
        return _pickle.loads(buf)
    raise TransportError(f"unknown wire codec id {codec_id}")


# -- shared-memory ring (same-host fast path) --------------------------------
_SHM_HEADER = 64       # one cache line; bytes 0..8 = consumer's counter "<Q"


class _ShmRing:
    """Producer side: a single-producer single-consumer byte ring in one
    `multiprocessing.shared_memory` segment. Offsets are VIRTUAL (they
    only ever grow); a blob never wraps the physical end — the tail gap
    is skipped and accounted, so the consumer can copy each blob with one
    slice. `try_write` returns None when the consumer is too far behind
    (ring full) or the blob exceeds the ring; the caller then falls back
    to inline TCP bytes, keeping shm strictly an optimization."""

    def __init__(self, size: int):
        from multiprocessing import shared_memory
        self.size = int(size)
        assert self.size > 0
        self._seg = shared_memory.SharedMemory(
            create=True, size=_SHM_HEADER + self.size)
        self._seg.buf[:_SHM_HEADER] = b"\x00" * _SHM_HEADER
        self._prod = 0                 # virtual write offset
        self.wraps = 0

    @property
    def name(self) -> str:
        return self._seg.name

    def try_write(self, mv) -> Optional[Tuple[int, int]]:
        n = len(mv)
        if n == 0 or n > self.size:
            return None
        v = self._prod
        off = v % self.size
        if off + n > self.size:        # skip the tail gap; never wrap a blob
            v += self.size - off
            off = 0
            self.wraps += 1
        (consumed,) = struct.unpack_from("<Q", self._seg.buf, 0)
        if v + n - consumed > self.size:
            return None                # consumer behind: fall back to TCP
        try:
            # np.copyto is measurably faster than memoryview slice
            # assignment for MB-sized blobs — this copy IS the shm path's
            # cost, so it gets the fast lane
            np.copyto(np.frombuffer(self._seg.buf, np.uint8, n,
                                    _SHM_HEADER + off),
                      np.frombuffer(mv, np.uint8))
        except (ValueError, TypeError):   # non-contiguous source
            self._seg.buf[_SHM_HEADER + off:_SHM_HEADER + off + n] = mv
        self._prod = v + n
        return (v, n)

    def close(self) -> None:
        # close() can raise BufferError under exported views and unlink
        # can race the peer; neither failure matters at teardown
        with contextlib.suppress(Exception):
            self._seg.close()
        with contextlib.suppress(Exception):
            self._seg.unlink()


class _ShmReader:
    """Consumer side: attach to the client's ring WITHOUT letting this
    process's resource tracker adopt it (bpo-38119 — the attacher's
    tracker would unlink a segment it does not own at exit).

    Reads are ZERO-COPY: `view` returns a memoryview straight into the
    segment (a blob never wraps the physical end, so one slice always
    covers it) and does NOT advance the consumed counter. The frame
    reader calls `seal()` once per frame to register the frame's ring
    span; the dispatch worker calls `release(token)` when the handler —
    and the reply that may still reference the blobs — is done with the
    memory. Workers finish out of order, but the consumed counter is a
    single monotonic offset, so spans retire in ARRIVAL order: a span is
    only published once every earlier span has been released too."""

    def __init__(self, name: str, size: int):
        from multiprocessing import shared_memory
        self.size = int(size)
        try:
            try:
                seg = shared_memory.SharedMemory(name=name, track=False)
            except TypeError:          # Python < 3.13: no track kwarg —
                # suppress the attach-side resource_tracker registration
                # (bpo-38119) instead of unregistering after the fact,
                # which double-unregisters when both peers share a process
                from multiprocessing import resource_tracker
                orig = resource_tracker.register
                resource_tracker.register = lambda *a, **k: None
                try:
                    seg = shared_memory.SharedMemory(name=name)
                finally:
                    resource_tracker.register = orig
        except (OSError, ValueError) as e:
            raise TransportError(
                f"cannot attach shm ring {name!r}: {e}") from e
        if seg.size < _SHM_HEADER + self.size:
            with contextlib.suppress(Exception):
                seg.close()
            raise TransportError(
                f"shm ring {name!r} is smaller than negotiated")
        self._seg = seg
        # byte bounds of the mapped segment, for the dispatch-side
        # aliasing check (`_copy_shm_backed`)
        self.bounds = _byte_bounds(np.frombuffer(seg.buf, np.uint8))
        self._lock = threading.Lock()
        self._frame_end: Optional[int] = None   # reader thread only
        self._next_seq = 0                      # arrival order (reader)
        self._retire_seq = 0                    # next span to publish
        self._spans: Dict[int, int] = {}        # seq -> virtual end
        self._released: set = set()

    def view(self, v: int, n: int) -> memoryview:
        off = v % self.size
        if n > self.size or off + n > self.size:
            raise TransportError(
                f"shm blob out of bounds (virt={v}, len={n}, "
                f"ring={self.size})")
        if self._frame_end is None or v + n > self._frame_end:
            self._frame_end = v + n
        return self._seg.buf[_SHM_HEADER + off:_SHM_HEADER + off + n]

    def seal(self) -> Optional[int]:
        """End of one frame (reader thread): claim the frame's ring span
        and return the release token, or None if no blob rode the ring."""
        if self._frame_end is None:
            return None
        with self._lock:
            seq = self._next_seq
            self._next_seq += 1
            self._spans[seq] = self._frame_end
        self._frame_end = None
        return seq

    def release(self, seq: int) -> None:
        """Dispatch worker is done with the frame's blobs: retire spans
        in arrival order and publish the new consumed offset, which is
        what un-fills the producer's ring."""
        with self._lock:
            self._released.add(seq)
            end = None
            while self._retire_seq in self._released:
                self._released.remove(self._retire_seq)
                end = self._spans.pop(self._retire_seq)
                self._retire_seq += 1
            if end is not None:
                (cur,) = struct.unpack_from("<Q", self._seg.buf, 0)
                if end > cur:
                    struct.pack_into("<Q", self._seg.buf, 0, end)

    def close(self) -> None:
        # dispatched handlers may still hold views into the mapping;
        # close() then raises BufferError. Deliberately LEAK the mapping
        # until process exit in that case — and disarm SharedMemory's
        # __del__ (which would retry close and spew "Exception ignored"
        # at GC). The PRODUCER owns the unlink either way.
        try:
            self._seg.close()
        except BufferError:
            self._seg.close = lambda: None
        except Exception:                  # noqa: BLE001 — teardown
            pass


# -- framing -----------------------------------------------------------------
# 1-byte codec id + 8-byte big-endian length, then the payload. The codec
# byte makes a mixed msgpack/pickle deployment either work (pickle frames
# decode anywhere) or fail with a message that names the problem. The
# 0x80 bit of the codec byte flags a streamed message: a 4-byte blob
# count follows the payload, then each blob. Without a negotiated shm
# ring each blob is 8-byte length + raw bytes; with one, each blob leads
# with a tag byte — 0 = inline (8-byte length + bytes), 1 = shm stub
# (8-byte virtual offset + 8-byte length, no bytes on the wire).

def _send_frame(sock: socket.socket, obj, shm: Optional[_ShmRing] = None,
                stats: Optional[dict] = None) -> None:
    blobs: Optional[List[np.ndarray]] = [] if CODEC == "msgpack" else None
    payload = packb(obj, blobs)
    streamed = bool(blobs)
    try:
        sock.sendall(struct.pack(
            ">BQ", _CODEC_ID | (_STREAM_FLAG if streamed else 0),
            len(payload)) + payload)
        if streamed:
            sock.sendall(struct.pack(">I", len(blobs)))
            for arr in blobs:
                mv = memoryview(arr).cast("B")
                if shm is not None:
                    slot = shm.try_write(mv)
                    if slot is not None:
                        sock.sendall(struct.pack(">BQQ", 1, slot[0], slot[1]))
                        if stats is not None:
                            stats["shm_blobs"] += 1
                        continue
                    sock.sendall(struct.pack(">BQ", 0, len(mv)))
                    if stats is not None:
                        stats["shm_fallbacks"] += 1
                else:
                    sock.sendall(struct.pack(">Q", len(mv)))
                # bounded slices: the bulk buffer is handed to the kernel
                # piecewise, never serialized into one giant frame
                for off in range(0, len(mv), _CHUNK_BYTES):
                    sock.sendall(mv[off:off + _CHUNK_BYTES])
    except OSError as e:
        raise TransportError(f"send failed: {e}") from e


def send_msg(sock: socket.socket, obj) -> None:
    _send_frame(sock, obj)


class _FrameReader:
    """Per-connection receive state: one growable scratch buffer that
    every frame payload lands in (`recv_into`, no per-frame allocation)
    plus a small metadata buffer for headers and blob prefixes — kept
    separate so reading a blob header can never clobber the payload the
    decoder is still aliasing. Blob bytes land in FRESH bytearrays: the
    decoded ndarrays wrap them zero-copy and outlive the scratch."""

    __slots__ = ("_sock", "_scratch", "_meta")

    def __init__(self, sock: socket.socket):
        self._sock = sock
        self._scratch = bytearray(64 * 1024)
        self._meta = bytearray(32)

    def _read_into(self, mv, n: int, first: bool = False) -> None:
        off = 0
        while off < n:
            try:
                k = self._sock.recv_into(
                    mv[off:off + min(_CHUNK_BYTES, n - off)])
            except socket.timeout:
                if first and off == 0:
                    raise _IdleTimeout() from None
                raise TransportError("recv timed out mid-frame") from None
            except OSError as e:
                raise TransportError(f"recv failed: {e}") from e
            if k == 0:
                raise TransportError("peer closed the connection")
            off += k

    def _read_meta(self, n: int, first: bool = False):
        mv = memoryview(self._meta)[:n]
        self._read_into(mv, n, first)
        return self._meta

    def _read_blob(self, n: int) -> bytearray:
        buf = bytearray(n)
        mv = memoryview(buf)
        off = 0
        while off < n:
            try:
                k = self._sock.recv_into(
                    mv[off:off + min(_CHUNK_BYTES, n - off)])
            except OSError as e:
                raise TransportError(f"recv failed mid-chunk: {e}") from e
            if k == 0:
                raise TransportError(
                    f"peer closed the connection mid-chunk ({off}/{n} bytes)")
            off += k
        return buf

    def recv(self, shm: Optional[_ShmReader] = None, idle_ok: bool = False):
        """Receive one message. With `idle_ok`, a socket timeout BEFORE
        the first header byte raises `_IdleTimeout` (the pipelined
        reader's 'nothing owed, keep waiting' signal); a timeout anywhere
        else is a dead peer. With `shm`, blob prefixes are tagged (see
        the wire format note above)."""
        self._read_meta(9, first=idle_ok)
        codec_byte, n = struct.unpack_from(">BQ", self._meta)
        codec_id = codec_byte & ~_STREAM_FLAG
        if n > len(self._scratch):
            self._scratch = bytearray(max(n, 2 * len(self._scratch)))
        payload = memoryview(self._scratch)[:n]
        self._read_into(payload, n)
        blobs: Optional[List[bytearray]] = None
        if codec_byte & _STREAM_FLAG:
            self._read_meta(4)
            (count,) = struct.unpack_from(">I", self._meta)
            blobs = []
            for _ in range(count):
                if shm is not None:
                    self._read_meta(1)
                    if self._meta[0] == 1:
                        self._read_meta(16)
                        virt, ln = struct.unpack_from(">QQ", self._meta)
                        blobs.append(shm.view(virt, ln))
                        continue
                self._read_meta(8)
                (ln,) = struct.unpack_from(">Q", self._meta)
                blobs.append(self._read_blob(ln))
        return unpackb(payload, codec_id, blobs)


def recv_msg(sock: socket.socket):
    """One-shot receive (fresh scratch) — tests and hand-rolled wire
    exchanges; long-lived connections keep a `_FrameReader`."""
    return _FrameReader(sock).recv()


def _copy_shm_backed(obj, lo: int, hi: int):
    """Replace every ndarray whose memory lies inside the shm ring
    [lo, hi) with a private copy. The dispatch worker runs this on the
    request args when the target method does NOT declare
    `_zero_copy_ok = True` — such a handler may retain the array past
    the dispatch (e.g. `InfServer.submit` references obs until flush),
    and the ring span is recycled the moment the dispatch returns.
    Handlers that copy-or-finish during dispatch (`DataServer.put*`
    copies rows into its preallocated ring) mark themselves and skip
    this — that is the zero-copy fast path."""
    if isinstance(obj, np.ndarray):
        lo_a, hi_a = _byte_bounds(obj)
        return obj.copy() if (lo_a >= lo and hi_a <= hi) else obj
    if isinstance(obj, dict):
        return {k: _copy_shm_backed(v, lo, hi) for k, v in obj.items()}
    if isinstance(obj, tuple):
        return tuple(_copy_shm_backed(v, lo, hi) for v in obj)
    if isinstance(obj, list):
        return [_copy_shm_backed(v, lo, hi) for v in obj]
    if dataclasses.is_dataclass(obj) and not isinstance(obj, type):
        for f in dataclasses.fields(obj):
            v = getattr(obj, f.name)
            c = _copy_shm_backed(v, lo, hi)
            if c is not v:
                object.__setattr__(obj, f.name, c)
        return obj
    return obj


def parse_addr(addr: str) -> Tuple[str, int]:
    """'host:port' -> (host, port)."""
    host, _, port = addr.rpartition(":")
    return host or "127.0.0.1", int(port)


# -- chaos harness ------------------------------------------------------------
# Server-side fault injection for the chaos smoke and the fault_recovery
# benchmark: a seeded FaultPlan decides, per incoming request, whether the
# connection drops before dispatch (request lost), after dispatch (reply
# lost — the ambiguity RetryableError models), gets delayed, or dies
# mid-streamed-chunk. Deterministic given (rules, seed, request order per
# rule); ships across process boundaries as JSON via REPRO_FAULT_PLAN.

@dataclasses.dataclass
class FaultRule:
    """One injection rule. `match` is an fnmatch pattern over the wire
    method name (`"pool.*"`, `"*.pull_if_changed"`, `"*"`); `kind` is
    `drop` (close before dispatch), `drop_reply` (dispatch, then close
    instead of replying), `delay` (sleep `delay_s`, then behave), or
    `close_mid_chunk` (send a truncated reply — for streamed replies,
    half of the first blob — then close). Fires with probability `p`, at
    most `max_times` times."""
    match: str
    kind: str
    p: float = 1.0
    delay_s: float = 0.05
    max_times: Optional[int] = None
    fired: int = 0

    _KINDS = ("drop", "drop_reply", "delay", "close_mid_chunk")

    def __post_init__(self):
        assert self.kind in self._KINDS, \
            f"unknown fault kind {self.kind!r}; pick from {self._KINDS}"


class FaultPlan:
    """A seeded set of FaultRules a `RpcServer` consults per request."""

    def __init__(self, rules: Iterable[FaultRule], seed: int = 0):
        self.rules = list(rules)
        self.seed = seed
        self._rng = random.Random(seed)
        self._lock = threading.Lock()

    def decide(self, method: str) -> Optional[FaultRule]:
        """First matching rule that fires for this request, else None."""
        with self._lock:
            for rule in self.rules:
                if not fnmatch.fnmatchcase(method, rule.match):
                    continue
                if rule.max_times is not None and rule.fired >= rule.max_times:
                    continue
                if rule.p < 1.0 and self._rng.random() >= rule.p:
                    continue
                rule.fired += 1
                return rule
        return None

    def stats(self) -> dict:
        with self._lock:
            return {f"{r.match}:{r.kind}": r.fired for r in self.rules}

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed, "rules": [
            {"match": r.match, "kind": r.kind, "p": r.p,
             "delay_s": r.delay_s, "max_times": r.max_times}
            for r in self.rules]})

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        d = json.loads(s)
        return cls([FaultRule(**r) for r in d.get("rules", [])],
                   seed=d.get("seed", 0))

    @classmethod
    def from_env(cls, var: str = "REPRO_FAULT_PLAN") -> Optional["FaultPlan"]:
        """The cross-process seam: a parent (the chaos smoke) plants the
        plan in the environment; `run_coordinator` installs it on its
        server at startup."""
        s = os.environ.get(var)
        return cls.from_json(s) if s else None


def _send_truncated(sock: socket.socket, obj) -> None:
    """Send a deliberately incomplete reply (the close_mid_chunk fault):
    for streamed messages, the header + payload + half of the first blob;
    otherwise half of the frame itself. The peer sees TransportError
    mid-message, exactly like a server dying mid-transfer."""
    blobs: Optional[List[np.ndarray]] = [] if CODEC == "msgpack" else None
    payload = packb(obj, blobs)
    streamed = bool(blobs)
    header = struct.pack(
        ">BQ", _CODEC_ID | (_STREAM_FLAG if streamed else 0), len(payload))
    if streamed:
        sock.sendall(header + payload)
        sock.sendall(struct.pack(">I", len(blobs)))
        mv = memoryview(blobs[0]).cast("B")
        sock.sendall(struct.pack(">Q", len(mv)))
        sock.sendall(mv[:max(1, len(mv) // 2)])
    else:
        frame = header + payload
        sock.sendall(frame[:max(9, len(frame) // 2)])


# -- server ------------------------------------------------------------------
class RpcServer:
    """Serve the public surface of named objects over one TCP socket.

    `objects` maps a namespace to a backend object; a request for
    `"ns.name"` resolves `getattr(objects[ns], name)` — called with the
    request args when callable, returned as a snapshot value otherwise
    (so plain attributes like `LeagueMgr.frozen_pool` are readable
    remotely). Dunder/private names never resolve.

    One handler thread per connection; a connection whose client opens
    with a v2 `__hello__` is upgraded to the pipelined protocol — its
    requests dispatch on a per-connection thread pool (`conn_workers`)
    and replies go out tagged with the request id as they finish, out of
    order. Every other connection is served with the strict serial v1
    loop. The backend objects' own locks provide the concurrency
    contract, exactly as they do for in-process threads (multiple serial
    connections already dispatched concurrently)."""

    def __init__(self, objects: Dict[str, Any], host: str = "127.0.0.1",
                 port: int = 0, fault_plan: Optional[FaultPlan] = None,
                 pipeline: Optional[bool] = None, conn_workers: int = 8,
                 shm: Optional[bool] = None):
        self._objects = {ns: o for ns, o in objects.items() if o is not None}
        self.fault_plan = fault_plan
        self._pipeline = _PIPELINE_ENABLED if pipeline is None else bool(pipeline)
        self._conn_workers = max(1, int(conn_workers))
        self._shm = _SHM_ENABLED if shm is None else bool(shm)
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(64)
        self._sock.settimeout(0.2)              # accept-loop stop poll
        self._stop = threading.Event()
        self._accept_thread: Optional[threading.Thread] = None
        self._conns: list[socket.socket] = []
        self._lock = threading.Lock()

    @property
    def address(self) -> str:
        host, port = self._sock.getsockname()
        return f"{host}:{port}"

    def start(self) -> "RpcServer":
        if self._accept_thread is not None:      # idempotent
            return self
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name=f"rpc-accept@{self.address}",
            daemon=True)
        self._accept_thread.start()
        return self

    def _accept_loop(self):
        while not self._stop.is_set():
            try:
                conn, _ = self._sock.accept()
            except socket.timeout:
                continue
            except OSError:
                return
            # pipelined replies go out as bursts of small frames; Nagle
            # would hold each burst for the peer's delayed ACK
            with contextlib.suppress(OSError):
                conn.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            with self._lock:
                self._conns.append(conn)
            threading.Thread(target=self._serve_conn, args=(conn,),
                             daemon=True).start()

    def _serve_conn(self, conn: socket.socket):
        rd = _FrameReader(conn)
        try:
            try:
                first = rd.recv()
            except TransportError:
                return
            if (self._pipeline and isinstance(first, dict)
                    and first.get("m") == _HELLO_METHOD):
                self._serve_pipelined(conn, rd, first)
            else:
                self._serve_legacy(conn, rd, first)
        finally:
            conn.close()

    # - v1: strict serial request/reply (legacy clients, pipeline=False) -
    def _serve_legacy(self, conn: socket.socket, rd: _FrameReader, req):
        while not self._stop.is_set():
            rule = (self.fault_plan.decide(req.get("m", ""))
                    if self.fault_plan is not None else None)
            if rule is not None:
                if rule.kind == "drop":
                    return                 # request lost, never dispatched
                if rule.kind == "delay":
                    time.sleep(rule.delay_s)
            reply = self._dispatch(req)
            if rule is not None and rule.kind == "drop_reply":
                return                     # executed, reply lost
            if rule is not None and rule.kind == "close_mid_chunk":
                with contextlib.suppress(OSError):
                    _send_truncated(conn, reply)
                return
            try:
                send_msg(conn, reply)
            except TransportError:
                return                     # peer gone mid-reply
            except Exception as e:         # noqa: BLE001 — result didn't
                # serialize (packb raises before any bytes hit the
                # socket): ship the failure as a RemoteError instead of
                # dropping the connection, which clients would misread
                # as a server shutdown
                send_msg(conn, {"err": f"unserializable reply: "
                                       f"{type(e).__name__}: {e}",
                                "tb": traceback.format_exc()})
            try:
                req = rd.recv()
            except TransportError:
                return

    # - v2: pipelined, id-tagged, out-of-order replies ----------------------
    def _serve_pipelined(self, conn: socket.socket, rd: _FrameReader, hello):
        send_lock = threading.Lock()
        shm_reader: Optional[_ShmReader] = None
        try:
            client_proto = int((hello.get("a") or [1])[0])
        except (TypeError, ValueError):
            client_proto = 1

        def shutdown():
            # wake our own blocked rd.recv AND the client's reader
            with contextlib.suppress(OSError):
                conn.shutdown(socket.SHUT_RDWR)

        def reply(msg):
            try:
                with send_lock:
                    send_msg(conn, msg)
            except TransportError:
                shutdown()
            except Exception as e:         # noqa: BLE001 — unserializable
                # reply: packb raised before any bytes hit the socket
                with contextlib.suppress(Exception):
                    with send_lock:
                        send_msg(conn, {
                            "i": msg.get("i"),
                            "err": f"unserializable reply: "
                                   f"{type(e).__name__}: {e}",
                            "tb": traceback.format_exc()})

        reply({"i": hello.get("i"),
               "ok": {"proto": min(_PROTO, max(1, client_proto)),
                      "boot": _BOOT_ID, "shm": self._shm}})
        pool = ThreadPoolExecutor(
            max_workers=self._conn_workers,
            thread_name_prefix=f"rpc-worker@{self.address}")
        try:
            while not self._stop.is_set():
                try:
                    req = rd.recv(shm=shm_reader)
                except TransportError:
                    return
                # frames that used ring blobs hold their span until the
                # dispatch worker releases it (zero-copy reads)
                token = shm_reader.seal() if shm_reader is not None else None
                method = req.get("m", "") if isinstance(req, dict) else ""
                if method == _SHM_METHOD:
                    ok = False
                    if self._shm:
                        try:
                            shm_reader = _ShmReader(
                                req["a"][0], int(req["a"][1]))
                            ok = True
                        except (TransportError, Exception):  # noqa: B014
                            shm_reader = None
                    reply({"i": req.get("i"), "ok": bool(ok)})
                    continue
                rule = (self.fault_plan.decide(method)
                        if self.fault_plan is not None else None)
                if rule is not None and rule.kind == "drop":
                    return                 # request lost, never dispatched
                pool.submit(self._handle_pipelined, conn, send_lock,
                            shutdown, reply, req, rule, shm_reader, token)
        finally:
            pool.shutdown(wait=False)
            if shm_reader is not None:
                shm_reader.close()

    def _handle_pipelined(self, conn, send_lock, shutdown, reply, req, rule,
                          shm=None, token=None):
        try:
            if rule is not None and rule.kind == "delay":
                time.sleep(rule.delay_s)
            if token is not None and not self._zero_copy_ok(req):
                # the handler may retain the ring-backed arrays past the
                # dispatch; privatize them before the span is recycled
                lo, hi = shm.bounds
                req["a"] = _copy_shm_backed(req.get("a", ()), lo, hi)
                req["k"] = _copy_shm_backed(req.get("k", {}), lo, hi)
            result = self._dispatch(req)
            if req.get("n"):
                return                     # one-way notify: no reply at all
            if rule is not None and rule.kind == "drop_reply":
                shutdown()                 # executed, connection dies
                return
            result["i"] = req.get("i")
            if rule is not None and rule.kind == "close_mid_chunk":
                with contextlib.suppress(OSError):
                    with send_lock:
                        _send_truncated(conn, result)
                shutdown()
                return
            reply(result)
        except Exception:                  # noqa: BLE001 — a worker must
            # never die silently; treat any escape as a dead connection
            shutdown()
        finally:
            if token is not None:
                # reply (which may reference the blobs) is out: retire
                # the frame's ring span so the producer can reuse it
                shm.release(token)

    def _zero_copy_ok(self, req) -> bool:
        """Does the target method declare it never retains argument
        arrays past the dispatch (`_zero_copy_ok = True`)?"""
        try:
            ns, _, name = req.get("m", "").partition(".")
            if name.startswith("_") or not name:
                return False
            target = getattr(self._objects.get(ns), name, None)
            return bool(getattr(target, "_zero_copy_ok", False))
        except Exception:                  # noqa: BLE001 — resolution
            return False                   # failures fall to the safe copy

    def _dispatch(self, req) -> dict:
        try:
            ns, _, name = req["m"].partition(".")
            if name.startswith("_") or not name:
                raise AttributeError(f"{req['m']!r} is not a public method")
            target = getattr(self._objects[ns], name)
            result = (target(*req.get("a", ()), **req.get("k", {}))
                      if callable(target) else target)
            return {"ok": result}
        except Exception as e:                   # noqa: BLE001 — shipped back
            return {"err": f"{type(e).__name__}: {e}",
                    "tb": traceback.format_exc()}

    def close(self) -> None:
        self._stop.set()
        self._sock.close()
        with self._lock:
            conns, self._conns = self._conns, []
        for c in conns:
            try:
                c.shutdown(socket.SHUT_RDWR)
            except OSError:
                pass
            c.close()
        if self._accept_thread is not None:
            self._accept_thread.join(timeout=5.0)

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.close()


# -- client ------------------------------------------------------------------
class _Future:
    """Minimal thread-safe future for pipelined replies. `result` raises
    the remote/transport failure or returns the reply VALUE (`"ok"`,
    already unwrapped)."""

    __slots__ = ("_ev", "_result", "_exc")

    def __init__(self):
        self._ev = threading.Event()
        self._result = None
        self._exc: Optional[BaseException] = None

    def set_result(self, value) -> None:
        self._result = value
        self._ev.set()

    def set_exception(self, exc: BaseException) -> None:
        self._exc = exc
        self._ev.set()

    def done(self) -> bool:
        return self._ev.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._ev.wait(timeout):
            raise TimeoutError(f"no reply within {timeout}s")
        if self._exc is not None:
            raise self._exc
        return self._result


class _ClientConn:
    """One live connection: socket + frame reader + (for v2) the pending
    request-id → future map the reader thread resolves. `fail` is the
    single teardown path — it poisons every pending future, wakes both a
    blocked serial caller and the reader, and releases the shm ring."""

    __slots__ = ("sock", "rd", "addr", "send_lock", "plock", "pending",
                 "next_rid", "proto", "shm", "dead", "reader", "stats", "sem")

    def __init__(self, sock: socket.socket, addr: str, max_inflight: int):
        self.sock = sock
        self.rd = _FrameReader(sock)
        self.addr = addr
        self.send_lock = threading.Lock()
        self.plock = threading.Lock()
        self.pending: Dict[int, _Future] = {}
        self.next_rid = 0
        self.proto = 1
        self.shm: Optional[_ShmRing] = None
        self.dead: Optional[TransportError] = None
        self.reader: Optional[threading.Thread] = None
        self.stats = {"shm_blobs": 0, "shm_fallbacks": 0}
        self.sem = threading.Semaphore(max_inflight)

    def rid(self) -> int:
        with self.plock:
            r = self.next_rid
            self.next_rid += 1
            return r

    def has_pending(self) -> bool:
        with self.plock:
            return bool(self.pending)

    def pop_pending(self, rid) -> Optional[_Future]:
        with self.plock:
            fut = self.pending.pop(rid, None)
        if fut is not None:
            self.sem.release()
        return fut

    def submit(self, method: str, args, kwargs) -> _Future:
        """Register a future and put the request on the wire (v2 only).
        Raises TransportError with `.unsent = True` when the connection
        is already down (nothing hit the wire — safe to retry); a send
        failure fails the whole connection and re-raises ambiguous."""
        fut = _Future()
        self.sem.acquire()
        registered = False
        try:
            with self.plock:
                if self.dead is not None:
                    e = TransportError(
                        f"connection to {self.addr} is down: {self.dead}")
                    e.unsent = True
                    raise e
                rid = self.next_rid
                self.next_rid += 1
                self.pending[rid] = fut
            registered = True
        finally:
            if not registered:
                self.sem.release()
        try:
            with self.send_lock:
                _send_frame(self.sock,
                            {"i": rid, "m": method, "a": list(args),
                             "k": kwargs},
                            shm=self.shm, stats=self.stats)
        except TransportError as e:
            self.fail(e)
            raise
        return fut

    def send_notify(self, method: str, args, kwargs) -> None:
        with self.plock:
            if self.dead is not None:
                e = TransportError(
                    f"connection to {self.addr} is down: {self.dead}")
                e.unsent = True
                raise e
        with self.send_lock:
            _send_frame(self.sock,
                        {"m": method, "a": list(args), "k": kwargs, "n": 1},
                        shm=self.shm, stats=self.stats)

    def fail(self, exc: TransportError) -> None:
        with self.plock:
            if self.dead is None:
                self.dead = exc
            pending, self.pending = self.pending, {}
        for fut in pending.values():
            fut.set_exception(TransportError(str(exc)))
            self.sem.release()
        with contextlib.suppress(OSError):
            self.sock.shutdown(socket.SHUT_RDWR)
        with contextlib.suppress(OSError):
            self.sock.close()
        shm, self.shm = self.shm, None
        if shm is not None:
            shm.close()


class RpcClient:
    """One connection, pipelined when the peer speaks v2 (thread-safe:
    any number of threads may `call`/`call_async`/`notify` concurrently
    and share the connection — requests interleave on the wire and the
    reader thread routes each reply to its caller; against a legacy peer
    calls serialize on a lock exactly as before).

    Failure handling (the robustness plane):

    * `address` may be one endpoint, a comma-separated list, or a list —
      a failed attempt rotates to the next endpoint, so a `ModelPoolClient`
      handed `[replica, primary]` survives either dying.
    * connect failures and IDEMPOTENT call failures retry under the
      jittered-exponential-backoff `RetryPolicy` (pass `idempotent=True`
      to `call` — the seam wrappers do for `pull_if_changed`,
      `request_task`, `has_model`, `ping` and other pure reads).
    * a NON-idempotent call that fails after the request was (possibly)
      sent raises `RetryableError`: the side effect may have happened, so
      the caller must resolve it at the protocol layer instead of the
      transport resending blind. A failure guaranteed pre-wire carries
      `.unsent = True` and retries freely.
    * `abort()` (another thread) poisons the client: every in-flight call
      wakes with TransportError and NO further retry — a heartbeat
      monitor that declared the peer dead must not fight a 5s backoff.
    * a connection failure poisons ALL of its in-flight futures (the
      transport cannot know which requests the dead server processed).

    `call_async` submits without waiting and returns a `_Future`; against
    a legacy peer it degrades to the synchronous call with an
    already-resolved future. `notify` is one-way: no reply is ever
    generated server-side (v2) or the reply is drained and discarded
    (legacy); send failures drop the message (`notify_drops` counts) —
    beat/telemetry traffic must never block progress.

    `connect_retries`/`retry_delay_s` are the legacy knobs: they map onto
    `RetryPolicy(max_attempts=connect_retries, base_s=retry_delay_s,
    deadline_s=connect_retries * retry_delay_s)`, preserving the old
    worst-case wait while replacing the fixed sleep with jittered
    backoff."""

    def __init__(self, address: Union[str, Iterable[str]],
                 timeout: Optional[float] = None,
                 connect_retries: int = 50, retry_delay_s: float = 0.1,
                 retry: Optional[RetryPolicy] = None,
                 seed: Optional[int] = None,
                 pipeline: Optional[bool] = None,
                 shm: Optional[bool] = None,
                 shm_bytes: Optional[int] = None,
                 max_inflight: int = 256):
        if isinstance(address, str):
            self._endpoints = [a.strip() for a in address.split(",") if a.strip()]
        else:
            self._endpoints = list(address)
        assert self._endpoints, "RpcClient needs at least one endpoint"
        self._ep_i = 0
        self._timeout = timeout
        self._retry = retry or RetryPolicy(
            base_s=retry_delay_s, max_attempts=max(1, connect_retries),
            deadline_s=max(1, connect_retries) * retry_delay_s)
        self._rng = random.Random(seed)
        self._pipeline = _PIPELINE_ENABLED if pipeline is None else bool(pipeline)
        self._shm = _SHM_ENABLED if shm is None else bool(shm)
        self._shm_bytes = int(shm_bytes if shm_bytes is not None
                              else _SHM_DEFAULT_MB * (1 << 20))
        self._max_inflight = max(1, int(max_inflight))
        self._conn: Optional[_ClientConn] = None
        self._lock = threading.Lock()
        self._aborted = False
        self.notify_drops = 0

    @property
    def address(self) -> str:
        """The CURRENT endpoint (rotates on failover)."""
        return self._endpoints[self._ep_i]

    @property
    def endpoints(self) -> Tuple[str, ...]:
        return tuple(self._endpoints)

    # - connection lifecycle -------------------------------------------------
    def _ensure_conn(self) -> _ClientConn:
        """Return the live connection, dialing + negotiating a new one if
        needed. Every TransportError raised here carries `.unsent = True`
        — no caller request has touched the wire yet."""
        with self._lock:
            if self._aborted:
                e = TransportError(f"client for {self.address} was aborted")
                e.unsent = True
                raise e
            conn = self._conn
            if conn is not None and conn.dead is None:
                return conn
            self._conn = None
            host, port = parse_addr(self.address)
            try:
                sock = socket.create_connection((host, port), timeout=10.0)
            except OSError as e:
                err = TransportError(f"cannot connect to {self.address}: {e}")
                err.unsent = True
                raise err from e
            sock.settimeout(self._timeout)
            sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
            conn = _ClientConn(sock, self.address, self._max_inflight)
            if self._pipeline:
                try:
                    self._negotiate(conn)
                except TransportError as e:
                    with contextlib.suppress(OSError):
                        sock.close()
                    e.unsent = True    # only the internal hello was on the wire
                    raise
            if conn.proto >= 2:
                t = threading.Thread(
                    target=self._reader_loop, args=(conn,),
                    name=f"rpc-reader@{self.address}", daemon=True)
                conn.reader = t
                t.start()
            self._conn = conn
            return conn

    def _negotiate(self, conn: _ClientConn) -> None:
        """Synchronous hello exchange (the reader is not running yet). A
        legacy server dispatches `__hello__`, fails to resolve it and
        answers `{"err": ...}` — that IS the negotiate-down signal; we
        stay on the serial v1 protocol over the same connection. A v2
        server acks with its proto/boot/shm capabilities; matching boot
        ids then negotiate the shm ring with a second exchange."""
        _send_frame(conn.sock, {"i": conn.rid(), "m": _HELLO_METHOD,
                                "a": [_PROTO], "k": {"boot": _BOOT_ID}})
        reply = conn.rd.recv()
        ack = reply.get("ok") if isinstance(reply, dict) else None
        if not isinstance(ack, dict):
            conn.proto = 1                 # legacy peer errored the hello
            return
        try:
            conn.proto = min(_PROTO, max(1, int(ack.get("proto", 1))))
        except (TypeError, ValueError):
            conn.proto = 1
        if not (conn.proto >= 2 and self._shm and ack.get("shm")
                and ack.get("boot") == _BOOT_ID):
            return
        try:
            ring = _ShmRing(self._shm_bytes)
        except Exception:                  # noqa: BLE001 — /dev/shm full or
            return                         # absent: silently stay on TCP
        try:
            _send_frame(conn.sock, {"i": conn.rid(), "m": _SHM_METHOD,
                                    "a": [ring.name, ring.size], "k": {}})
            ack2 = conn.rd.recv()
        except TransportError:
            ring.close()
            raise
        if isinstance(ack2, dict) and ack2.get("ok"):
            conn.shm = ring
        else:
            ring.close()

    def _reader_loop(self, conn: _ClientConn) -> None:
        """Route id-tagged replies to their futures, out of order. A
        socket timeout only kills the connection when replies are owed;
        an idle pipelined connection waits forever (liveness is the
        heartbeat plane's job, not the transport's)."""
        while True:
            try:
                msg = conn.rd.recv(idle_ok=True)
            except _IdleTimeout:
                if conn.has_pending():
                    conn.fail(TransportError(
                        f"timed out after {self._timeout}s waiting for a "
                        f"reply from {conn.addr}"))
                    return
                continue
            except TransportError as e:
                conn.fail(e)
                return
            except Exception as e:         # noqa: BLE001 — a decode bug must
                conn.fail(TransportError(f"reader failed: {e}"))
                return
            rid = msg.get("i") if isinstance(msg, dict) else None
            fut = conn.pop_pending(rid)
            if fut is None:
                continue                   # stale reply after a local drop
            if "err" in msg:
                fut.set_exception(RemoteError(msg["err"], msg.get("tb", "")))
            else:
                fut.set_result(msg.get("ok"))

    def _drop_conn(self, conn: _ClientConn, exc: TransportError) -> None:
        with self._lock:
            if self._conn is conn:
                self._conn = None
        conn.fail(exc)

    def _rotate(self) -> None:
        if len(self._endpoints) > 1:
            self._ep_i = (self._ep_i + 1) % len(self._endpoints)

    # - the three call shapes ------------------------------------------------
    def call(self, method: str, *args, idempotent: bool = False, **kwargs):
        """Submit and await one reply (the classic shape). Pipelined
        under v2 — other threads' calls overlap on the same connection;
        serial with the connection lock held across the round trip under
        v1."""
        delays = self._retry.delays(self._rng)
        last: Optional[TransportError] = None
        while True:
            if self._aborted:
                raise last or TransportError(
                    f"client for {self.address} was aborted")
            sent = False
            conn: Optional[_ClientConn] = None
            try:
                conn = self._ensure_conn()
                if conn.proto >= 2:
                    sent = True
                    fut = conn.submit(method, args, kwargs)
                    return fut.result()    # RemoteError propagates, no retry
                with conn.send_lock:
                    sent = True
                    _send_frame(conn.sock,
                                {"m": method, "a": list(args), "k": kwargs})
                    reply = conn.rd.recv()
                if "err" in reply:
                    raise RemoteError(reply["err"], reply.get("tb", ""))
                return reply.get("ok")
            except TransportError as e:
                if conn is not None:
                    self._drop_conn(conn, e)
                last = e
                if self._aborted:
                    raise
                if sent and not idempotent and not getattr(e, "unsent", False):
                    raise RetryableError(
                        f"{method} may or may not have executed on "
                        f"{self.address}: {e}") from e
                try:
                    delay = next(delays)
                except StopIteration:
                    raise TransportError(
                        f"cannot reach any of {self._endpoints} "
                        f"for {method}: {last}") from last
                self._rotate()
                if delay > 0:
                    time.sleep(delay)

    def call_async(self, method: str, *args, **kwargs) -> _Future:
        """Submit without waiting; returns a `_Future` whose `result()`
        yields the reply value or raises RemoteError/TransportError. One
        attempt, no retry loop — a connect failure raises immediately
        (with `.unsent = True`) so fan-out callers can fail over fast.
        Against a legacy peer this degrades to the synchronous retrying
        `call` wrapped in an already-resolved future."""
        if self._aborted:
            e = TransportError(f"client for {self.address} was aborted")
            e.unsent = True
            raise e
        conn = self._ensure_conn()
        if conn.proto >= 2:
            try:
                return conn.submit(method, args, kwargs)
            except TransportError as e:
                self._drop_conn(conn, e)
                raise
        fut = _Future()
        try:
            fut.set_result(self.call(method, *args, **kwargs))
        except (TransportError, RemoteError) as e:
            fut.set_exception(e)
        return fut

    def notify(self, method: str, *args, **kwargs) -> bool:
        """One-way fire-and-forget: no reply is consumed, so no round
        trip is paid (under v2 the server generates no reply at all).
        Returns False — and counts `notify_drops` — instead of raising
        when the message could not be handed to the wire; beat and
        telemetry traffic must never block or kill progress."""
        if self._aborted:
            self.notify_drops += 1
            return False
        try:
            conn = self._ensure_conn()
        except TransportError:
            self.notify_drops += 1
            return False
        try:
            if conn.proto >= 2:
                conn.send_notify(method, args, kwargs)
            else:
                with conn.send_lock:
                    _send_frame(conn.sock,
                                {"m": method, "a": list(args), "k": kwargs})
                    conn.rd.recv()         # drain + discard the v1 reply
        except TransportError as e:
            self._drop_conn(conn, e)
            self.notify_drops += 1
            return False
        return True

    # - teardown + introspection ---------------------------------------------
    def close(self) -> None:
        with self._lock:
            conn, self._conn = self._conn, None
        if conn is not None:
            conn.fail(TransportError(f"client for {conn.addr} closed"))

    def abort(self) -> None:
        """Force-close from ANOTHER thread: fails the connection, which
        shuts the socket down (waking a v1 caller blocked in recv and the
        v2 reader) and poisons every pipelined future. Poisons the client
        against further retries. Deliberately takes no client lock — a
        blocked caller may be holding it."""
        self._aborted = True
        conn = self._conn
        if conn is not None:
            conn.fail(TransportError(
                f"client for {self.address} was aborted"))

    def transport_stats(self) -> dict:
        """Negotiation + fast-path counters for benches and tests."""
        conn = self._conn
        shm = conn.shm if conn is not None else None
        return {
            "proto": conn.proto if conn is not None else 0,
            "shm": shm is not None,
            "shm_blobs": conn.stats["shm_blobs"] if conn is not None else 0,
            "shm_fallbacks": (conn.stats["shm_fallbacks"]
                              if conn is not None else 0),
            "shm_wraps": shm.wraps if shm is not None else 0,
            "notify_drops": self.notify_drops,
        }


class _ShipFuture:
    """Future for a non-idempotent async ship (`put_when_room_async`):
    a transport failure after the frame may have hit the wire surfaces
    as `RetryableError` from `result()`, exactly like the synchronous
    call raising it — the caller resolves the ambiguity (a duplicated or
    lost segment is just data). A pre-wire failure (`.unsent`) passes
    through as plain TransportError: safe to resubmit."""

    __slots__ = ("_fut", "_addr")

    def __init__(self, fut: _Future, addr: str):
        self._fut = fut
        self._addr = addr

    def done(self) -> bool:
        return self._fut.done()

    def result(self, timeout: Optional[float] = None):
        try:
            return self._fut.result(timeout)
        except RetryableError:
            raise
        except TransportError as e:
            if getattr(e, "unsent", False):
                raise
            raise RetryableError(
                f"put_when_room may or may not have executed on "
                f"{self._addr}: {e}") from e


class _NamespaceClient:
    """Shared plumbing: bind an RpcClient (or address/endpoint-list) to
    one namespace. `_get` marks the call idempotent — safe to resend with
    backoff and to fail over across endpoints. `_notify` is one-way,
    `_call_async` returns a future (both degrade against legacy peers —
    see RpcClient)."""

    def __init__(self, client, ns: str):
        self._c = client if isinstance(client, RpcClient) else RpcClient(client)
        self._ns = ns

    def _call(self, name: str, *args, **kwargs):
        return self._c.call(f"{self._ns}.{name}", *args, **kwargs)

    def _get(self, name: str, *args, **kwargs):
        return self._c.call(f"{self._ns}.{name}", *args, idempotent=True,
                            **kwargs)

    def _call_async(self, name: str, *args, **kwargs) -> _Future:
        return self._c.call_async(f"{self._ns}.{name}", *args, **kwargs)

    def _notify(self, name: str, *args, **kwargs) -> bool:
        return self._c.notify(f"{self._ns}.{name}", *args, **kwargs)

    def ping(self) -> bool:
        """Idempotent liveness probe against the namespace's server; True
        when any method on it answers (the remote `ping` if it exists).
        Deliberately a round trip, NOT a notify — liveness consumers
        (the heartbeat monitor) need the reply."""
        try:
            self._get("ping")
        except RemoteError:
            pass                       # server is up, ns just has no ping
        return True

    def transport_stats(self) -> dict:
        return self._c.transport_stats()

    def close(self) -> None:
        self._c.close()

    def abort(self) -> None:
        """Wake blocked in-flight calls with TransportError (see
        `RpcClient.abort`)."""
        self._c.abort()


# -- seam wrappers -----------------------------------------------------------
class ModelPoolClient(_NamespaceClient):
    """Remote `repro.core.ModelPool` with a LOCAL VERSION CACHE: `pull`
    sends the cached version number, and the server answers with a
    `NotModified` tag (cache hit — zero param bytes move), the changed
    leaves only (grafted onto the cached copy), or the full pytree
    (first pull / prehistoric cache). Callers written against the plain
    pool API therefore get hash-gated delta pulls for free.

    Cache-hit and delta pulls return the cached object BY REFERENCE —
    read-only by contract, like a `copy=False` local pull. Pass
    `copy=True` (the Learner's post-freeze adopt does) for a private
    deep copy the caller may feed to a donating train step. Every array
    that does cross the wire lands in fresh buffers, so corruption by a
    remote writer remains impossible by construction."""

    def __init__(self, client, ns: str = "pool", write_client=None):
        super().__init__(client, ns)
        # the cache logic itself lives in CachedPuller (it drives our raw
        # pull_if_changed below); this class only adds the lock and the
        # copy-on-request semantics
        self._puller = CachedPuller(self)
        self._cache_lock = threading.Lock()
        # reads may fail over across replicas (`client` can be an endpoint
        # list), but WRITES must land on the primary: a separate pinned
        # connection when the read path is replicated
        self._w = (write_client if (write_client is None or
                                    isinstance(write_client, RpcClient))
                   else RpcClient(write_client))

    def _write(self, name: str, *args, **kwargs):
        if self._w is not None:
            return self._w.call(f"{self._ns}.{name}", *args, **kwargs)
        return self._call(name, *args, **kwargs)

    def _read(self, name: str, *args, **kwargs):
        """Keyed read with replica-lag fallback: a replica that hasn't
        synced a freshly-minted key yet answers `RemoteError(KeyError)`
        — the server is alive, so endpoint failover never triggers.
        When a pinned primary exists, retry the read there; the primary
        minted the key, so it always has it."""
        try:
            return self._get(name, *args, **kwargs)
        except RemoteError as e:
            if self._w is None or not str(e).startswith("KeyError"):
                raise
            return self._w.call(f"{self._ns}.{name}", *args, **kwargs)

    def pull(self, key: ModelKey, copy: Optional[bool] = None):
        with self._cache_lock:
            params = self._puller.get(key)
        return tree_copy(params) if copy else params

    def drop(self, key: ModelKey) -> None:
        """Evict `key` from the local version cache (a model-sized
        allocation): callers that pull a key once and then sync through
        their own CachedPuller should drop it so two copies aren't
        pinned for the process lifetime."""
        with self._cache_lock:
            self._puller.drop(key)

    def clear_cache(self) -> None:
        with self._cache_lock:
            self._puller.clear()

    def pull_if_changed(self, key: ModelKey,
                        have_version: Optional[int] = None,
                        copy: Optional[bool] = None, have_hashes=None):
        """The raw protocol call (no client-side caching — `CachedPuller`
        or `pull` own the cache). `copy` is accepted for signature
        compatibility; remote arrays are fresh by construction.
        `have_hashes` rides through to the pool's cross-key content
        addressing: leaves the caller already holds (under any key) come
        back as hash references instead of bytes."""
        if have_hashes is None:
            return self._read("pull_if_changed", key, have_version)
        return self._read("pull_if_changed", key, have_version,
                          have_hashes=sorted(have_hashes))

    def manifest(self, key: ModelKey) -> ParamManifest:
        return self._read("manifest", key)

    def version(self, key: ModelKey) -> int:
        return self._read("version", key)

    def push(self, key: ModelKey, params, step: int = 0) -> None:
        self._write("push", key, params, step=step)

    def pull_attr(self, key: ModelKey) -> dict:
        return self._read("pull_attr", key)

    def freeze(self, key: ModelKey) -> None:
        self._write("freeze", key)

    def keys(self):
        return self._get("keys")

    def __contains__(self, key: ModelKey) -> bool:
        return key in self.keys()

    @property
    def membership_version(self) -> int:
        return self._get("membership_version")

    def close(self) -> None:
        super().close()
        if self._w is not None:
            self._w.close()

    def abort(self) -> None:
        super().abort()
        if self._w is not None:
            self._w.abort()


class LeagueMgrClient(_NamespaceClient):
    """Remote `repro.core.LeagueMgr` — the Actor/Learner-facing slice of
    the league protocol (request_task/report_result on the actor side,
    should_freeze/end_learning_period on the learner side). `model_pool`
    is a `ModelPoolClient` over the same connection, so code written
    against the in-process LeagueMgr (`league.model_pool.pull(...)`) runs
    unchanged against the remote one."""

    def __init__(self, client, ns: str = "league", pool_ns: str = "pool",
                 pool_endpoints: Optional[Union[str, Iterable[str]]] = None):
        super().__init__(client, ns)
        if pool_endpoints:
            # replicated read path: pulls fail over across the endpoint
            # list; writes (push/freeze) stay pinned to the coordinator's
            # authoritative pool over this client's own connection
            self.model_pool = ModelPoolClient(
                RpcClient(pool_endpoints), ns=pool_ns, write_client=self._c)
        else:
            self.model_pool = ModelPoolClient(self._c, ns=pool_ns)

    def request_task(self, agent_id: str = "main",
                     actor_id: Optional[str] = None) -> Task:
        # idempotent by lease design: a duplicate issue is just an extra
        # lease the reaper collects once its TTL lapses
        if actor_id is None:
            return self._get("request_task", agent_id)
        return self._get("request_task", agent_id, actor_id=actor_id)

    def request_learner_task(self, agent_id: str = "main") -> Task:
        return self._get("request_learner_task", agent_id)

    def report_result(self, result: MatchResult) -> None:
        # NOT idempotent: double-recording an outcome skews the payoff
        # matrix — an ambiguous failure surfaces as RetryableError and the
        # lease generation guard makes the caller's choice safe either way
        self._call("report_result", result)

    def pool_winrate(self, agent_id: str) -> Tuple[float, float]:
        return tuple(self._get("pool_winrate", agent_id))

    def should_freeze(self, agent_id: str, steps: int) -> Optional[str]:
        return self._get("should_freeze", agent_id, steps)

    def end_learning_period(self, agent_id: str, params,
                            reason: str = "period") -> ModelKey:
        return self._call("end_learning_period", agent_id, params,
                          reason=reason)

    def league_state(self) -> dict:
        return self._get("league_state")

    def lease_state(self) -> dict:
        return self._get("lease_state")

    @property
    def frozen_pool(self):
        return list(self._get("frozen_pool"))

    @property
    def agents(self):
        """Remote agent registry shaped like the in-process
        `LeagueMgr.agents` just enough for `Learner.current_key`
        (`league.agents[aid].current`). Lazy: indexing returns a view
        whose `.current` is ONE small `current_model_key` RPC — not a
        full `league_state` dump, which Learner.learn would otherwise
        trigger on every published step."""
        return _RemoteAgents(self)

    def close(self) -> None:
        self.model_pool.close()      # may own a separate replica connection
        super().close()

    def abort(self) -> None:
        self.model_pool.abort()
        super().abort()


class _RemoteAgents:
    def __init__(self, league: "LeagueMgrClient"):
        self._league = league

    def __getitem__(self, agent_id: str) -> SimpleNamespace:
        key = self._league._get("current_model_key", agent_id)
        return SimpleNamespace(current=key)


class RemoteTicket:
    """Client-side future for a submitted batch; mirrors `infserver.Ticket`
    (the integer ticket id is what actually crossed the wire). Under the
    pipelined protocol the id itself may still be in flight
    (`submit_async`): `tid` resolves it lazily on first touch, so a
    collector can stage its next submit before the previous ack lands."""
    __slots__ = ("_tid", "model", "rows", "_client")

    def __init__(self, tid, model, rows: int, client: "InfServerClient"):
        self._tid, self.model, self.rows, self._client = \
            tid, model, rows, client

    @property
    def tid(self) -> int:
        t = self._tid
        if not isinstance(t, int):
            self._tid = t = int(t.result())
        return t

    def done(self) -> bool:
        return self._client.poll(self.tid)

    def result(self):
        return self._client.get(self)

    def __int__(self) -> int:
        return self.tid

    def __repr__(self):
        t = self._tid if isinstance(self._tid, int) else "<pending>"
        return f"RemoteTicket({t}, model={self.model!r}, rows={self.rows})"


class InfServerBackend:
    """Server-side adapter: `infserver.Ticket` holds a live server
    reference, so over the wire only its integer id travels. `submit`
    returns the id, `get` accepts it back, `poll` is the non-blocking
    probe.

    Outstanding tickets are bounded (`max_outstanding`): a client that
    submits and then dies would otherwise leak its ticket — and, once
    flushed, its result arrays — forever in a long-lived serving process.
    Beyond the cap the oldest unfetched ticket is discarded server-side
    (its later `get` raises KeyError, which a live client would see as a
    RemoteError rather than silent wrong data)."""

    def __init__(self, server, max_outstanding: int = 4096):
        self._server = server
        self._max_outstanding = max_outstanding
        self._tickets: Dict[int, Any] = {}       # insertion-ordered
        self._lock = threading.Lock()

    def submit(self, obs, model: Hashable = None,
               deadline_s: Optional[float] = None) -> int:
        # `deadline_s` is accepted so a gateway-aware client can talk to
        # a single server unchanged; a lone InfServer is size-bucketed
        # only, so the hint is ignored rather than raised on.
        t = self._server.submit(np.asarray(obs), model=model)
        with self._lock:
            self._tickets[t.tid] = t
            while len(self._tickets) > self._max_outstanding:
                stale = next(iter(self._tickets))
                self._server.discard(self._tickets.pop(stale))
        return t.tid

    def poll(self, tid: int) -> bool:
        with self._lock:
            t = self._tickets.get(tid)
        return bool(t is not None and t.done())

    def get(self, tid: int):
        with self._lock:
            t = self._tickets.pop(tid)
        a, logp, v = self._server.get(t)
        return np.asarray(a), np.asarray(logp), np.asarray(v)

    def flush(self) -> None:
        self._server.flush()

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        self._server.update_params(params, key=key,
                                   content_hash=content_hash,
                                   version=version)

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        self._server.ensure_model(key, params, content_hash=content_hash)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        self._server.register_model(key, params, content_hash=content_hash,
                                    version=version)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        return self._server.has_model(key, content_hash=content_hash)

    def evict_model(self, key: Hashable) -> bool:
        return self._server.evict_model(key)

    def stats(self) -> dict:
        return self._server.stats()

    def telemetry(self) -> dict:
        return self._server.telemetry()


class InfServerClient(_NamespaceClient):
    """Remote `repro.infserver.InfServer` speaking the same
    submit/flush/get protocol as the in-process server, so
    `build_served_rollout` (and therefore a served Actor) can run against
    either without knowing which it has. The `*_async` variants pipeline
    submits/probes on the shared connection — a collector overlaps its
    per-slot submits, the gateway fans probes across a fleet."""

    def __init__(self, client, ns: str = "inf"):
        super().__init__(client, ns)

    def submit(self, obs: np.ndarray, model: Hashable = None,
               deadline_s: Optional[float] = None) -> RemoteTicket:
        """`deadline_s` rides along only when set: a plain
        `InfServerBackend` has no deadline notion (size-bucketed only),
        a `serving.GatewayBackend` feeds it to the SLO pump."""
        obs = np.asarray(obs)
        if deadline_s is None:
            tid = self._call("submit", obs, model=model)
        else:
            tid = self._call("submit", obs, model=model,
                             deadline_s=deadline_s)
        return RemoteTicket(tid, model, obs.shape[0], self)

    def submit_async(self, obs: np.ndarray, model: Hashable = None,
                     deadline_s: Optional[float] = None) -> RemoteTicket:
        """Pipelined submit: returns immediately with a ticket whose id
        resolves lazily (first `get`/`poll`/`int()` touch). Lets a caller
        put several submits on the wire back to back — the obs rows ride
        the shm ring when negotiated — before awaiting any ack."""
        obs = np.asarray(obs)
        if deadline_s is None:
            fut = self._call_async("submit", obs, model=model)
        else:
            fut = self._call_async("submit", obs, model=model,
                                   deadline_s=deadline_s)
        return RemoteTicket(fut, model, obs.shape[0], self)

    def poll(self, tid) -> bool:
        return self._get("poll", int(tid))

    def get(self, ticket):
        return tuple(self._call("get", int(ticket)))

    def flush(self) -> None:
        self._call("flush")

    def flush_async(self) -> _Future:
        return self._call_async("flush")

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        """Hash-gated hot-swap over RPC: with a `content_hash`, a cheap
        `has_model` probe runs first and the params are NOT shipped when
        the server already hosts that exact content — the common case
        for every actor but the first to refresh a route."""
        if content_hash is not None and self._get("has_model", key,
                                                  content_hash):
            return
        self._call("update_params", params, key=key,
                   content_hash=content_hash, version=version)

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        """Idempotent route setup; with a `content_hash` the params only
        cross the wire when the route is absent or stale."""
        if content_hash is not None and self._get("has_model", key,
                                                  content_hash):
            return
        self._call("ensure_model", key, params, content_hash=content_hash)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        self._call("register_model", key, params, content_hash=content_hash,
                   version=version)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        return self._get("has_model", key, content_hash)

    def has_model_async(self, key: Hashable,
                        content_hash: Optional[str] = None) -> _Future:
        return self._call_async("has_model", key, content_hash)

    def evict_model(self, key: Hashable) -> bool:
        return self._call("evict_model", key)

    def stats(self) -> dict:
        """Full server telemetry across the seam — `InfServer.stats()`
        verbatim (occupancy, per-batch latency, swap + dispatch
        counters). The gateway's router reads the cheap `telemetry()`
        probe instead at steady state; this is the operator view."""
        return self._get("stats")

    def telemetry(self) -> dict:
        """The high-cadence occupancy/latency probe (see
        `InfServer.telemetry`) — the routing signal crossing the RPC
        seam."""
        return self._get("telemetry")

    def telemetry_async(self) -> _Future:
        """Pipelined telemetry probe — the gateway fans these across its
        fleet with a shared deadline so one stalled replica only goes
        stale, never freezes the occupancy view."""
        return self._call_async("telemetry")


class DataServerClient(_NamespaceClient):
    """Remote `repro.learners.DataServer` put-side: the Actor→Learner data
    seam. The DataServer lives in the Learner's process (the paper
    embeds it there); Actors connect here to ship segments. Backpressure
    crosses the boundary: `put_when_room` blocks server-side under the
    ring's condition variable and returns False on timeout exactly like
    the in-process call. `put_when_room_async` overlaps that server-side
    backpressure wait with the actor staging its NEXT segment."""

    def __init__(self, client, ns: str = "data"):
        super().__init__(client, ns)

    def put(self, traj) -> None:
        self._call("put", traj)

    def put_when_room(self, traj, timeout: Optional[float] = None) -> bool:
        return self._call("put_when_room", traj, timeout=timeout)

    def put_when_room_async(self, traj,
                            timeout: Optional[float] = None) -> _ShipFuture:
        """Ship a segment without blocking on the server's admission
        decision: the bulk rows go on the wire (or shm ring) now and the
        returned future resolves to the server's True/False once the ring
        admits or times the segment out. Failure semantics match the
        sync call: ambiguous-after-send surfaces as `RetryableError` from
        `result()`; a failure guaranteed pre-wire falls back to the
        retrying synchronous path before giving up."""
        try:
            fut = self._c.call_async(f"{self._ns}.put_when_room", traj,
                                     timeout=timeout)
        except TransportError as e:
            fut = _Future()
            if getattr(e, "unsent", False):
                # nothing hit the wire — the retrying sync path may still
                # land it (endpoint rotation, backoff)
                try:
                    fut.set_result(
                        self._call("put_when_room", traj, timeout=timeout))
                except (TransportError, RemoteError) as e2:
                    fut.set_exception(e2)
            else:
                fut.set_exception(e)
        return _ShipFuture(fut, self._c.address)

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        return self._call("wait_ready", timeout=timeout)

    def ready(self) -> bool:
        return self._get("ready")

    def throughput(self) -> dict:
        return self._get("throughput")

    def last_sample_info(self):
        return self._call("last_sample_info")

    def update_priorities(self, slots, priorities, gen=None) -> None:
        """Prioritized-replay write-back over the wire: a remote learner
        (or a priority-computing sidecar) echoes the sampled slots and
        generations back with fresh priorities; the server drops updates
        for rows the ring has overwritten since.

        One-way by design: no caller ever consumed the applied-count the
        server used to return, and the generation guard already makes a
        LOST update harmless (stale rows keep their old priority until
        resampled) — so the learner's train loop no longer pays a round
        trip per batch."""
        self._notify("update_priorities", slots, priorities, gen=gen)


# -- one-call league server ---------------------------------------------------
def serve_league(league, inf_server=None, *, extra: Optional[Dict[str, Any]] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 fault_plan: Optional[FaultPlan] = None) -> RpcServer:
    """Put a LeagueMgr (namespace `league`), its ModelPool (`pool`) and
    optionally an InfServer (`inf`, ticket ids over the wire) behind one
    started RpcServer. `extra` adds more namespaces (the multiprocess
    driver's `ctrl` plane). `fault_plan` arms the chaos harness on every
    namespace. Close the returned server to tear down."""
    objects: Dict[str, Any] = {"league": league, "pool": league.model_pool}
    if inf_server is not None:
        objects["inf"] = InfServerBackend(inf_server)
    objects.update(extra or {})
    return RpcServer(objects, host=host, port=port,
                     fault_plan=fault_plan).start()
