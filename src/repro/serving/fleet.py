"""Replica-fleet process management for the serving gateway.

Spawns standalone InfServer replica processes (`python -m
repro.launch.serve --replica`), discovers their bound addresses from the
`REPLICA host:port` line each prints on startup, and hands back handles
the smoke/chaos harnesses can `kill -9` — a gateway test against
replicas that can't really die isn't a gateway test.
"""
from __future__ import annotations

import os
import signal
import subprocess
import sys
import time
from typing import List, Optional

from repro.distributed.transport import InfServerClient, RpcClient, RetryPolicy

_BANNER = "REPLICA "


class ReplicaProc:
    """One spawned replica process + its serving address."""

    def __init__(self, proc: subprocess.Popen, address: str):
        self.proc = proc
        self.address = address

    @property
    def alive(self) -> bool:
        return self.proc.poll() is None

    def kill(self) -> None:
        """SIGKILL — the chaos path; no cleanup runs in the replica."""
        if self.alive:
            os.kill(self.proc.pid, signal.SIGKILL)
        self.proc.wait(timeout=10)

    def terminate(self) -> None:
        if self.alive:
            self.proc.terminate()
        try:
            self.proc.wait(timeout=10)
        except subprocess.TimeoutExpired:          # pragma: no cover
            self.kill()

    def __repr__(self):
        return f"ReplicaProc(pid={self.proc.pid}, address={self.address!r})"


def _src_pythonpath() -> str:
    """PYTHONPATH for a child that must import `repro` like we do."""
    here = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))               # .../src
    prev = os.environ.get("PYTHONPATH", "")
    return here + (os.pathsep + prev if prev else "")


def spawn_replica(*, arch: str = "tleague-policy-s", env_name: str = "rps",
                  seed: int = 0, max_batch: int = 256,
                  bind: str = "127.0.0.1:0",
                  startup_timeout_s: float = 60.0) -> ReplicaProc:
    """Start one standalone replica and wait for its address banner."""
    cmd = [sys.executable, "-m", "repro.launch.serve", "--replica",
           "--bind", bind, "--arch", arch, "--env", env_name,
           "--seed", str(seed), "--max-batch", str(max_batch)]
    env = dict(os.environ, PYTHONPATH=_src_pythonpath())
    proc = subprocess.Popen(cmd, stdout=subprocess.PIPE,
                            stderr=subprocess.DEVNULL, text=True, env=env)
    deadline = time.monotonic() + startup_timeout_s
    address = None
    while time.monotonic() < deadline:
        line = proc.stdout.readline()
        if not line:
            break                                  # child died
        if line.startswith(_BANNER):
            address = line[len(_BANNER):].strip()
            break
    if address is None:
        proc.kill()
        raise RuntimeError(
            f"replica failed to start within {startup_timeout_s}s "
            f"(exit={proc.poll()})")
    return ReplicaProc(proc, address)


def spawn_fleet(n: int, *, base_seed: int = 0, **kwargs) -> List[ReplicaProc]:
    """N replicas, distinct seeds (distinct serving RNG streams)."""
    return [spawn_replica(seed=base_seed + i, **kwargs) for i in range(n)]


def connect(address: str, *, retry: Optional[RetryPolicy] = None,
            timeout: Optional[float] = 30.0) -> InfServerClient:
    """An `InfServerClient` for one replica address. The default retry
    gives up fast — the GATEWAY owns failover across replicas, so a dead
    replica should surface as TransportError quickly, not after a long
    single-endpoint backoff."""
    retry = retry or RetryPolicy(base_s=0.05, cap_s=0.2, max_attempts=4,
                                 deadline_s=1.0)
    return InfServerClient(RpcClient(address, timeout=timeout, retry=retry))


def shutdown(fleet: List[ReplicaProc]) -> None:
    for r in fleet:
        try:
            r.terminate()
        except Exception:                          # pragma: no cover
            pass
