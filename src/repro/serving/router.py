"""Routing policies for the serving gateway (the dispatcher idiom).

One abstract `Router` interface, per-policy implementations — the same
shape vLLM uses for its token dispatchers: the gateway never branches on
which policy is active, it just calls `route()` against a snapshot of
per-replica load.

Routers are PURE decision functions over `ReplicaView`s: they hold only
their own counters, never replica handles, so the same router drives an
in-process fleet and an RPC fleet identically and a seeded request
sequence routes identically on every run (the determinism the serving
tests pin).

* `RoundRobinRouter` — rotate over alive replicas; the baseline.
* `LeastLoadedRouter` — min outstanding rows; pure occupancy.
* `LineageRouter` — the league-aware default. A league serves many
  concurrent policies (MALib's population-serving argument), and every
  replica hosting every lineage would blow the stacked-model group and
  the param footprint. So each model lineage (the `ModelKey.agent_id` —
  versions within a lineage share weights structure and actors) hashes
  to a home replica; requests follow the lineage unless the home's
  outstanding load exceeds `spill_factor` x the least-loaded replica's
  (plus a small absolute floor so an idle fleet never spills), at which
  point the request spills to the least-loaded replica — occupancy wins
  over affinity under pressure.
"""
from __future__ import annotations

import abc
import zlib
from typing import Hashable, List, Optional, Sequence


def lineage_of(model: Hashable) -> str:
    """The affinity key for a model route: `ModelKey.agent_id` (all
    versions of one agent land together), else the stringified route."""
    agent = getattr(model, "agent_id", None)
    if agent is not None:
        return str(agent)
    return str(model)


class ReplicaView:
    """What a router is allowed to see about one replica: index, liveness
    and load. `load` folds the gateway's own outstanding-row ledger with
    the replica-reported queue depth from the last telemetry refresh —
    the `InfServer.stats()` occupancy signal crossing the RPC seam."""
    __slots__ = ("index", "alive", "inflight_rows", "queue_depth",
                 "ewma_latency_s")

    def __init__(self, index: int, alive: bool = True,
                 inflight_rows: int = 0, queue_depth: int = 0,
                 ewma_latency_s: float = 0.0):
        self.index = index
        self.alive = alive
        self.inflight_rows = inflight_rows
        self.queue_depth = queue_depth
        self.ewma_latency_s = ewma_latency_s

    @property
    def load(self) -> int:
        return self.inflight_rows + self.queue_depth

    def __repr__(self):
        return (f"ReplicaView({self.index}, alive={self.alive}, "
                f"load={self.load})")


class Router(abc.ABC):
    """One routing decision per submit: pick the replica index for
    (`model`, `rows`) given the fleet's current load views. Implementations
    must be deterministic in their inputs and must only return the index
    of an ALIVE view; `NoReplicas` is raised for them when none is."""

    @abc.abstractmethod
    def route(self, model: Hashable, rows: int,
              replicas: Sequence[ReplicaView]) -> int:
        ...


class NoReplicas(RuntimeError):
    """Every replica in the fleet is marked dead."""


def _alive(replicas: Sequence[ReplicaView]) -> List[ReplicaView]:
    alive = [r for r in replicas if r.alive]
    if not alive:
        raise NoReplicas("no alive replicas in the fleet")
    return alive


class RoundRobinRouter(Router):
    def __init__(self):
        self._i = 0

    def route(self, model, rows, replicas) -> int:
        alive = _alive(replicas)
        pick = alive[self._i % len(alive)]
        self._i += 1
        return pick.index


class LeastLoadedRouter(Router):
    def route(self, model, rows, replicas) -> int:
        alive = _alive(replicas)
        return min(alive, key=lambda r: (r.load, r.index)).index


class LineageRouter(Router):
    """Lineage affinity with occupancy spill (see module docstring).

    `spill_factor` — spill when home.load > factor x min load;
    `spill_min_rows` — but never below this absolute home load, so a
    quiet fleet keeps perfect affinity (min load 0 would otherwise make
    any nonzero home load spill)."""

    def __init__(self, spill_factor: float = 2.0, spill_min_rows: int = 64):
        assert spill_factor >= 1.0
        self.spill_factor = spill_factor
        self.spill_min_rows = spill_min_rows
        self.spills = 0          # routed away from home by occupancy
        self.affinity_hits = 0   # routed to the lineage's home replica

    def home_index(self, model: Hashable, n_replicas: int) -> int:
        """The lineage's home slot over the FULL fleet size (stable when
        a replica dies — other lineages don't reshuffle)."""
        h = zlib.crc32(lineage_of(model).encode("utf-8"))
        return h % max(1, n_replicas)

    def route(self, model, rows, replicas) -> int:
        alive = _alive(replicas)
        by_index = {r.index: r for r in alive}
        # walk forward from the home slot to the first alive replica, so
        # a dead home only moves ITS lineages (consistent-hashing-lite)
        n = len(replicas)
        home = None
        start = self.home_index(model, n)
        for k in range(n):
            cand = by_index.get((start + k) % n)
            if cand is not None:
                home = cand
                break
        least = min(alive, key=lambda r: (r.load, r.index))
        if (home.load + rows > self.spill_min_rows
                and home.load > self.spill_factor * least.load
                and least.index != home.index):
            self.spills += 1
            return least.index
        self.affinity_hits += 1
        return home.index


ROUTERS = {
    "round_robin": RoundRobinRouter,
    "least_loaded": LeastLoadedRouter,
    "lineage": LineageRouter,
}


def make_router(name: str, **kwargs) -> Router:
    """Registry constructor: `make_router('lineage', spill_factor=1.5)`.
    Accepts a ready Router instance pass-through for callers that built
    their own."""
    if isinstance(name, Router):
        return name
    try:
        cls = ROUTERS[name]
    except KeyError:
        raise ValueError(f"unknown router {name!r}; "
                         f"have {sorted(ROUTERS)}") from None
    return cls(**kwargs)
