"""The serving-gateway plane: route a replica fleet by lineage and
occupancy (see `docs/architecture.md`, "The nine planes")."""
from repro.serving.gateway import (AdmissionRejected, DeadlineBuckets,
                                   GatewayBackend, GatewayTicket,
                                   ServingGateway)
from repro.serving.router import (LeastLoadedRouter, LineageRouter,
                                  NoReplicas, ReplicaView, RoundRobinRouter,
                                  Router, ROUTERS, lineage_of, make_router)

__all__ = [
    "AdmissionRejected", "DeadlineBuckets", "GatewayBackend", "GatewayTicket",
    "ServingGateway", "LeastLoadedRouter", "LineageRouter", "NoReplicas",
    "ReplicaView", "RoundRobinRouter", "Router", "ROUTERS", "lineage_of",
    "make_router",
]
