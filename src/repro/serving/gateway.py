"""ServingGateway: N InfServer replicas behind one routing/admission seam.

The millions-of-users story for the Model_M→Actor serving plane: the
paper deploys many inference consumers per model, and one InfServer
process — however well it batches — is a single flush lock and a single
accelerator. The gateway fronts a FLEET of replicas (in-process
`InfServer`s or remote `InfServerClient`s — both speak the same
submit/flush/get protocol, so the gateway never knows which it holds)
and adds the three things a fleet needs that a single server doesn't:

* **Routing** — a pluggable `Router` (see `repro.serving.router`) picks
  the replica per submit. The default `LineageRouter` keeps each model
  lineage on a home replica (small stacked-model groups, warm param
  routes) and spills to the least-loaded replica when the home's
  outstanding load crosses the occupancy threshold. Load is the
  gateway's own outstanding-row ledger plus the replica-reported queue
  depth from `telemetry()` — the `InfServer.stats()` signal crossing
  the RPC seam.
* **SLO-aware continuous batching** — the InfServer already batches by
  SIZE (flush at `max_batch` rows); the gateway adds DEADLINE buckets:
  each submit may carry `deadline_s`, and the pump loop flushes any
  replica holding a request whose deadline is within the replica's
  expected batch latency. Size buckets fill throughput, deadline
  buckets bound tail latency; `stats()["deadlines"]` reports per-bucket
  p50/p99 and hit rate.
* **Admission control** — outstanding rows across the fleet are capped;
  past the cap `submit` sheds the request with a typed
  `AdmissionRejected` (reason, current load, cap, suggested retry-after)
  instead of queueing unboundedly. A shed is a fast, explicit signal the
  caller can back off on — an unbounded queue is a slow timeout for
  everyone.
* **Failover** — a replica that dies mid-request (TransportError from
  its client) is marked dead, its ledger is released, and every ticket
  it held is transparently resubmitted to a surviving replica on its
  next `get` (the gateway retains each ticket's observation rows until
  resolution for exactly this).
* **Fleet rollout** — `rollout()` propagates a (frozen) league model to
  every replica with `has_model(key, tree_hash)` probes first, so
  replicas already hosting the content receive ZERO param bytes; paired
  with `rollout_from_pool` the whole fleet warms from one delta pull.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Dict, Hashable, List, Optional, Sequence, Tuple

import numpy as np

from repro.params.manifest import build_manifest
from repro.serving.router import (NoReplicas, ReplicaView, Router,
                                  make_router)

try:                                     # transport is an optional seam:
    from repro.distributed.transport import RemoteError, TransportError
except Exception:                        # pragma: no cover - bare installs
    class TransportError(ConnectionError):  # type: ignore
        pass

    class RemoteError(RuntimeError):     # type: ignore
        pass


class AdmissionRejected(RuntimeError):
    """Typed load-shed: the fleet's outstanding-row cap (or the fleet
    itself) cannot absorb this request right now. Carries enough for the
    caller to back off intelligently instead of parsing a message."""

    def __init__(self, reason: str, *, rows: int, inflight_rows: int,
                 limit: int, retry_after_s: float = 0.0):
        super().__init__(
            f"admission rejected ({reason}): {rows} rows over "
            f"{inflight_rows}/{limit} outstanding; retry in "
            f"~{retry_after_s * 1e3:.0f}ms")
        self.reason = reason
        self.rows = rows
        self.inflight_rows = inflight_rows
        self.limit = limit
        self.retry_after_s = retry_after_s


class DeadlineBuckets:
    """Deadline-bucketed latency accounting (the SLO half of continuous
    batching). Buckets are by REQUESTED deadline — `le_50ms` collects
    every request that asked for <=50ms — so the hit rate reads as 'of
    requests wanting X, how many got it'. Latencies keep a bounded
    window per bucket (enough for a stable p99, bounded forever)."""

    def __init__(self, edges_s: Sequence[float] = (0.01, 0.05, 0.25, 1.0),
                 window: int = 4096):
        self.edges_s = tuple(sorted(edges_s))
        self._lat: Dict[str, deque] = {}
        self._met: Dict[str, int] = {}
        self._count: Dict[str, int] = {}
        self._window = window
        self._lock = threading.Lock()

    def label(self, deadline_s: Optional[float]) -> str:
        if deadline_s is None:
            return "le_inf"
        for e in self.edges_s:
            if deadline_s <= e:
                return f"le_{e * 1e3:g}ms"
        return "le_inf"

    def record(self, deadline_s: Optional[float], latency_s: float) -> bool:
        met = deadline_s is None or latency_s <= deadline_s
        lab = self.label(deadline_s)
        with self._lock:
            self._count[lab] = self._count.get(lab, 0) + 1
            self._met[lab] = self._met.get(lab, 0) + int(met)
            dq = self._lat.setdefault(lab, deque(maxlen=self._window))
            dq.append(latency_s)
        return met

    def snapshot(self) -> Dict[str, dict]:
        with self._lock:
            out = {}
            for lab, n in self._count.items():
                lat = np.sort(np.asarray(self._lat[lab], dtype=np.float64))
                out[lab] = {
                    "count": n,
                    "met": self._met[lab],
                    "hit_rate": self._met[lab] / n,
                    "p50_ms": float(lat[int(0.50 * (len(lat) - 1))] * 1e3),
                    "p99_ms": float(lat[int(0.99 * (len(lat) - 1))] * 1e3),
                }
            return out


class GatewayTicket:
    """Fleet-level future: which replica holds the request, the inner
    replica ticket, and the retained observation rows (the failover
    resubmit payload). Resolve with `result()` / `gateway.get()`."""
    __slots__ = ("gid", "model", "rows", "obs", "deadline_s", "t_submit",
                 "handle", "inner", "_gateway")

    def __init__(self, gid, model, obs, deadline_s, handle, inner, gateway):
        self.gid = gid
        self.model = model
        self.obs = obs
        self.rows = obs.shape[0]
        self.deadline_s = deadline_s
        self.t_submit = time.perf_counter()
        self.handle = handle
        self.inner = inner
        self._gateway = gateway

    def result(self) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        return self._gateway.get(self)

    def __repr__(self):
        return (f"GatewayTicket({self.gid}, model={self.model!r}, "
                f"rows={self.rows}, replica={self.handle.index})")


class _Handle:
    """Gateway-side ledger for one replica: liveness, outstanding rows,
    which routes the gateway installed, last-seen telemetry, and the
    deadlines pending since the last flush (what the pump reads)."""
    __slots__ = ("index", "replica", "alive", "inflight_rows", "hosted",
                 "outstanding", "pending_deadlines", "queue_depth",
                 "ewma_latency_s", "routed_rows", "routed_requests")

    def __init__(self, index: int, replica):
        self.index = index
        self.replica = replica
        self.alive = True
        self.inflight_rows = 0
        self.hosted: set = set()
        self.outstanding: Dict[int, int] = {}        # gid -> rows
        self.pending_deadlines: Dict[int, float] = {}  # gid -> abs deadline
        self.queue_depth = 0
        self.ewma_latency_s = 0.0
        self.routed_rows = 0
        self.routed_requests = 0

    def view(self) -> ReplicaView:
        return ReplicaView(self.index, alive=self.alive,
                           inflight_rows=self.inflight_rows,
                           queue_depth=self.queue_depth,
                           ewma_latency_s=self.ewma_latency_s)


class ServingGateway:
    """Front a fleet of InfServer-protocol replicas. See module docstring.

    `replicas` — in-process `InfServer`s, `InfServerClient`s, or a mix.
    `router` — a name from `repro.serving.router.ROUTERS`, or an
    instance. `max_inflight_rows` — the fleet-wide admission cap.
    `deadline_edges_s` — the SLO bucket boundaries. `failover_retries` —
    how many replica deaths one request survives. `pump_interval_s` —
    cadence of the deadline pump thread once `start()`ed (telemetry
    refreshes ride the same thread every `telemetry_every` ticks)."""

    def __init__(self, replicas: Sequence[Any], *, router="lineage",
                 max_inflight_rows: int = 4096,
                 deadline_edges_s: Sequence[float] = (0.01, 0.05, 0.25, 1.0),
                 deadline_safety: float = 2.0,
                 failover_retries: int = 2,
                 pump_interval_s: float = 0.002,
                 telemetry_every: int = 25):
        assert replicas, "gateway needs at least one replica"
        self._handles = [_Handle(i, r) for i, r in enumerate(replicas)]
        self._router = make_router(router)
        self.max_inflight_rows = max_inflight_rows
        self.deadlines = DeadlineBuckets(deadline_edges_s)
        self.deadline_safety = deadline_safety
        self.failover_retries = failover_retries
        self.pump_interval_s = pump_interval_s
        self.telemetry_every = telemetry_every
        self._lock = threading.Lock()
        self._inflight_total = 0
        self._next_gid = 0
        # params the gateway can (re)install on a replica: rollout /
        # register_model keep the latest copy per route so spill targets
        # and failover targets warm lazily, hash-gated
        self._sources: Dict[Hashable, Tuple[Any, Optional[str],
                                            Optional[int]]] = {}
        # counters
        self.shed_requests = 0
        self.shed_rows = 0
        self.failovers = 0
        self.replicas_died = 0
        self.rollout_bytes_shipped = 0
        self.rollout_noops = 0
        self.requests = 0
        self.rows = 0
        self._pump_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- routing + admission -------------------------------------------------
    def submit(self, obs, model: Hashable = None,
               deadline_s: Optional[float] = None) -> GatewayTicket:
        """Route one observation batch into the fleet. Raises
        `AdmissionRejected` instead of queueing past the fleet cap."""
        obs = np.asarray(obs)
        rows = obs.shape[0]
        deadline_abs = (None if deadline_s is None
                        else time.perf_counter() + deadline_s)
        with self._lock:
            if self._inflight_total + rows > self.max_inflight_rows:
                self.shed_requests += 1
                self.shed_rows += rows
                retry = max((h.ewma_latency_s for h in self._handles
                             if h.alive), default=0.0) or 0.01
                raise AdmissionRejected(
                    "overload", rows=rows,
                    inflight_rows=self._inflight_total,
                    limit=self.max_inflight_rows, retry_after_s=retry)
            try:
                idx = self._router.route(
                    model, rows, [h.view() for h in self._handles])
            except NoReplicas:
                self.shed_requests += 1
                self.shed_rows += rows
                raise AdmissionRejected(
                    "no_replicas", rows=rows,
                    inflight_rows=self._inflight_total,
                    limit=self.max_inflight_rows) from None
            h = self._handles[idx]
            gid = self._next_gid
            self._next_gid += 1
            self._acquire(h, gid, rows, deadline_abs)
        h, inner = self._submit_on(h, gid, obs, model, deadline_abs)
        gt = GatewayTicket(gid, model, obs, deadline_s, h, inner, self)
        with self._lock:
            self.requests += 1
            self.rows += rows
        return gt

    def _acquire(self, h: _Handle, gid: int, rows: int,
                 deadline_abs: Optional[float]) -> None:
        """Ledger a routed request onto `h` (gateway lock held)."""
        h.inflight_rows += rows
        h.outstanding[gid] = rows
        h.routed_rows += rows
        h.routed_requests += 1
        if deadline_abs is not None:
            h.pending_deadlines[gid] = deadline_abs
        self._inflight_total += rows

    def _release(self, gid: int, h: _Handle) -> bool:
        """Un-ledger; idempotent (False when already released — e.g. the
        handle died and its ledger was swept)."""
        with self._lock:
            rows = h.outstanding.pop(gid, None)
            h.pending_deadlines.pop(gid, None)
            if rows is None:
                return False
            h.inflight_rows -= rows
            self._inflight_total -= rows
            return True

    def _submit_on(self, h: _Handle, gid: int, obs, model,
                   deadline_abs: Optional[float]) -> Tuple[_Handle, Any]:
        """The replica call, OUTSIDE the gateway lock (it may block for a
        replica flush). A transport death here fails over immediately.
        Returns (handle, inner ticket) for the replica the submit
        actually LANDED on — every failover hop releases the previous
        handle's ledger and re-acquires (deadline intact) on the next, so
        the caller's ticket always points at the replica holding the
        rows."""
        while True:
            try:
                if model is not None:
                    self._ensure_route(h, model)
                return h, h.replica.submit(obs, model=model)
            except (TransportError, OSError):
                self._mark_dead(h)
                self._release(gid, h)
                with self._lock:
                    try:
                        idx = self._router.route(
                            model, obs.shape[0],
                            [x.view() for x in self._handles])
                    except NoReplicas:
                        raise AdmissionRejected(
                            "no_replicas", rows=obs.shape[0],
                            inflight_rows=self._inflight_total,
                            limit=self.max_inflight_rows) from None
                    h = self._handles[idx]
                    self._acquire(h, gid, obs.shape[0], deadline_abs)
                self.failovers += 1

    def _ensure_route(self, h: _Handle, model: Hashable) -> None:
        """Install `model` on `h` if the gateway knows its params and has
        not installed it there yet (hash-gated on the replica side, so a
        replica that already hosts the content ships zero bytes)."""
        if model in h.hosted:
            return
        src = self._sources.get(model)
        if src is None:
            # the replica may host it natively (e.g. its default route);
            # let the submit itself be the probe
            return
        params, content_hash, version = src
        h.replica.register_model(model, params, content_hash=content_hash,
                                 version=version)
        h.hosted.add(model)

    # -- resolution + failover -----------------------------------------------
    def get(self, gt: GatewayTicket) -> Tuple[np.ndarray, np.ndarray,
                                              np.ndarray]:
        """Resolve a gateway ticket. Survives up to `failover_retries`
        replica deaths by resubmitting the retained observations to a
        surviving replica. Records the deadline outcome."""
        deaths = 0
        while True:
            h = gt.handle
            try:
                a, logp, v = h.replica.get(gt.inner)
                break
            except (TransportError, OSError) as e:
                self._mark_dead(h)
                deaths += 1
                if deaths > self.failover_retries:
                    self._release(gt.gid, h)
                    raise
                self._failover(gt)
            except RemoteError:
                # the replica is alive but no longer holds the ticket
                # (restarted, or expired it) — resubmit, same budget.
                # On exhaustion the ledger must be released HERE: the
                # replica stays alive, so no _mark_dead sweep will ever
                # reclaim this gid's rows or its pending deadline.
                deaths += 1
                if deaths > self.failover_retries:
                    self._release(gt.gid, h)
                    raise
                self._failover(gt)
        self._release(gt.gid, h)
        latency = time.perf_counter() - gt.t_submit
        self.deadlines.record(gt.deadline_s, latency)
        w = 0.2                       # ewma of observed request latency:
        with self._lock:              # the pump's flush-margin estimate
            h.ewma_latency_s = ((1 - w) * h.ewma_latency_s + w * latency
                                if h.ewma_latency_s else latency)
        return np.asarray(a), np.asarray(logp), np.asarray(v)

    def _failover(self, gt: GatewayTicket) -> None:
        """Re-route a ticket whose replica died: re-ledger on a survivor
        and resubmit the retained obs rows."""
        self._release(gt.gid, gt.handle)
        with self._lock:
            try:
                idx = self._router.route(
                    gt.model, gt.rows, [h.view() for h in self._handles])
            except NoReplicas:
                raise AdmissionRejected(
                    "no_replicas", rows=gt.rows,
                    inflight_rows=self._inflight_total,
                    limit=self.max_inflight_rows) from None
            h2 = self._handles[idx]
            deadline_abs = (None if gt.deadline_s is None
                            else gt.t_submit + gt.deadline_s)
            self._acquire(h2, gt.gid, gt.rows, deadline_abs)
        self.failovers += 1
        gt.handle, gt.inner = self._submit_on(
            h2, gt.gid, gt.obs, gt.model, deadline_abs)

    def _mark_dead(self, h: _Handle) -> None:
        with self._lock:
            if not h.alive:
                return
            h.alive = False
            self.replicas_died += 1
            # sweep its ledger: every ticket it held will re-ledger on a
            # survivor at its own failover
            for gid, rows in list(h.outstanding.items()):
                h.inflight_rows -= rows
                self._inflight_total -= rows
            h.outstanding.clear()
            h.pending_deadlines.clear()

    def mark_dead(self, index: int) -> None:
        """Operator/escape hatch: take a replica out of rotation."""
        self._mark_dead(self._handles[index])

    # -- SLO pump + telemetry ------------------------------------------------
    def pump(self, now: Optional[float] = None) -> int:
        """One deadline pass: flush every alive replica holding a pending
        request whose deadline is within `deadline_safety` x the
        replica's expected latency (+ one pump interval of slack). The
        InfServer's own size trigger (`max_batch` rows) stays primary —
        this is the tail-latency bound for half-full batches. Returns
        how many replicas were flushed."""
        now = time.perf_counter() if now is None else now
        to_flush: List[_Handle] = []
        with self._lock:
            for h in self._handles:
                if not (h.alive and h.pending_deadlines):
                    continue
                margin = (self.deadline_safety * h.ewma_latency_s
                          + self.pump_interval_s)
                if min(h.pending_deadlines.values()) <= now + margin:
                    h.pending_deadlines.clear()
                    to_flush.append(h)
        self._flush_fanout(to_flush)
        return len(to_flush)

    def flush(self) -> None:
        """Flush the whole fleet concurrently (and clear the deadline
        ledger): remote replicas take a pipelined `flush_async`, so one
        slow replica no longer serializes the rest."""
        with self._lock:
            handles = [h for h in self._handles if h.alive]
            for h in handles:
                h.pending_deadlines.clear()
        self._flush_fanout(handles)

    def _flush_fanout(self, handles) -> None:
        """Submit every flush before awaiting any ack; in-process
        replicas (no `flush_async`) flush inline."""
        futs = []
        for h in handles:
            fa = getattr(h.replica, "flush_async", None)
            try:
                if fa is None:
                    h.replica.flush()
                else:
                    futs.append((h, fa()))
            except (TransportError, OSError):
                self._mark_dead(h)
        for h, fut in futs:
            try:
                fut.result()
            except (TransportError, OSError):
                self._mark_dead(h)
            except RemoteError:
                pass                   # replica alive; flush itself failed

    def refresh_telemetry(self, probe_timeout_s: float = 0.25) -> None:
        """Pull each replica's occupancy/latency probe into the router's
        view of the fleet — `InfServer.telemetry()` in-process, a
        pipelined `telemetry_async` fan-out over RPC. All probes go out
        before any reply is awaited, under ONE shared deadline: a replica
        that cannot answer within `probe_timeout_s` just keeps its stale
        view (NOT marked dead — a late reply resolves harmlessly in the
        reader; liveness is the failover path's call), so one stalled
        replica can no longer freeze the router's occupancy view or the
        pump thread's deadline math. A replica whose transport is
        actually gone IS marked dead."""
        probes = []
        for h in self._handles:
            if not h.alive:
                continue
            probe = getattr(h.replica, "telemetry_async", None)
            if probe is None:          # in-process replica: local + cheap
                try:
                    self._fold_telemetry(h, h.replica.telemetry())
                except (TransportError, OSError):
                    self._mark_dead(h)
                continue
            try:
                probes.append((h, probe()))
            except (TransportError, OSError):
                self._mark_dead(h)
        deadline = time.perf_counter() + probe_timeout_s
        for h, fut in probes:
            try:
                t = fut.result(max(0.0, deadline - time.perf_counter()))
            except TimeoutError:
                continue               # stale this round, not dead
            except (TransportError, OSError):
                self._mark_dead(h)
                continue
            except RemoteError:
                continue               # replica alive; probe itself failed
            self._fold_telemetry(h, t)

    def _fold_telemetry(self, h: "_Handle", t: dict) -> None:
        with self._lock:
            h.queue_depth = int(t.get("queue_depth", 0))
            lat = t.get("mean_batch_latency_ms")
            if lat:
                h.ewma_latency_s = max(h.ewma_latency_s, lat / 1e3)

    def start(self) -> "ServingGateway":
        """Run the deadline pump (+ periodic telemetry refresh) in a
        daemon thread until `stop()`/`close()`."""
        if self._pump_thread is not None:
            return self
        self._stop.clear()

        def loop():
            tick = 0
            while not self._stop.wait(self.pump_interval_s):
                self.pump()
                tick += 1
                if tick % self.telemetry_every == 0:
                    self.refresh_telemetry()

        self._pump_thread = threading.Thread(
            target=loop, name="gateway-pump", daemon=True)
        self._pump_thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()
        if self._pump_thread is not None:
            self._pump_thread.join(timeout=5.0)
            self._pump_thread = None

    close = stop

    # -- fleet param plane ---------------------------------------------------
    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        """Broadcast a route to every alive replica (replica-side
        hash-gated: identical refreshes no-op) and retain the copy as the
        install source for spill/failover targets."""
        with self._lock:
            self._sources[key] = (params, content_hash, version)
            handles = [h for h in self._handles if h.alive]
        for h in handles:
            h.replica.register_model(key, params, content_hash=content_hash,
                                     version=version)
            h.hosted.add(key)

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        self.register_model(key, params, content_hash=content_hash,
                            version=version)

    def rollout(self, key: Hashable, params, manifest=None) -> dict:
        """Propagate a (frozen) model to the whole fleet, `has_model`
        probes first: a replica already hosting `manifest.tree_hash`
        receives ZERO param bytes (one tiny probe round trip). Returns
        the propagation report the bench records — per-replica shipped
        flag/bytes/latency and the fleet totals."""
        if manifest is None:
            manifest = build_manifest(params, version=0)
        t0 = time.perf_counter()
        per: List[dict] = []
        bytes_shipped = 0
        with self._lock:
            handles = [h for h in self._handles if h.alive]
        # probe the whole fleet concurrently (pipelined has_model_async
        # on RPC replicas), then ship params only where the probe said
        # the content is missing — the warm-fleet rollout pays N
        # overlapped probe round trips instead of N serial ones
        t1s: Dict[int, float] = {}
        hosted: Dict[int, bool] = {}
        probes = []
        for h in handles:
            t1s[h.index] = time.perf_counter()
            probe = getattr(h.replica, "has_model_async", None)
            if probe is None:          # in-process replica
                hosted[h.index] = bool(
                    h.replica.has_model(key, manifest.tree_hash))
                continue
            try:
                probes.append((h, probe(key, manifest.tree_hash)))
            except (TransportError, OSError):
                self._mark_dead(h)
        for h, fut in probes:
            try:
                hosted[h.index] = bool(fut.result())
            except (TransportError, OSError, RemoteError):
                self._mark_dead(h)
        for h in handles:
            if h.index not in hosted:
                continue               # died during the probe pass
            if hosted[h.index]:
                shipped = False
                self.rollout_noops += 1
            else:
                h.replica.register_model(
                    key, params, content_hash=manifest.tree_hash,
                    version=manifest.version)
                shipped = True
                bytes_shipped += manifest.nbytes
            h.hosted.add(key)
            per.append({"replica": h.index, "shipped": shipped,
                        "bytes": manifest.nbytes if shipped else 0,
                        "ms": (time.perf_counter() - t1s[h.index]) * 1e3})
        with self._lock:
            self._sources[key] = (params, manifest.tree_hash,
                                  manifest.version)
            self.rollout_bytes_shipped += bytes_shipped
        return {"key": str(key), "tree_hash": manifest.tree_hash,
                "version": manifest.version, "replicas": per,
                "bytes_shipped": bytes_shipped,
                "shipped_to": sum(p["shipped"] for p in per),
                "already_hosted": sum(not p["shipped"] for p in per),
                "propagation_ms": (time.perf_counter() - t0) * 1e3}

    def rollout_from_pool(self, pool, key: Hashable) -> dict:
        """Warm the fleet from a ModelPool: ONE (delta-cached) pull from
        the pool, then the probe-gated fleet rollout — the frozen-model
        propagation path."""
        manifest = pool.manifest(key)
        params = pool.pull(key)
        return self.rollout(key, params, manifest=manifest)

    # -- introspection -------------------------------------------------------
    @property
    def inflight_rows(self) -> int:
        return self._inflight_total

    @property
    def alive_replicas(self) -> int:
        return sum(h.alive for h in self._handles)

    def stats(self) -> dict:
        with self._lock:
            per = [{"replica": h.index, "alive": h.alive,
                    "inflight_rows": h.inflight_rows,
                    "routed_rows": h.routed_rows,
                    "routed_requests": h.routed_requests,
                    "queue_depth": h.queue_depth,
                    "ewma_latency_ms": h.ewma_latency_s * 1e3,
                    "hosted": len(h.hosted)}
                   for h in self._handles]
            out = {
                "replicas": per,
                "alive_replicas": sum(h.alive for h in self._handles),
                "requests": self.requests,
                "rows": self.rows,
                "inflight_rows": self._inflight_total,
                "max_inflight_rows": self.max_inflight_rows,
                "shed_requests": self.shed_requests,
                "shed_rows": self.shed_rows,
                "failovers": self.failovers,
                "replicas_died": self.replicas_died,
                "rollout_bytes_shipped": self.rollout_bytes_shipped,
                "rollout_noops": self.rollout_noops,
                "router": type(self._router).__name__,
            }
        for attr in ("spills", "affinity_hits"):
            val = getattr(self._router, attr, None)
            if val is not None:
                out[f"router_{attr}"] = val
        out["deadlines"] = self.deadlines.snapshot()
        return out

    def telemetry(self) -> dict:
        """Fleet-level analogue of `InfServer.telemetry()`: what a
        front-of-gateway poller (an HPA metric exporter, a higher tier
        of routing) reads cheaply."""
        with self._lock:
            return {
                "queue_depth": self._inflight_total,
                "alive_replicas": sum(h.alive for h in self._handles),
                "mean_batch_latency_ms": 1e3 * max(
                    (h.ewma_latency_s for h in self._handles if h.alive),
                    default=0.0),
                "shed_requests": self.shed_requests,
            }


class GatewayBackend:
    """RPC adapter: put a `ServingGateway` behind an `RpcServer` under the
    `inf` namespace and every existing `InfServerClient` (and therefore
    every served Actor) talks to the FLEET without knowing it — the same
    trick `InfServerBackend` plays for one server, one level up. Tickets
    cross the wire as integers; the retained `GatewayTicket`s (and their
    failover obs payloads) stay here. Outstanding tickets are bounded
    exactly like `InfServerBackend`'s."""

    def __init__(self, gateway: ServingGateway, max_outstanding: int = 4096):
        self._gw = gateway
        self._max_outstanding = max_outstanding
        self._tickets: Dict[int, GatewayTicket] = {}   # insertion-ordered
        self._lock = threading.Lock()

    def submit(self, obs, model: Hashable = None,
               deadline_s: Optional[float] = None) -> int:
        gt = self._gw.submit(np.asarray(obs), model=model,
                             deadline_s=deadline_s)
        with self._lock:
            self._tickets[gt.gid] = gt
            while len(self._tickets) > self._max_outstanding:
                stale = next(iter(self._tickets))
                dead = self._tickets.pop(stale)
                self._gw._release(dead.gid, dead.handle)
        return gt.gid

    def poll(self, gid: int) -> bool:
        with self._lock:
            gt = self._tickets.get(gid)
        if gt is None:
            return False
        done = getattr(gt.inner, "done", None)
        return bool(done()) if callable(done) else False

    def get(self, gid: int):
        with self._lock:
            gt = self._tickets.pop(gid)
        a, logp, v = self._gw.get(gt)
        return np.asarray(a), np.asarray(logp), np.asarray(v)

    def flush(self) -> None:
        self._gw.flush()

    def update_params(self, params, key: Hashable = None,
                      content_hash: Optional[str] = None,
                      version: Optional[int] = None) -> None:
        self._gw.update_params(params, key=key, content_hash=content_hash,
                               version=version)

    def ensure_model(self, key: Hashable, params,
                     content_hash: Optional[str] = None) -> None:
        # fleet semantics: idempotent == hash-gated broadcast
        self._gw.register_model(key, params, content_hash=content_hash)

    def register_model(self, key: Hashable, params,
                       content_hash: Optional[str] = None,
                       version: Optional[int] = None) -> None:
        self._gw.register_model(key, params, content_hash=content_hash,
                                version=version)

    def has_model(self, key: Hashable,
                  content_hash: Optional[str] = None) -> bool:
        with self._gw._lock:
            src = self._gw._sources.get(key)
            handles = [h for h in self._gw._handles if h.alive]
        if src is not None and (content_hash is None
                                or src[1] == content_hash):
            return True
        return any(h.replica.has_model(key, content_hash) for h in handles)

    def stats(self) -> dict:
        return self._gw.stats()

    def telemetry(self) -> dict:
        return self._gw.telemetry()
