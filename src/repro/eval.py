"""Evaluation harness: pit policies (learned or scripted) against each other
in any bundled env — used by the paper-table benchmarks (Tables 1-2 FRAG
ranking, Fig. 4 win-rate curves)."""
from __future__ import annotations

from typing import Callable, Dict, Sequence

import jax
import numpy as np

from repro.actors.policy import make_obs_policy
from repro.envs.base import MultiAgentEnv


def learned_policy_fn(cfg, num_actions, params, seed=0):
    policy = make_obs_policy(cfg, num_actions)
    act = jax.jit(policy.act)
    rng_holder = {"rng": jax.random.PRNGKey(seed)}

    def fn(obs, np_rng):
        rng_holder["rng"], k = jax.random.split(rng_holder["rng"])
        a, _, _ = act(params, k, jax.numpy.asarray(obs))
        return np.asarray(a)

    return fn


def play_episodes(env: MultiAgentEnv, slot_policies: Sequence[Callable],
                  episodes: int = 10, seed: int = 0) -> Dict:
    """slot_policies[i](obs (1,L), np_rng) -> (1,) action for agent slot i.
    Returns outcomes, per-slot reward sums, and env-specific info (frags)."""
    assert len(slot_policies) == env.spec.num_agents
    rng = jax.random.PRNGKey(seed)
    np_rng = np.random.default_rng(seed)
    step = jax.jit(env.step)
    reset = jax.jit(env.reset)
    outcomes, reward_sums, frags = [], [], []
    for ep in range(episodes):
        rng, k = jax.random.split(rng)
        state, obs = reset(k)
        done = False
        rsum = np.zeros(env.spec.num_agents)
        info = {}
        t = 0
        while not done and t < env.spec.max_steps + 1:
            obs_np = np.asarray(obs)
            acts = np.concatenate([
                slot_policies[i](obs_np[i:i + 1], np_rng)
                for i in range(env.spec.num_agents)])
            rng, k = jax.random.split(rng)
            state, obs, rew, done_, info = step(state, jax.numpy.asarray(acts), k)
            rsum += np.asarray(rew)
            done = bool(done_)
            t += 1
        outcomes.append(int(info.get("outcome", 0)))
        reward_sums.append(rsum)
        if "frags" in info:
            frags.append(np.asarray(info["frags"]))
    out = {"outcomes": np.array(outcomes),
           "reward_sums": np.stack(reward_sums)}
    if frags:
        out["frags"] = np.stack(frags)
    return out


def winrate_vs(outcomes: np.ndarray) -> float:
    """Ties half-counted, as the paper's Fig. 4 does."""
    wins = (outcomes > 0).sum() + 0.5 * (outcomes == 0).sum()
    return float(wins / len(outcomes))
