from repro.learners.replay import DataServer
from repro.learners.samplers import (SAMPLERS, Sampler, SegmentTree,
                                     UniformSampler, PrioritizedSampler,
                                     EpisodeSampler, make_sampler)
from repro.learners.steps import build_env_train_step, build_seq_train_step, build_mlm_train_step
from repro.learners.learner import Learner
