"""Learner: the data-consuming module (§3.2).

Owns the train step, an embedded DataServer, and the league protocol:
requests its task at each learning-period beginning (rank-0 semantics),
periodically pushes theta to the ModelPool so Actors stay fresh, and at
learning-period end freezes theta into the opponent pool via LeagueMgr.
The M_L-way synchronous gradient sync lives inside the (p)jit'd train step.
"""
from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import LeagueMgr
from repro.kernels import dispatch
from repro.learners.replay import DataServer
from repro.params import CachedPuller


def _snapshot(params):
    """Deep-copy a param pytree before handing it to the ModelPool: the
    train step donates its param buffers (donate_argnums), so sharing the
    live object with the pool would leave Actors pulling deleted buffers."""
    return jax.tree.map(lambda x: jnp.array(x, copy=True), params)


class Learner:
    def __init__(self, league: LeagueMgr, train_step: Callable, optimizer,
                 init_params, *, agent_id: str = "main",
                 publish_every: int = 1, data_server: Optional[DataServer] = None,
                 device_feed: bool = True,
                 priority_fn: Optional[Callable] = None):
        """`device_feed` routes minibatches through the DataServer's
        double-buffered `sample_to_device` path (host->device copies overlap
        the train step); falls back to host `sample` for data servers
        without that path.

        `priority_fn(traj, metrics) -> per-row priorities` closes the
        prioritized-replay loop: after each train step it is called with
        the consumed minibatch and the step metrics, and its result is
        written back through `data_server.update_priorities` against the
        slots/generations the server recorded for that batch (stale rows
        — overwritten since the sample — are dropped server-side). Don't
        combine with a batch-donating train step: the traj buffers must
        outlive the step."""
        self.league = league
        self.agent_id = agent_id
        self.train_step = train_step
        self.optimizer = optimizer
        self.device_feed = device_feed
        # private working copy: the caller's init_params object is typically
        # also the ModelPool's seed entry, and train_step donates its inputs
        self.params = _snapshot(init_params)
        self.opt_state = optimizer.init(self.params)
        # version-cached pool pulls for the post-freeze adopt: an
        # exploiter reset or PBT exploit ships only the changed leaves
        # (and a remote pool sends zero param bytes when nothing changed).
        # copy=False: the cache may alias the pool's live entry — safe
        # because pool entries are replaced, never mutated, and the adopt
        # below snapshots before the donating train step ever sees them —
        # so adopting costs exactly ONE deep copy, as before
        self._puller = CachedPuller(league.model_pool, copy=False)
        self.data_server = data_server or DataServer()
        self.priority_fn = priority_fn
        self.publish_every = publish_every
        self.step_count = 0
        self.task = league.request_learner_task(agent_id)

    @property
    def current_key(self):
        return self.league.agents[self.agent_id].current

    def learn(self, num_steps: int = 1):
        """Consume `num_steps` minibatches from the DataServer."""
        last_metrics = {}
        for _ in range(num_steps):
            if not self.data_server.ready():
                break
            if self.device_feed and hasattr(self.data_server, "sample_to_device"):
                traj = self.data_server.sample_to_device()
            else:
                traj = self.data_server.sample()
            if self.priority_fn is None:
                self.params, self.opt_state, last_metrics = self.train_step(
                    self.params, self.opt_state, traj)
            else:
                info = self.data_server.last_sample_info() \
                    if hasattr(self.data_server, "last_sample_info") else None
                self.params, self.opt_state, last_metrics = self.train_step(
                    self.params, self.opt_state, traj)
                if info is not None and info.get("slots") is not None:
                    self.data_server.update_priorities(
                        info["slots"], self.priority_fn(traj, last_metrics),
                        gen=info.get("gen"))
            self.step_count += 1
            if self.step_count % self.publish_every == 0:
                self.league.model_pool.push(self.current_key,
                                            _snapshot(self.params),
                                            step=self.step_count)
        return last_metrics

    def stats(self) -> dict:
        """Learner-side telemetry: step progress, the DataServer's feed
        rates, and which kernel tier the train step actually traced to
        (dispatch counts are trace-time — an 'attention|reference|...'
        key here means the escape hatch or a misroute is live)."""
        out = {"step_count": self.step_count}
        if hasattr(self.data_server, "throughput"):
            out["data_server"] = self.data_server.throughput()
        out["dispatch"] = dispatch.stats()
        return out

    def end_learning_period(self, reason: str = "period"):
        """Freeze theta into M, adopt theta_{v+1} (paper lifecycle).

        theta_{v+1} is re-pulled from the ModelPool rather than assumed to
        equal our live params: the LeagueMgr may have reset it to the seed
        (exploiter reset-on-freeze) or PBT-exploited the leader's weights —
        either way the pool entry is authoritative. The pull rides the
        param plane (`pull_if_changed` under a `CachedPuller`: changed
        leaves only on a warm cache) and is then snapshotted, so our
        (donating) train step never shares buffers with the pool OR the
        puller's cache."""
        old_key = self.current_key
        new_key = self.league.end_learning_period(
            self.agent_id, _snapshot(self.params), reason=reason)
        self.params = _snapshot(self._puller.get(new_key))
        if old_key != new_key:
            self._puller.drop(old_key)       # one lineage key cached, ever
        self.opt_state = self.optimizer.init(self.params)   # fresh moments
        self.task = self.league.request_learner_task(self.agent_id)
        return new_key
