"""Train-step factories — the Learner's compute (§3.2).

Three flavors covering every assigned arch x task:
  env_train_step — PPO/V-trace over env trajectory segments with the
                   memoryless obs-token policy (the real league training).
  seq_train_step — PPO/V-trace over full token sequences (AlphaStar-style
                   autoregressive action head). This is what `train_4k`
                   lowers at scale: the learner consumes (B, S) trajectories.
  mlm_train_step — masked-unit prediction for the encoder-only audio arch
                   (hubert), its `train_4k` objective.

Each returns f(params, opt_state, batch) -> (params, opt_state, metrics);
under pjit the gradient psum over the mesh data/pod axes is the paper's
Horovod allreduce (DESIGN.md §2).
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from repro.actors.policy import make_obs_policy
from repro.models import forward_train
from repro.rl.ppo import PPOConfig, ppo_loss
from repro.rl.vtrace_loss import VTraceConfig, vtrace_loss


def _loss_for(kind):
    return {"ppo": (ppo_loss, PPOConfig), "vtrace": (vtrace_loss, VTraceConfig)}[kind]


def _jit(train_step, jit: bool, donate_batch: bool):
    """donate_argnums always covers (params, opt_state); `donate_batch`
    additionally donates the trajectory argument — safe when batches arrive
    as fresh device buffers (DataServer.sample_to_device), and it lets XLA
    reuse the batch's device memory for activations."""
    if not jit:
        return train_step
    donate = (0, 1, 2) if donate_batch else (0, 1)
    return jax.jit(train_step, donate_argnums=donate)


def build_env_train_step(cfg, num_actions: int, optimizer, hp=None,
                         loss: str = "ppo", jit: bool = True,
                         donate_batch: bool = False):
    loss_fn_impl, hp_cls = _loss_for(loss)
    hp = hp or hp_cls()
    policy = make_obs_policy(cfg, num_actions)

    def train_step(params, opt_state, traj):
        B, T, L0 = traj["obs"].shape
        discounts = hp.gamma * (1.0 - traj["done"].astype(jnp.float32))
        tfields = {
            "actions": traj["actions"],
            "behavior_logp": traj["behavior_logp"],
            "behavior_values": traj["behavior_values"],
            "rewards": traj["rewards"],
            "discounts": discounts,
            "bootstrap_value": traj["bootstrap_value"],
        }

        def loss_fn(p):
            lg, v = policy.logits_values(p, traj["obs"].reshape(B * T, L0))
            logits = lg.reshape(B, T, num_actions)
            values = v.reshape(B, T)
            return loss_fn_impl(logits, values, tfields, hp)

        (lv, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        metrics = {**metrics, **om, "loss": lv}
        return params, opt_state, metrics

    return _jit(train_step, jit, donate_batch)


def build_seq_train_step(cfg, optimizer, hp=None, loss: str = "ppo",
                         q_chunk: int = 512, remat: bool = True,
                         unroll: bool = False, jit: bool = False,
                         donate_batch: bool = False):
    """Sequence-model PPO/V-trace: actions are tokens; logits from the LM
    head over the whole unroll. The big-arch learner step (`train_4k`)."""
    loss_fn_impl, hp_cls = _loss_for(loss)
    hp = hp or hp_cls()

    def train_step(params, opt_state, batch):
        tfields = {
            "actions": batch["actions"],
            "behavior_logp": batch["behavior_logp"],
            "behavior_values": batch["behavior_values"],
            "rewards": batch["rewards"],
            "discounts": batch["discounts"],
            "bootstrap_value": batch["bootstrap_value"],
        }
        inputs = {k: batch[k] for k in ("tokens", "patch_embeds", "frame_embeds")
                  if k in batch}

        def loss_fn(p):
            logits, values, aux = forward_train(p, cfg, inputs, q_chunk=q_chunk,
                                                remat=remat, unroll=unroll)
            # modality prefixes (vlm patches) are observation-only: the RL
            # fields are aligned to the *last* S_act positions.
            S_act = tfields["actions"].shape[1]
            logits = logits[:, -S_act:]
            values = values[:, -S_act:]
            lv, metrics = loss_fn_impl(logits, values, tfields, hp)
            return lv + aux, metrics

        (lv, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **om, "loss": lv}

    return _jit(train_step, jit, donate_batch)


def build_mlm_train_step(cfg, optimizer, remat: bool = True, unroll: bool = False,
                         jit: bool = False, donate_batch: bool = False):
    """HuBERT-style masked-unit prediction (encoder-only audio)."""
    assert cfg.encoder_only

    def train_step(params, opt_state, batch):
        frames, units, mask = batch["frame_embeds"], batch["units"], batch["mask"]

        def loss_fn(p):
            x = jnp.where(mask[..., None], 0.0, frames)   # mask-out input frames
            logits, _, _ = forward_train(p, cfg, {"frame_embeds": x, "tokens": None},
                                         remat=remat, unroll=unroll)
            logp = jax.nn.log_softmax(logits, axis=-1)
            nll = -jnp.take_along_axis(logp, units[..., None], axis=-1)[..., 0]
            m = mask.astype(jnp.float32)
            loss = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
            acc = jnp.sum((jnp.argmax(logits, -1) == units) * m) / jnp.maximum(jnp.sum(m), 1.0)
            return loss, {"masked_acc": acc}

        (lv, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        params, opt_state, om = optimizer.update(grads, opt_state, params)
        return params, opt_state, {**metrics, **om, "loss": lv}

    return _jit(train_step, jit, donate_batch)
