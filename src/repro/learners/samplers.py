"""Pluggable replay sampling: the strategy seam under `DataServer`.

`DataServer._sample_idx` used to hard-code one policy (newest segment in
blocking mode, uniform otherwise). The uniform branch is now a
`Sampler` object the server delegates to, with two more strategies for
off-policy / value-based workloads:

* **UniformSampler** — the default; draws from the server's own
  `np.random.Generator` with the exact pre-refactor call sequence
  (``rng.integers(size, size=k)`` then the head-relative ring mapping),
  so the slot stream is bit-identical to the old `DataServer` and the
  `--sync` oracle stays deterministic.
* **PrioritizedSampler** — proportional prioritized replay on a
  vectorized array segment tree. Semantics are pinned to tianshou's
  `PrioritizedReplayBuffer` (the reference this repo's tests encode):
  new rows enter at ``max_priority ** alpha``; sampling draws
  ``rng.random(k) * tree_total`` prefix-sum lookups; importance weights
  are ``(tree_weight / min_priority) ** (-beta)``; consumer updates set
  ``(|p| + eps) ** alpha`` and widen the max/min trackers.
* **EpisodeSampler** — episode-granularity sampling per AlphaFIRST's
  episode replay: rows are chained into episodes as they arrive (lane =
  producer source × row offset, terminal rows close an episode, ring
  overwrites invalidate), and sampling returns whole episodes' rows —
  contiguous in time even when the episode's rows straddle the ring
  wraparound point.

Samplers deal purely in *ring slots*; the blocking-mode newest-segment
fast path stays in `DataServer` (it is a freshness contract, not a
sampling strategy).
"""
from __future__ import annotations

from typing import Dict, Optional

import numpy as np


class SegmentTree:
    """Array-backed sum tree over `size` slots (vectorized set/query).

    Layout: `_value[bound:bound+size]` are the leaves, internal node i
    sums its children 2i/2i+1, `_value[1]` is the total. All operations
    take numpy index/value arrays and run level-synchronously — no
    per-element Python loops."""

    def __init__(self, size: int):
        self._size = size
        bound = 1
        while bound < size:
            bound *= 2
        self._bound = bound
        self._value = np.zeros(2 * bound, np.float64)

    def __getitem__(self, index):
        return self._value[np.asarray(index) + self._bound]

    def __setitem__(self, index, value):
        index = np.asarray(index).reshape(-1) + self._bound
        self._value[index] = value
        while index[0] > 1:
            index = np.unique(index // 2)
            self._value[index] = (self._value[2 * index]
                                  + self._value[2 * index + 1])

    def reduce(self) -> float:
        return float(self._value[1])

    def get_prefix_sum_idx(self, value) -> np.ndarray:
        """For each scalar v, the smallest leaf i with prefix_sum(i) > v —
        the proportional-sampling lookup."""
        value = np.asarray(value, np.float64).copy().reshape(-1)
        index = np.ones_like(value, np.int64)
        while index[0] < self._bound:
            index *= 2
            left = self._value[index]
            go_right = value >= left
            value -= left * go_right
            index += go_right
        return np.minimum(index - self._bound, self._size - 1)


class Sampler:
    """Strategy interface. `bind(ds)` attaches the owning DataServer
    (ring geometry + rng live there); `on_allocate` fires once when the
    ring is sized; `on_write` observes every segment as it lands (ring
    slots + per-row terminal flags + producer source); `sample(k)`
    returns k ring slots; `weights`/`update_priorities` are the
    prioritized-replay consumer loop and no-op elsewhere."""

    name = "base"

    def bind(self, ds) -> None:
        self.ds = ds

    def on_allocate(self, row_slots: int) -> None:
        pass

    def on_write(self, slots: np.ndarray, *, row_done=None, source=None) -> None:
        pass

    def sample(self, k: int) -> np.ndarray:
        raise NotImplementedError

    def weights(self, slots: np.ndarray) -> Optional[np.ndarray]:
        return None

    def update_priorities(self, slots: np.ndarray, priorities) -> None:
        pass

    def _uniform(self, k: int) -> np.ndarray:
        """The pre-refactor uniform draw, bit-for-bit: same generator,
        same call, same head-relative mapping onto ring slots."""
        ds = self.ds
        idx = ds.rng.integers(ds._size, size=k)
        return (ds._head - ds._size + idx) % ds._row_slots


class UniformSampler(Sampler):
    name = "uniform"

    def sample(self, k: int) -> np.ndarray:
        return self._uniform(k)


class PrioritizedSampler(Sampler):
    """Proportional prioritized replay, tianshou-pinned semantics."""

    name = "prioritized"
    reweights = True          # priority updates invalidate staged batches

    def __init__(self, alpha: float = 0.6, beta: float = 0.4):
        assert alpha > 0.0 and beta >= 0.0
        self.alpha, self.beta = alpha, beta
        self._eps = np.finfo(np.float32).eps.item()
        self._max_prio = 1.0
        self._min_prio = 1.0
        self._tree: Optional[SegmentTree] = None

    def on_allocate(self, row_slots: int) -> None:
        self._tree = SegmentTree(row_slots)

    def on_write(self, slots, *, row_done=None, source=None) -> None:
        # init_weight: fresh rows enter at the running max priority so
        # every row is consumed at least once before its TD error rules
        self._tree[slots] = self._max_prio ** self.alpha

    def sample(self, k: int) -> np.ndarray:
        total = self._tree.reduce()
        assert total > 0.0, "prioritized sample from an empty tree"
        scalar = self.ds.rng.random(k) * total
        return self._tree.get_prefix_sum_idx(scalar)

    def weights(self, slots) -> np.ndarray:
        # tianshou's get_weight: tree value (already ** alpha) over the
        # raw min priority, to the -beta — unnormalized IS weights; the
        # consumer divides by weights.max() if it wants the stable form
        return (np.asarray(self._tree[slots])
                / self._min_prio) ** (-self.beta)

    def update_priorities(self, slots, priorities) -> None:
        w = np.abs(np.asarray(priorities, np.float64)) + self._eps
        self._tree[slots] = w ** self.alpha
        self._max_prio = max(self._max_prio, float(w.max()))
        self._min_prio = min(self._min_prio, float(w.min()))


class EpisodeSampler(Sampler):
    """Episode-granularity sampling over ring rows.

    Rows arrive segment-by-segment; row i of consecutive segments from
    one producer is the same env slot, so each (source, i) lane chains
    rows in episode order. A row whose `done` fires closes the lane's
    open chain into a complete episode; a ring overwrite of any chained
    slot invalidates whatever contained it (episode or open chain) —
    stale boundaries are never sampled.

    `sample(k)` draws complete episodes uniformly (with replacement),
    concatenates their rows in temporal order, and truncates to exactly
    k — callers get whole-episode runs, reconstructable across the ring
    wraparound. Before any episode completes it falls back to the
    uniform draw so the learner never starves."""

    name = "episode"

    def __init__(self):
        self._episodes: Dict[int, np.ndarray] = {}
        self._open: Dict[tuple, list] = {}
        self._owner: Dict[int, tuple] = {}   # slot -> ("ep", id) | ("open", lane)
        self._next_id = 0

    def _invalidate(self, slot: int) -> None:
        owner = self._owner.pop(slot, None)
        if owner is None:
            return
        kind, key = owner
        members = (self._episodes.pop(key, None) if kind == "ep"
                   else self._open.pop(key, None))
        if members is not None:
            for s in members:
                self._owner.pop(int(s), None)

    def on_write(self, slots, *, row_done=None, source=None) -> None:
        slots = np.asarray(slots)
        rows = len(slots)
        if row_done is None:
            row_done = np.ones(rows, bool)   # no done signal: row == episode
        for s in slots:
            self._invalidate(int(s))
        for i in range(rows):
            lane = (source, i)
            chain = self._open.setdefault(lane, [])
            chain.append(int(slots[i]))
            self._owner[int(slots[i])] = ("open", lane)
            if row_done[i]:
                ep_id, self._next_id = self._next_id, self._next_id + 1
                ep = np.array(chain, np.int64)
                self._episodes[ep_id] = ep
                for s in chain:
                    self._owner[s] = ("ep", ep_id)
                self._open[lane] = []

    def episodes(self):
        """Complete episodes as ring-slot arrays (temporal order)."""
        return [ep.copy() for ep in self._episodes.values()]

    def sample(self, k: int) -> np.ndarray:
        eps = list(self._episodes.values())
        if not eps:
            return self._uniform(k)
        out: list = []
        while len(out) < k:
            e = eps[int(self.ds.rng.integers(len(eps)))]
            out.extend(e.tolist())
        return np.asarray(out[:k], np.int64)


SAMPLERS = {
    "uniform": UniformSampler,
    "prioritized": PrioritizedSampler,
    "episode": EpisodeSampler,
}


def make_sampler(name, **kwargs) -> Sampler:
    """`name` may already be a Sampler instance (passed through)."""
    if isinstance(name, Sampler):
        assert not kwargs, "kwargs only apply when constructing by name"
        return name
    if name not in SAMPLERS:
        raise KeyError(f"unknown sampler {name!r}; have {sorted(SAMPLERS)}")
    return SAMPLERS[name](**kwargs)
