"""DataServer + ReplayMem: the Learner's embedded data path (§3.2).

Receives trajectory segments from Actors, stores them in a bounded replay,
serves minibatches to the train step, and tracks the paper's throughput
telemetry: rfps (frames received / sec) and cfps (frames consumed / sec);
cfps/rfps is the average learn-repeat ratio, and a `blocking` mode makes
cfps track rfps for on-policy PPO (§4.4).
"""
from __future__ import annotations

import collections
import time
from typing import Any, Deque, Optional

import jax
import numpy as np


class DataServer:
    def __init__(self, capacity_segments: int = 64, seed: int = 0,
                 blocking: bool = True):
        self.buf: Deque[Any] = collections.deque(maxlen=capacity_segments)
        self.rng = np.random.default_rng(seed)
        self.blocking = blocking
        self.frames_received = 0
        self.frames_consumed = 0
        self._t0 = time.monotonic()
        self._unconsumed = 0

    # -- actor side --------------------------------------------------------------
    def put(self, traj) -> None:
        frames = int(np.prod(np.asarray(traj["actions"]).shape[:2]))
        self.frames_received += frames
        self._unconsumed += frames
        self.buf.append(traj)

    # -- learner side -----------------------------------------------------------
    def ready(self) -> bool:
        return len(self.buf) > 0 and (not self.blocking or self._unconsumed > 0)

    def sample(self):
        """Most-recent-first when blocking (on-policy); uniform otherwise."""
        assert self.buf, "DataServer empty"
        if self.blocking:
            traj = self.buf[-1]
        else:
            traj = self.buf[self.rng.integers(len(self.buf))]
        frames = int(np.prod(np.asarray(traj["actions"]).shape[:2]))
        self.frames_consumed += frames
        self._unconsumed = max(0, self._unconsumed - frames)
        return traj

    # -- telemetry (paper Table 3) ----------------------------------------------
    def throughput(self) -> dict:
        dt = max(time.monotonic() - self._t0, 1e-9)
        return {
            "rfps": self.frames_received / dt,
            "cfps": self.frames_consumed / dt,
            "repeat_ratio": self.frames_consumed / max(self.frames_received, 1),
        }
