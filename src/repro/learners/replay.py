"""DataServer + ring-buffer replay: the Learner's embedded data path (§3.2).

Receives trajectory segments from Actors, stores them in a preallocated
NumPy ring buffer keyed by the trajectory structure, serves minibatches to
the train step, and tracks the paper's throughput telemetry: rfps (frames
received / sec) and cfps (frames consumed / sec); cfps/rfps is the average
learn-repeat ratio, and a `blocking` mode makes cfps track rfps for
on-policy PPO (§4.4).

Storage layout: every trajectory leaf shares a leading "row" axis (one row
= one unroll of `unroll_len` frames), so the buffer is one fixed array per
leaf of shape (row_slots,) + leaf.shape[1:], allocated once from the first
segment's structure. `put` writes rows into fixed slots with at most two
contiguous copies (no per-put allocation), `sample` is a single vectorized
fancy-index gather per leaf, and capacity is expressed in frames, not
segments, so differently-shaped runs get comparable memory budgets.

Device feeding: `sample_to_device` returns the minibatch as device arrays
and overlaps the host->device copy with the learner's compute via a
double-buffered prefetch: the *next* minibatch's rows are gathered under
the lock the moment they become known (at `put` in blocking/on-policy
mode, right after the current sample in uniform mode), then the
`jax.device_put` transfers run on a dedicated staging thread, so the
copy proceeds while the caller's train step computes — not serialized in
front of the next `sample_to_device`. Staged batches are freshly
allocated device buffers each time, so a train step that donates its
batch argument (`build_*_train_step(donate_batch=True)`) never aliases
the next staged transfer.

On a CPU backend the overlap is real but small: `device_put` there is a
same-memory copy whose only concurrent part is the GIL-releasing memcpy,
and the train step is itself competing for the same cores — expect the
prefetch win to be a few percent on CPU and to matter on accelerators,
where the PCIe/ICI transfer genuinely rides under device compute (see
BENCH_learner.json's host_feed vs prefetch_feed fields).
"""
from __future__ import annotations

import concurrent.futures
import threading
import time
from typing import List, Optional

import jax
import numpy as np

from repro.learners.samplers import make_sampler


class DataServer:
    def __init__(self, *, capacity_frames: Optional[int] = None, seed: int = 0,
                 blocking: bool = True, capacity_segments: int = 64,
                 prefetch: bool = True, device=None, sampler="uniform",
                 sampler_kwargs: Optional[dict] = None):
        """`capacity_frames` bounds the buffer in frames (rows * unroll_len).
        When omitted, the legacy `capacity_segments` bound is translated to
        frames at first `put` (segments * frames-per-segment). Keyword-only:
        the first positional used to mean capacity_segments, and silently
        reinterpreting old callers as a frames bound would shrink their
        replay by orders of magnitude.

        `prefetch` enables the double-buffered `sample_to_device` staging;
        `device` pins transfers to a specific jax device (default: the
        backend's first device).

        `sampler` selects the off-policy sampling strategy — a name from
        `repro.learners.samplers.SAMPLERS` ("uniform" | "prioritized" |
        "episode", kwargs via `sampler_kwargs`) or a `Sampler` instance.
        The blocking-mode newest-segment fast path is independent of it."""
        self.capacity_frames = capacity_frames
        self.capacity_segments = capacity_segments
        self.rng = np.random.default_rng(seed)
        self.sampler = make_sampler(sampler, **(sampler_kwargs or {}))
        self.sampler.bind(self)
        # producer/consumer concurrency: every mutation runs under one
        # reentrant lock; the condition signals both directions — `put`
        # wakes learners blocked in `wait_ready`, consumption wakes actors
        # blocked in `wait_for_room` (ring-full backpressure)
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self.blocking = blocking
        self.prefetch = prefetch
        self.device = device
        self._staged = None      # (state_token, batch_rows, idx, Future)
        # one staging thread: transfers serialize among themselves but
        # overlap the learner's compute; lazily created at first _stage
        self._stage_pool: Optional[concurrent.futures.ThreadPoolExecutor] = None
        self.prefetch_hits = 0
        self.prefetch_misses = 0
        self.frames_received = 0
        self.frames_consumed = 0
        # lifetime rates start at the FIRST put, not construction — else
        # rfps/cfps average over pre-first-put idle time; the window
        # trackers feed the since-last-`throughput()`-call rates
        self._t0: Optional[float] = None
        self._win_t: Optional[float] = None
        self._win_rx = 0
        self._win_cx = 0
        self._unconsumed = 0
        self._last_sample: Optional[dict] = None
        self._slot_gen: Optional[np.ndarray] = None   # overwrite generations
        self._write_seq = 0
        # ring state, allocated lazily from the first segment's structure
        self._treedef = None
        self._buffers: List[np.ndarray] = []
        self._row_shapes: List[tuple] = []
        self._row_slots = 0
        self._frames_per_row = 0
        self._head = 0          # next slot to write
        self._size = 0          # live rows
        self._last_rows: Optional[np.ndarray] = None  # slots of the newest segment

    # -- allocation --------------------------------------------------------------
    def _leaves(self, traj):
        leaves, treedef = jax.tree_util.tree_flatten(traj)
        leaves = [np.asarray(x) for x in leaves]
        if self._treedef is None:
            self._treedef = treedef
            # frames-per-row (unroll length T) comes from the (rows, T)
            # actions leaf when present; row-only payloads count 1 frame/row
            t_len = 1
            if isinstance(traj, dict) and "actions" in traj:
                t_len = int(np.asarray(traj["actions"]).shape[1])
            self._allocate_with_t(leaves, leaves[0].shape[0], t_len)
        else:
            assert treedef == self._treedef, (
                "trajectory structure changed mid-run: "
                f"{treedef} != {self._treedef}")
        return leaves

    def _allocate_with_t(self, leaves, rows: int, t_len: int) -> None:
        self._frames_per_row = max(1, t_len)
        cap_frames = self.capacity_frames
        if cap_frames is None:
            cap_frames = self.capacity_segments * rows * self._frames_per_row
        self._row_slots = max(rows, cap_frames // self._frames_per_row)
        self._row_shapes = [leaf.shape[1:] for leaf in leaves]
        self._buffers = [np.zeros((self._row_slots,) + s, dtype=leaf.dtype)
                         for s, leaf in zip(self._row_shapes, leaves)]
        self._slot_gen = np.zeros(self._row_slots, np.int64)
        self.sampler.on_allocate(self._row_slots)

    @staticmethod
    def _row_done(traj) -> Optional[np.ndarray]:
        """Per-row terminal flags for episode-aware samplers: True where
        any frame of the row finished an episode; None when the payload
        carries no done signal."""
        if isinstance(traj, dict) and "done" in traj:
            d = np.asarray(traj["done"])
            return d.reshape(d.shape[0], -1).any(axis=1)
        return None

    # -- actor side --------------------------------------------------------------
    def _write_rows(self, leaves, row_done=None, source=None) -> None:
        """Ring write + accounting + prefetch staging; caller holds the lock."""
        if self._t0 is None:
            self._t0 = self._win_t = time.monotonic()
        rows = leaves[0].shape[0]
        frames = rows * self._frames_per_row
        cap = self._row_slots
        assert rows <= cap, (
            f"segment of {rows} rows exceeds the {cap}-row ring "
            f"(capacity_frames={self.capacity_frames})")
        start = self._head
        first = min(rows, cap - start)
        for buf, leaf in zip(self._buffers, leaves):
            np.copyto(buf[start:start + first], leaf[:first])
            if first < rows:                       # wraparound: second copy
                np.copyto(buf[:rows - first], leaf[first:])
        self._last_rows = (start + np.arange(rows)) % cap
        self._head = (start + rows) % cap
        self._size = min(self._size + rows, cap)
        self._write_seq += 1
        self._slot_gen[self._last_rows] = self._write_seq
        self.sampler.on_write(self._last_rows, row_done=row_done,
                              source=source)
        self.frames_received += frames
        self._unconsumed += frames
        if self.prefetch and self.blocking:
            # on-policy: the next sample IS this segment — start its
            # host->device copy now so it overlaps the in-flight train step
            self._stage(self._last_rows, None)
        self._cond.notify_all()

    def put(self, traj, source=None) -> None:
        """Unconditional ring write: never blocks (lock only) and never
        fails for capacity — old rows are overwritten, which in blocking
        (on-policy) mode can bury frames the learner never saw. Producers
        that must not lose frames use `put_when_room`. The segment is
        COPIED into the preallocated ring (np.copyto), so the caller's
        arrays stay the caller's.

        `source` identifies the producer for episode-granularity
        samplers (rows of consecutive segments from one source chain
        into episodes); it defaults to the calling thread, which matches
        the league runtime's one-thread-per-actor layout."""
        with self._cond:
            self._write_rows(self._leaves(traj),
                             row_done=self._row_done(traj),
                             source=threading.get_ident()
                             if source is None else source)

    def put_when_room(self, traj, timeout: Optional[float] = None,
                      source=None) -> bool:
        """`put` with TOCTOU-safe backpressure: the room predicate (the
        segment fits without burying frames the learner has not consumed)
        and the ring write happen under ONE lock hold, so concurrent
        producers can never jointly overshoot capacity — a separate
        check-then-put would re-release the lock between the two.

        MAY BLOCK up to `timeout` (forever when None) waiting for the
        learner to consume; returns False (nothing written) on timeout.
        This is the actor-side backpressure edge: a slow learner throttles
        every producer that uses this call."""
        with self._cond:
            leaves = self._leaves(traj)
            frames = leaves[0].shape[0] * self._frames_per_row

            def room():
                cap = self.ring_capacity_frames
                return cap is None or self._unconsumed + frames <= cap
            if not self._cond.wait_for(room, timeout=timeout):
                return False
            self._write_rows(leaves, row_done=self._row_done(traj),
                             source=threading.get_ident()
                             if source is None else source)
            return True

    def wait_for_room(self, frames: int, timeout: Optional[float] = None) -> bool:
        """Advisory backpressure probe: block until a segment of `frames`
        frames currently fits. Racy by construction under multiple
        producers (the room can be gone by the time the caller puts) —
        producers that need the guarantee use `put_when_room`."""
        with self._cond:
            def room():
                cap = self.ring_capacity_frames
                return cap is None or self._unconsumed + frames <= cap
            return self._cond.wait_for(room, timeout=timeout)

    # -- learner side -----------------------------------------------------------
    def ready(self) -> bool:
        with self._lock:
            return self._size > 0 and (not self.blocking or self._unconsumed > 0)

    def wait_ready(self, timeout: Optional[float] = None) -> bool:
        """Block until `ready()` (a fresh segment in blocking mode, any data
        otherwise). True when ready, False on timeout — the learner worker's
        continuous-drain wait."""
        with self._cond:
            return self._cond.wait_for(self.ready, timeout=timeout)

    def _sample_idx(self, batch_rows: Optional[int]) -> np.ndarray:
        if self.blocking and batch_rows is None:
            return self._last_rows                # freshness contract, not
        k = batch_rows if batch_rows is not None else len(self._last_rows)
        return self.sampler.sample(k)             # ... a sampling strategy

    def _record_sample(self, idx) -> None:
        """Remember the batch just served (slots + overwrite generations
        + IS weights) so the learner can push priorities back after its
        train step — `update_priorities` uses the generations to drop
        updates for slots the ring has since overwritten."""
        idx = np.asarray(idx)
        self._last_sample = {
            "slots": idx.copy(),
            "gen": None if self._slot_gen is None
            else self._slot_gen[idx].copy(),
            "weights": self.sampler.weights(idx),
        }

    def _consume(self, num_rows: int) -> None:
        frames = num_rows * self._frames_per_row
        self.frames_consumed += frames
        self._unconsumed = max(0, self._unconsumed - frames)
        self._cond.notify_all()        # wake producers blocked on backpressure

    def sample(self, batch_rows: Optional[int] = None):
        """Most-recent segment when blocking (on-policy); a uniform
        vectorized row gather otherwise. Host (NumPy) arrays. Never
        blocks — asserts non-empty instead (gate on `ready()` /
        `wait_ready` first). The gather COPIES out of the ring, so the
        returned batch is the caller's own (donation-safe) and later
        `put`s can never mutate it."""
        with self._cond:
            assert self._size > 0, "DataServer empty"
            idx = self._sample_idx(batch_rows)
            self._record_sample(idx)
            out_leaves = [buf[idx] for buf in self._buffers]
            self._consume(len(idx))
            return jax.tree_util.tree_unflatten(self._treedef, out_leaves)

    # -- pipelined device feeding -------------------------------------------------
    def _state_token(self) -> tuple:
        """Identity of the buffer state a staged batch was drawn from: any
        `put` advances frames_received, so a stale staged batch (rows since
        overwritten, or no longer the newest segment) can never be served."""
        return (self._head, self._size, self.frames_received)

    def _stage(self, idx: np.ndarray, for_batch_rows: Optional[int]) -> None:
        """`for_batch_rows` records which request shape the staged batch
        answers: a batch staged for the on-policy newest-segment request
        (None) must never satisfy an explicit uniform `batch_rows` request —
        the row *distributions* differ, not just the sizes.

        The row gather happens here, under the lock (a later `put` must
        not mutate what we stage — `buf[idx]` fancy-indexing copies); the
        `device_put` transfers are handed to the staging thread so they
        overlap the caller's train step instead of running inline."""
        host_leaves = [buf[idx] for buf in self._buffers]
        if self._stage_pool is None:
            self._stage_pool = concurrent.futures.ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="dataserver-stage")
        fut = self._stage_pool.submit(
            lambda: [jax.device_put(x, self.device) for x in host_leaves])
        self._staged = (self._state_token(), for_batch_rows, idx, fut)

    def sample_to_device(self, batch_rows: Optional[int] = None):
        """`sample`, but the minibatch lands as device arrays and the next
        minibatch's transfer is prefetched (double-buffered: the batch being
        consumed and the one being staged are distinct freshly-allocated
        device buffers, so donating the consumed batch is safe)."""
        with self._cond:
            assert self._size > 0, "DataServer empty"
            staged, self._staged = self._staged, None
            if (staged is not None and staged[0] == self._state_token()
                    and staged[1] == batch_rows):
                idx, leaves = staged[2], staged[3].result()
                self.prefetch_hits += 1
            else:
                idx = self._sample_idx(batch_rows)
                leaves = [jax.device_put(buf[idx], self.device)
                          for buf in self._buffers]
                self.prefetch_misses += 1
            self._record_sample(idx)
            self._consume(len(idx))
            if self.prefetch and not self.blocking:
                # off-policy: the next uniform gather is known now — stage it
                # (blocking mode stages at `put`, when the next segment exists)
                self._stage(self._sample_idx(batch_rows), batch_rows)
            return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- prioritized-replay consumer loop -----------------------------------------
    def last_sample_info(self) -> Optional[dict]:
        """Slots/generations/IS-weights of the most recent `sample`/
        `sample_to_device` batch (None before the first). The learner
        echoes slots+gen back through `update_priorities` after it knows
        the batch's TD errors."""
        with self._lock:
            return self._last_sample

    def update_priorities(self, slots, priorities, gen=None) -> int:
        """Consumer-side priority write-back. `gen` (from
        `last_sample_info`) guards against the ring moving on: updates
        for slots overwritten since the sample are dropped, not applied
        to whatever unrelated row lives there now. Returns the number of
        rows actually updated. No-op (0 rows still validated) under
        samplers that carry no priorities."""
        with self._cond:
            slots = np.asarray(slots, np.int64).reshape(-1)
            priorities = np.asarray(priorities, np.float64).reshape(-1)
            assert slots.shape == priorities.shape, \
                "one priority per sampled row"
            if gen is not None and self._slot_gen is not None:
                valid = self._slot_gen[slots] == np.asarray(gen).reshape(-1)
                slots, priorities = slots[valid], priorities[valid]
            if len(slots):
                self.sampler.update_priorities(slots, priorities)
                if (self._staged is not None
                        and getattr(self.sampler, "reweights", False)):
                    self._staged = None   # staged draw used stale priorities
            return int(len(slots))

    # -- introspection ------------------------------------------------------------
    @property
    def num_rows(self) -> int:
        return self._size

    @property
    def size_frames(self) -> int:
        return self._size * self._frames_per_row

    @property
    def ring_capacity_frames(self) -> Optional[int]:
        """Total ring capacity in frames; None before the first `put`
        allocates (capacity_frames unset) — no backpressure until known."""
        if self._row_slots:
            return self._row_slots * self._frames_per_row
        return self.capacity_frames

    @property
    def unconsumed_frames(self) -> int:
        return self._unconsumed

    # -- telemetry (paper Table 3) ----------------------------------------------
    def throughput(self) -> dict:
        """Lifetime rates (since the first `put` — construction-time idle
        is not averaged in) plus windowed rates over the interval since
        the previous `throughput()` call: the steady-state numbers a
        periodic telemetry poll actually wants."""
        with self._lock:
            now = time.monotonic()
            t0 = now if self._t0 is None else self._t0
            dt = max(now - t0, 1e-9)
            win_t = now if self._win_t is None else self._win_t
            wdt = max(now - win_t, 1e-9)
            rx_w = self.frames_received - self._win_rx
            cx_w = self.frames_consumed - self._win_cx
            self._win_t = now
            self._win_rx = self.frames_received
            self._win_cx = self.frames_consumed
            return {
                "rfps": self.frames_received / dt,
                "cfps": self.frames_consumed / dt,
                "rfps_window": rx_w / wdt,
                "cfps_window": cx_w / wdt,
                "repeat_ratio": self.frames_consumed / max(self.frames_received, 1),
                "prefetch_hits": self.prefetch_hits,
                "prefetch_misses": self.prefetch_misses,
            }


# Transport contract: both put paths COPY the segment into the
# preallocated ring (np.copyto in _write_rows) before returning, never
# retaining the caller's arrays — so the RPC server may hand them
# zero-copy views into the same-host shared-memory ring instead of
# privatizing the blobs first (see transport._ShmReader / ISSUE 10).
DataServer.put._zero_copy_ok = True
DataServer.put_when_room._zero_copy_ok = True
