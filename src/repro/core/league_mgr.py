"""LeagueMgr: sponsors the training, coordinates all other modules (§3.2).

Lifecycle per learning agent (M_G of them can run in parallel):
  - the current learning model key theta is registered with GameMgr+HyperMgr
  - Actors call `request_task` at each episode beginning -> Task(theta, phi~Q)
  - Actors call `report_result` at each episode end -> payoff/Elo update
  - the Learner calls `request_learner_task` at each learning-period
    beginning (rank-0 only, as in the paper's MPI semantics)
  - `end_learning_period` freezes theta into the pool (M <- M + {theta}),
    mints theta_{v+1} (inheriting params via the ModelPool and hypers via
    HyperMgr — optionally PBT-perturbed), and returns the new key.

Role-based scheduling (AlphaStar / Minimax-Exploiter extension): each
learning agent can carry a role (`main`, `main_exploiter`,
`league_exploiter`, `minimax_exploiter`), a `FreezeGate` that gates
freezing on pool winrate (freeze when winrate >= tau vs the frozen pool,
or on timeout) instead of a fixed period count, and a reset-on-freeze
policy (`continue` keeps training from theta; `seed` restores the
imitation/random seed params, the exploiter reset of AlphaStar). The
league coordinator polls `should_freeze` and the Learner executes the
freeze via `end_learning_period`.

Every public method is thread-safe (one RLock): in the async runtime
Actors, Learners and the coordinator call in concurrently from their own
threads.
"""
from __future__ import annotations

import collections
import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional, Tuple

from repro.core.game_mgr import GameMgr, SelfPlayPFSPGameMgr
from repro.core.hyper_mgr import HyperMgr
from repro.core.model_pool import ModelPool
from repro.core.payoff import PayoffMatrix
from repro.core.types import (FreezeGate, Hyperparam, MatchResult, ModelKey,
                              Task)
from repro.utils.pytree import tree_copy

ROLES = ("main", "main_exploiter", "league_exploiter", "minimax_exploiter")


@dataclass
class LearningAgent:
    agent_id: str
    current: ModelKey
    game_mgr: GameMgr
    frozen_count: int = 0
    role: str = "main"
    gate: Optional[FreezeGate] = None
    reset_on_freeze: str = "continue"      # 'continue' | 'seed'
    seed_params: Any = None                # kept only when reset needs it


@dataclass
class TaskLease:
    """One outstanding match: who holds it, and until when.

    A lease is completed by the first `report_result` quoting its task_id,
    released when the same actor requests its next task, or *reaped* when
    its deadline passes / its actor is declared dead — in which case the
    match template re-enters the matchmaking queue under a fresh task_id
    (a new generation) and any late results quoting the old id are dropped."""
    task_id: int
    task: Task
    agent_id: str
    actor_id: Optional[str]
    deadline: float
    issued_t: float
    reissue_of: Optional[int] = None


# how many reaped task_ids we remember for the late-result generation guard
_REAPED_MEMORY = 4096


class LeagueMgr:
    def __init__(self, model_pool: Optional[ModelPool] = None,
                 hyper_mgr: Optional[HyperMgr] = None,
                 payoff: Optional[PayoffMatrix] = None,
                 pbt: bool = False, seed: int = 0,
                 lease_ttl_s: Optional[float] = None):
        self.model_pool = model_pool or ModelPool()
        self.hyper_mgr = hyper_mgr or HyperMgr(seed=seed)
        self.payoff = payoff or PayoffMatrix()
        self.agents: Dict[str, LearningAgent] = {}
        self.frozen_pool: List[ModelKey] = []   # M, ordered by freeze time
        self.pbt = pbt
        self._task_ids = itertools.count()
        self._results: List[MatchResult] = []
        self._lock = threading.RLock()
        # incremental pool-membership filter: the opponent list only changes
        # when a model freezes or pool membership moves, so cache it behind a
        # (frozen-pool length, pool membership version) signature instead of
        # re-filtering O(pool) on every request_task
        self._opp_cache: Tuple[ModelKey, ...] = ()
        self._opp_sig: Tuple[int, int] = (-1, -1)
        self.freeze_events: List[dict] = []     # telemetry: who froze, why, when
        # -- lease plane (active only when lease_ttl_s is set) ----------------
        # With lease_ttl_s=None the task_id counter still runs but no lease
        # state is kept: legacy drivers keep the exact pre-lease behavior and
        # memory profile. With a TTL, every request_task records a TaskLease;
        # `reap_leases` (called by the coordinator, fed by heartbeat counters)
        # expires them, re-queues the match, and arms the generation guard.
        self.lease_ttl_s = lease_ttl_s
        self._leases: Dict[int, TaskLease] = {}
        self._actor_lease: Dict[str, int] = {}          # actor_id -> outstanding task_id
        self._reaped: "collections.OrderedDict[int, float]" = collections.OrderedDict()
        self._reissue: Dict[str, collections.deque] = {}  # agent_id -> Task templates
        self.lease_stats = {
            "issued": 0, "completed": 0, "released": 0, "reaped": 0,
            "reissued": 0, "dropped_results": 0,
        }

    # -- setup -------------------------------------------------------------------
    def add_learning_agent(self, agent_id: str, init_params: Any,
                           game_mgr: Optional[GameMgr] = None,
                           hyper: Optional[Hyperparam] = None,
                           seed_into_pool: bool = True,
                           role: str = "main",
                           gate: Optional[FreezeGate] = None,
                           reset_on_freeze: str = "continue") -> ModelKey:
        """Register a learning agent with its seed model theta_1 (random init
        or imitation-learned, §3.1)."""
        assert role in ROLES, f"unknown role {role!r}; pick from {ROLES}"
        assert reset_on_freeze in ("continue", "seed"), reset_on_freeze
        with self._lock:
            gm = game_mgr or SelfPlayPFSPGameMgr(payoff=self.payoff)
            gm.payoff = self.payoff             # all agents share one payoff matrix
            key = ModelKey(agent_id, 0)
            self.model_pool.push(key, init_params)
            self.hyper_mgr.register(key, hyper)
            gm.add_player(key)
            seed_params = tree_copy(init_params) if reset_on_freeze == "seed" else None
            self.agents[agent_id] = LearningAgent(
                agent_id, key, gm, role=role, gate=gate,
                reset_on_freeze=reset_on_freeze, seed_params=seed_params)
            if seed_into_pool:
                # the seed policy is a valid opponent from the start
                frozen_seed = ModelKey(agent_id, 0)
                if frozen_seed not in self.frozen_pool:
                    self.frozen_pool.append(frozen_seed)
            return key

    # -- actor-facing API -----------------------------------------------------
    def _opponents(self) -> Tuple[ModelKey, ...]:
        """Frozen-pool members whose params are pullable, cached until the
        frozen pool or the ModelPool's key set actually changes."""
        sig = (len(self.frozen_pool), self.model_pool.membership_version)
        if sig != self._opp_sig:
            self._opp_cache = tuple(k for k in self.frozen_pool
                                    if k in self.model_pool)
            self._opp_sig = sig
        return self._opp_cache

    def request_task(self, agent_id: str = "main",
                     actor_id: Optional[str] = None) -> Task:
        """Actor-facing: sample an opponent and return a fresh Task. Holds
        the league lock only for the matchmaking draw — never blocks on
        anything else. The returned Task is an immutable value object
        (safe to ship across threads or the RPC transport); params are NOT
        included — the Actor pulls them from the ModelPool by key.

        When the lease plane is active, the Task is issued under a lease
        with deadline `now + lease_ttl_s`; a reaped match waiting in the
        re-issue queue wins over a fresh matchmaking draw (under a NEW
        task_id — the old generation stays dead). An actor names itself
        via `actor_id` so its previous lease is released on its next
        request (one task in flight per actor) and so the reaper can tie
        leases to heartbeat liveness."""
        with self._lock:
            ag = self.agents[agent_id]
            tid = next(self._task_ids)
            task = self._pop_reissue(ag)
            if task is not None:
                self.lease_stats["reissued"] += 1
                task = Task(learner_key=task.learner_key,
                            opponent_keys=task.opponent_keys,
                            hyperparam=task.hyperparam, task_id=tid)
            else:
                opp = ag.game_mgr.get_opponent(ag.current, self._opponents())
                task = Task(learner_key=ag.current, opponent_keys=(opp,),
                            hyperparam=self.hyper_mgr.get(ag.current),
                            task_id=tid)
            if self.lease_ttl_s is not None:
                now = time.monotonic()
                if actor_id is not None:
                    self._release_actor(actor_id)
                    self._actor_lease[actor_id] = tid
                self._leases[tid] = TaskLease(
                    task_id=tid, task=task, agent_id=agent_id,
                    actor_id=actor_id, deadline=now + self.lease_ttl_s,
                    issued_t=now)
                self.lease_stats["issued"] += 1
            return task

    def _pop_reissue(self, ag: LearningAgent) -> Optional[Task]:
        """Next reaped match template for this agent, skipping templates
        whose learner key went stale (the lineage froze past them — the
        fresh draw is strictly better evidence)."""
        q = self._reissue.get(ag.agent_id)
        while q:
            t = q.popleft()
            if t.learner_key == ag.current:
                return t
        return None

    def _release_actor(self, actor_id: str):
        """The actor moved on: its previous lease is done (released), not
        reaped — no re-issue, and its late results stay acceptable."""
        prev = self._actor_lease.pop(actor_id, None)
        if prev is not None and self._leases.pop(prev, None) is not None:
            self.lease_stats["released"] += 1

    def report_result(self, result: MatchResult):
        """Actor-facing: record an episode outcome on the shared payoff
        matrix (and the owning agent's matchmaker state). Non-blocking
        (lock only); safe to call from any worker thread at any rate —
        freeze gating reads the same payoff under the same lock, so a
        result is visible to `should_freeze` as soon as this returns.

        Generation guard: a result quoting a reaped lease is dropped with
        telemetry (`lease_stats['dropped_results']`) — the match was
        re-issued to someone else, and double-recording would corrupt the
        payoff matrix. Results with task_id=-1 (legacy/eval traffic)
        bypass the guard entirely."""
        with self._lock:
            tid = getattr(result, "task_id", -1)
            if tid in self._reaped:
                self.lease_stats["dropped_results"] += 1
                return
            lease = self._leases.pop(tid, None) if tid >= 0 else None
            if lease is not None:
                self.lease_stats["completed"] += 1
                if lease.actor_id is not None and \
                        self._actor_lease.get(lease.actor_id) == tid:
                    del self._actor_lease[lease.actor_id]
            self._results.append(result)
            for key in (result.learner_key, *result.opponent_keys):
                if key not in self.payoff:
                    self.payoff.add_model(key)
            ag = self.agents.get(result.learner_key.agent_id)
            if ag is not None:
                ag.game_mgr.on_match_result(result)
            else:
                # unknown lineage (eval traffic, a lineage whose learner
                # already detached): record straight on the shared payoff
                # matrix instead of minting a throwaway GameMgr per result
                self.payoff.record(result)

    # -- lease plane (coordinator API) -----------------------------------------
    def touch_actor(self, actor_id: str, now: Optional[float] = None):
        """Heartbeat feed: the actor is alive — push its outstanding
        lease's deadline out to now + lease_ttl_s."""
        with self._lock:
            if self.lease_ttl_s is None:
                return
            tid = self._actor_lease.get(actor_id)
            lease = self._leases.get(tid) if tid is not None else None
            if lease is not None:
                t = time.monotonic() if now is None else now
                lease.deadline = t + self.lease_ttl_s

    def reap_leases(self, now: Optional[float] = None,
                    dead_actors: Iterable[str] = ()) -> List[TaskLease]:
        """Coordinator-facing: expire leases past their deadline or held by
        a dead actor. Each reaped match template re-enters its agent's
        re-issue queue (served to the next `request_task` under a fresh
        task_id) and the old task_id is remembered so late results from
        the presumed-dead actor are dropped. Returns the reaped leases."""
        with self._lock:
            if not self._leases:
                return []
            t = time.monotonic() if now is None else now
            dead = set(dead_actors)
            reaped = [l for l in self._leases.values()
                      if l.deadline <= t or
                      (l.actor_id is not None and l.actor_id in dead)]
            for lease in reaped:
                del self._leases[lease.task_id]
                if lease.actor_id is not None and \
                        self._actor_lease.get(lease.actor_id) == lease.task_id:
                    del self._actor_lease[lease.actor_id]
                self._reaped[lease.task_id] = t
                q = self._reissue.setdefault(lease.agent_id,
                                             collections.deque())
                q.append(lease.task)
                self.lease_stats["reaped"] += 1
            while len(self._reaped) > _REAPED_MEMORY:
                self._reaped.popitem(last=False)
            return reaped

    def lease_state(self) -> dict:
        """Lease-plane telemetry: counters plus current occupancy. The
        chaos smoke asserts `dropped_results` here — the payoff matrix
        never saw a reaped generation's outcome."""
        with self._lock:
            return {
                **self.lease_stats,
                "outstanding": len(self._leases),
                "reissue_queued": sum(len(q) for q in self._reissue.values()),
                "ttl_s": self.lease_ttl_s,
            }

    # -- learner-facing API ------------------------------------------------------
    def request_learner_task(self, agent_id: str = "main") -> Task:
        return self.request_task(agent_id)

    # -- freeze gating (league coordinator API) ----------------------------------
    def pool_winrate(self, agent_id: str) -> Tuple[float, float]:
        """theta's aggregate (winrate, games) vs the current frozen pool —
        the FreezeGate signal."""
        with self._lock:
            ag = self.agents[agent_id]
            opponents = [k for k in self._opponents() if k != ag.current]
            return self.payoff.aggregate_vs(ag.current, opponents)

    def should_freeze(self, agent_id: str, steps: int) -> Optional[str]:
        """Freeze reason if this agent's gate fires at `steps` learner steps
        into the current period; None to keep training. Agents without a
        gate (legacy fixed-period drivers) never self-trigger."""
        with self._lock:
            ag = self.agents[agent_id]
            if ag.gate is None:
                return None
            wr, games = self.pool_winrate(agent_id)
            return ag.gate.check(steps, wr, games)

    def end_learning_period(self, agent_id: str, params: Any,
                            reason: str = "period") -> ModelKey:
        """Freeze theta, mint theta_{v+1} (same lineage), PBT if enabled.

        theta_{v+1} warm-starts from theta, unless the agent's
        reset-on-freeze policy is 'seed' (exploiter roles), in which case it
        restarts from the stashed seed params — the AlphaStar exploiter
        reset. Callers that hold live params (the Learner) must re-pull
        theta_{v+1} from the ModelPool afterwards.

        Contract: non-blocking (league lock only, briefly also the pool
        lock via push/freeze). `params` is stored LIVE as the frozen final
        weights AND (under 'continue') as theta_{v+1}'s warm start — hand
        over a snapshot, never a buffer a donating step may delete. The
        single-writer discipline (only the owning Learner thread calls
        this for its agent) is by convention, not enforced."""
        with self._lock:
            ag = self.agents[agent_id]
            old = ag.current
            self.model_pool.push(old, params)       # final weights
            self.model_pool.freeze(old)
            if old not in self.frozen_pool:
                self.frozen_pool.append(old)
            new = ModelKey(agent_id, old.version + 1)
            if ag.reset_on_freeze == "seed" and ag.seed_params is not None:
                self.model_pool.push(new, tree_copy(ag.seed_params))
            else:
                self.model_pool.push(new, params)   # warm start from theta
            self.hyper_mgr.inherit(new, old)
            if self.pbt:
                self._maybe_pbt(agent_id, new)
            ag.game_mgr.add_player(new, parent=old)
            if new not in self.payoff:
                self.payoff.add_model(new)
            ag.current = new
            ag.frozen_count += 1
            self.freeze_events.append({
                "key": str(old), "agent": agent_id, "role": ag.role,
                "reason": reason, "t": time.monotonic()})
            return new

    def _maybe_pbt(self, agent_id: str, new_key: ModelKey):
        """If this agent's Elo trails the best learning agent by >100, copy
        the leader's params+hypers (exploit) and perturb (explore)."""
        if len(self.agents) < 2:
            self.hyper_mgr.explore(new_key)
            return
        elos = {aid: self.payoff.elo.get(a.current, self.payoff.init_elo)
                for aid, a in self.agents.items()}
        best = max(elos, key=elos.get)
        if best != agent_id and elos[best] - elos[agent_id] > 100.0:
            leader = self.agents[best]
            # deep-copy the leader's pytree: the pulled object is (or will
            # be adopted as) live learner state, and sharing it between two
            # lineages lets one donating train step delete the other's
            # buffers (the PR 1 aliasing-bug class)
            self.model_pool.push(new_key,
                                 self.model_pool.pull(leader.current, copy=True))
            self.hyper_mgr.exploit_explore(new_key, leader.current)
        else:
            self.hyper_mgr.explore(new_key)

    # -- introspection ---------------------------------------------------------
    def current_model_key(self, agent_id: str) -> ModelKey:
        """The lineage's current learning key. Cheap by design (one small
        value, lock only) — the RPC transport's per-step `current_key`
        lookups land here instead of on the full `league_state` dump."""
        with self._lock:
            return self.agents[agent_id].current

    def league_state(self) -> dict:
        with self._lock:
            return {
                "frozen_pool": [str(k) for k in self.frozen_pool],
                "agents": {aid: str(a.current) for aid, a in self.agents.items()},
                "roles": {aid: a.role for aid, a in self.agents.items()},
                "elo": {str(k): v for k, v in self.payoff.elo.items()},
                "num_results": len(self._results),
                "num_freezes": len(self.freeze_events),
            }
