"""LeagueMgr: sponsors the training, coordinates all other modules (§3.2).

Lifecycle per learning agent (M_G of them can run in parallel):
  - the current learning model key theta is registered with GameMgr+HyperMgr
  - Actors call `request_task` at each episode beginning -> Task(theta, phi~Q)
  - Actors call `report_result` at each episode end -> payoff/Elo update
  - the Learner calls `request_learner_task` at each learning-period
    beginning (rank-0 only, as in the paper's MPI semantics)
  - `end_learning_period` freezes theta into the pool (M <- M + {theta}),
    mints theta_{v+1} (inheriting params via the ModelPool and hypers via
    HyperMgr — optionally PBT-perturbed), and returns the new key.

Role-based scheduling (AlphaStar / Minimax-Exploiter extension): each
learning agent can carry a role (`main`, `main_exploiter`,
`league_exploiter`, `minimax_exploiter`), a `FreezeGate` that gates
freezing on pool winrate (freeze when winrate >= tau vs the frozen pool,
or on timeout) instead of a fixed period count, and a reset-on-freeze
policy (`continue` keeps training from theta; `seed` restores the
imitation/random seed params, the exploiter reset of AlphaStar). The
league coordinator polls `should_freeze` and the Learner executes the
freeze via `end_learning_period`.

Every public method is thread-safe (one RLock): in the async runtime
Actors, Learners and the coordinator call in concurrently from their own
threads.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

from repro.core.game_mgr import GameMgr, SelfPlayPFSPGameMgr
from repro.core.hyper_mgr import HyperMgr
from repro.core.model_pool import ModelPool
from repro.core.payoff import PayoffMatrix
from repro.core.types import (FreezeGate, Hyperparam, MatchResult, ModelKey,
                              Task)
from repro.utils.pytree import tree_copy

ROLES = ("main", "main_exploiter", "league_exploiter", "minimax_exploiter")


@dataclass
class LearningAgent:
    agent_id: str
    current: ModelKey
    game_mgr: GameMgr
    frozen_count: int = 0
    role: str = "main"
    gate: Optional[FreezeGate] = None
    reset_on_freeze: str = "continue"      # 'continue' | 'seed'
    seed_params: Any = None                # kept only when reset needs it


class LeagueMgr:
    def __init__(self, model_pool: Optional[ModelPool] = None,
                 hyper_mgr: Optional[HyperMgr] = None,
                 payoff: Optional[PayoffMatrix] = None,
                 pbt: bool = False, seed: int = 0):
        self.model_pool = model_pool or ModelPool()
        self.hyper_mgr = hyper_mgr or HyperMgr(seed=seed)
        self.payoff = payoff or PayoffMatrix()
        self.agents: Dict[str, LearningAgent] = {}
        self.frozen_pool: List[ModelKey] = []   # M, ordered by freeze time
        self.pbt = pbt
        self._task_ids = itertools.count()
        self._results: List[MatchResult] = []
        self._lock = threading.RLock()
        # incremental pool-membership filter: the opponent list only changes
        # when a model freezes or pool membership moves, so cache it behind a
        # (frozen-pool length, pool membership version) signature instead of
        # re-filtering O(pool) on every request_task
        self._opp_cache: Tuple[ModelKey, ...] = ()
        self._opp_sig: Tuple[int, int] = (-1, -1)
        self.freeze_events: List[dict] = []     # telemetry: who froze, why, when

    # -- setup -------------------------------------------------------------------
    def add_learning_agent(self, agent_id: str, init_params: Any,
                           game_mgr: Optional[GameMgr] = None,
                           hyper: Optional[Hyperparam] = None,
                           seed_into_pool: bool = True,
                           role: str = "main",
                           gate: Optional[FreezeGate] = None,
                           reset_on_freeze: str = "continue") -> ModelKey:
        """Register a learning agent with its seed model theta_1 (random init
        or imitation-learned, §3.1)."""
        assert role in ROLES, f"unknown role {role!r}; pick from {ROLES}"
        assert reset_on_freeze in ("continue", "seed"), reset_on_freeze
        with self._lock:
            gm = game_mgr or SelfPlayPFSPGameMgr(payoff=self.payoff)
            gm.payoff = self.payoff             # all agents share one payoff matrix
            key = ModelKey(agent_id, 0)
            self.model_pool.push(key, init_params)
            self.hyper_mgr.register(key, hyper)
            gm.add_player(key)
            seed_params = tree_copy(init_params) if reset_on_freeze == "seed" else None
            self.agents[agent_id] = LearningAgent(
                agent_id, key, gm, role=role, gate=gate,
                reset_on_freeze=reset_on_freeze, seed_params=seed_params)
            if seed_into_pool:
                # the seed policy is a valid opponent from the start
                frozen_seed = ModelKey(agent_id, 0)
                if frozen_seed not in self.frozen_pool:
                    self.frozen_pool.append(frozen_seed)
            return key

    # -- actor-facing API -----------------------------------------------------
    def _opponents(self) -> Tuple[ModelKey, ...]:
        """Frozen-pool members whose params are pullable, cached until the
        frozen pool or the ModelPool's key set actually changes."""
        sig = (len(self.frozen_pool), self.model_pool.membership_version)
        if sig != self._opp_sig:
            self._opp_cache = tuple(k for k in self.frozen_pool
                                    if k in self.model_pool)
            self._opp_sig = sig
        return self._opp_cache

    def request_task(self, agent_id: str = "main") -> Task:
        """Actor-facing: sample an opponent and return a fresh Task. Holds
        the league lock only for the matchmaking draw — never blocks on
        anything else. The returned Task is an immutable value object
        (safe to ship across threads or the RPC transport); params are NOT
        included — the Actor pulls them from the ModelPool by key."""
        with self._lock:
            ag = self.agents[agent_id]
            opp = ag.game_mgr.get_opponent(ag.current, self._opponents())
            return Task(learner_key=ag.current, opponent_keys=(opp,),
                        hyperparam=self.hyper_mgr.get(ag.current),
                        task_id=next(self._task_ids))

    def report_result(self, result: MatchResult):
        """Actor-facing: record an episode outcome on the shared payoff
        matrix (and the owning agent's matchmaker state). Non-blocking
        (lock only); safe to call from any worker thread at any rate —
        freeze gating reads the same payoff under the same lock, so a
        result is visible to `should_freeze` as soon as this returns."""
        with self._lock:
            self._results.append(result)
            for key in (result.learner_key, *result.opponent_keys):
                if key not in self.payoff:
                    self.payoff.add_model(key)
            ag = self.agents.get(result.learner_key.agent_id)
            if ag is not None:
                ag.game_mgr.on_match_result(result)
            else:
                # unknown lineage (eval traffic, a lineage whose learner
                # already detached): record straight on the shared payoff
                # matrix instead of minting a throwaway GameMgr per result
                self.payoff.record(result)

    # -- learner-facing API ------------------------------------------------------
    def request_learner_task(self, agent_id: str = "main") -> Task:
        return self.request_task(agent_id)

    # -- freeze gating (league coordinator API) ----------------------------------
    def pool_winrate(self, agent_id: str) -> Tuple[float, float]:
        """theta's aggregate (winrate, games) vs the current frozen pool —
        the FreezeGate signal."""
        with self._lock:
            ag = self.agents[agent_id]
            opponents = [k for k in self._opponents() if k != ag.current]
            return self.payoff.aggregate_vs(ag.current, opponents)

    def should_freeze(self, agent_id: str, steps: int) -> Optional[str]:
        """Freeze reason if this agent's gate fires at `steps` learner steps
        into the current period; None to keep training. Agents without a
        gate (legacy fixed-period drivers) never self-trigger."""
        with self._lock:
            ag = self.agents[agent_id]
            if ag.gate is None:
                return None
            wr, games = self.pool_winrate(agent_id)
            return ag.gate.check(steps, wr, games)

    def end_learning_period(self, agent_id: str, params: Any,
                            reason: str = "period") -> ModelKey:
        """Freeze theta, mint theta_{v+1} (same lineage), PBT if enabled.

        theta_{v+1} warm-starts from theta, unless the agent's
        reset-on-freeze policy is 'seed' (exploiter roles), in which case it
        restarts from the stashed seed params — the AlphaStar exploiter
        reset. Callers that hold live params (the Learner) must re-pull
        theta_{v+1} from the ModelPool afterwards.

        Contract: non-blocking (league lock only, briefly also the pool
        lock via push/freeze). `params` is stored LIVE as the frozen final
        weights AND (under 'continue') as theta_{v+1}'s warm start — hand
        over a snapshot, never a buffer a donating step may delete. The
        single-writer discipline (only the owning Learner thread calls
        this for its agent) is by convention, not enforced."""
        with self._lock:
            ag = self.agents[agent_id]
            old = ag.current
            self.model_pool.push(old, params)       # final weights
            self.model_pool.freeze(old)
            if old not in self.frozen_pool:
                self.frozen_pool.append(old)
            new = ModelKey(agent_id, old.version + 1)
            if ag.reset_on_freeze == "seed" and ag.seed_params is not None:
                self.model_pool.push(new, tree_copy(ag.seed_params))
            else:
                self.model_pool.push(new, params)   # warm start from theta
            self.hyper_mgr.inherit(new, old)
            if self.pbt:
                self._maybe_pbt(agent_id, new)
            ag.game_mgr.add_player(new, parent=old)
            if new not in self.payoff:
                self.payoff.add_model(new)
            ag.current = new
            ag.frozen_count += 1
            self.freeze_events.append({
                "key": str(old), "agent": agent_id, "role": ag.role,
                "reason": reason, "t": time.monotonic()})
            return new

    def _maybe_pbt(self, agent_id: str, new_key: ModelKey):
        """If this agent's Elo trails the best learning agent by >100, copy
        the leader's params+hypers (exploit) and perturb (explore)."""
        if len(self.agents) < 2:
            self.hyper_mgr.explore(new_key)
            return
        elos = {aid: self.payoff.elo.get(a.current, self.payoff.init_elo)
                for aid, a in self.agents.items()}
        best = max(elos, key=elos.get)
        if best != agent_id and elos[best] - elos[agent_id] > 100.0:
            leader = self.agents[best]
            # deep-copy the leader's pytree: the pulled object is (or will
            # be adopted as) live learner state, and sharing it between two
            # lineages lets one donating train step delete the other's
            # buffers (the PR 1 aliasing-bug class)
            self.model_pool.push(new_key,
                                 self.model_pool.pull(leader.current, copy=True))
            self.hyper_mgr.exploit_explore(new_key, leader.current)
        else:
            self.hyper_mgr.explore(new_key)

    # -- introspection ---------------------------------------------------------
    def current_model_key(self, agent_id: str) -> ModelKey:
        """The lineage's current learning key. Cheap by design (one small
        value, lock only) — the RPC transport's per-step `current_key`
        lookups land here instead of on the full `league_state` dump."""
        with self._lock:
            return self.agents[agent_id].current

    def league_state(self) -> dict:
        with self._lock:
            return {
                "frozen_pool": [str(k) for k in self.frozen_pool],
                "agents": {aid: str(a.current) for aid, a in self.agents.items()},
                "roles": {aid: a.role for aid, a in self.agents.items()},
                "elo": {str(k): v for k, v in self.payoff.elo.items()},
                "num_results": len(self._results),
                "num_freezes": len(self.freeze_events),
            }
