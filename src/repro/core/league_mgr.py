"""LeagueMgr: sponsors the training, coordinates all other modules (§3.2).

Lifecycle per learning agent (M_G of them can run in parallel):
  - the current learning model key theta is registered with GameMgr+HyperMgr
  - Actors call `request_task` at each episode beginning -> Task(theta, phi~Q)
  - Actors call `report_result` at each episode end -> payoff/Elo update
  - the Learner calls `request_learner_task` at each learning-period
    beginning (rank-0 only, as in the paper's MPI semantics)
  - `end_learning_period` freezes theta into the pool (M <- M + {theta}),
    mints theta_{v+1} (inheriting params via the ModelPool and hypers via
    HyperMgr — optionally PBT-perturbed), and returns the new key.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

from repro.core.game_mgr import GameMgr, SelfPlayPFSPGameMgr
from repro.core.hyper_mgr import HyperMgr
from repro.core.model_pool import ModelPool
from repro.core.payoff import PayoffMatrix
from repro.core.types import Hyperparam, MatchResult, ModelKey, Task


@dataclass
class LearningAgent:
    agent_id: str
    current: ModelKey
    game_mgr: GameMgr
    frozen_count: int = 0


class LeagueMgr:
    def __init__(self, model_pool: Optional[ModelPool] = None,
                 hyper_mgr: Optional[HyperMgr] = None,
                 payoff: Optional[PayoffMatrix] = None,
                 pbt: bool = False, seed: int = 0):
        self.model_pool = model_pool or ModelPool()
        self.hyper_mgr = hyper_mgr or HyperMgr(seed=seed)
        self.payoff = payoff or PayoffMatrix()
        self.agents: Dict[str, LearningAgent] = {}
        self.frozen_pool: List[ModelKey] = []   # M, ordered by freeze time
        self.pbt = pbt
        self._task_ids = itertools.count()
        self._results: List[MatchResult] = []

    # -- setup -------------------------------------------------------------------
    def add_learning_agent(self, agent_id: str, init_params: Any,
                           game_mgr: Optional[GameMgr] = None,
                           hyper: Optional[Hyperparam] = None,
                           seed_into_pool: bool = True) -> ModelKey:
        """Register a learning agent with its seed model theta_1 (random init
        or imitation-learned, §3.1)."""
        gm = game_mgr or SelfPlayPFSPGameMgr(payoff=self.payoff)
        gm.payoff = self.payoff                 # all agents share one payoff matrix
        key = ModelKey(agent_id, 0)
        self.model_pool.push(key, init_params)
        self.hyper_mgr.register(key, hyper)
        gm.add_player(key)
        self.agents[agent_id] = LearningAgent(agent_id, key, gm)
        if seed_into_pool:
            # the seed policy is a valid opponent from the start
            frozen_seed = ModelKey(agent_id, 0)
            if frozen_seed not in self.frozen_pool:
                self.frozen_pool.append(frozen_seed)
        return key

    # -- actor-facing API -----------------------------------------------------
    def request_task(self, agent_id: str = "main") -> Task:
        ag = self.agents[agent_id]
        opponents = [k for k in self.frozen_pool if k in self.model_pool]
        opp = ag.game_mgr.get_opponent(ag.current, opponents)
        return Task(learner_key=ag.current, opponent_keys=(opp,),
                    hyperparam=self.hyper_mgr.get(ag.current),
                    task_id=next(self._task_ids))

    def report_result(self, result: MatchResult):
        self._results.append(result)
        for key in (result.learner_key, *result.opponent_keys):
            if key not in self.payoff:
                self.payoff.add_model(key)
        ag = self.agents.get(result.learner_key.agent_id)
        (ag.game_mgr if ag else GameMgr(payoff=self.payoff)).on_match_result(result)

    # -- learner-facing API ------------------------------------------------------
    def request_learner_task(self, agent_id: str = "main") -> Task:
        return self.request_task(agent_id)

    def end_learning_period(self, agent_id: str, params: Any) -> ModelKey:
        """Freeze theta, mint theta_{v+1} (same lineage), PBT if enabled."""
        ag = self.agents[agent_id]
        old = ag.current
        self.model_pool.push(old, params)       # final weights
        self.model_pool.freeze(old)
        if old not in self.frozen_pool:
            self.frozen_pool.append(old)
        new = ModelKey(agent_id, old.version + 1)
        self.model_pool.push(new, params)       # warm start from theta
        self.hyper_mgr.inherit(new, old)
        if self.pbt:
            self._maybe_pbt(agent_id, new)
        ag.game_mgr.add_player(new, parent=old)
        if new not in self.payoff:
            self.payoff.add_model(new)
        ag.current = new
        ag.frozen_count += 1
        return new

    def _maybe_pbt(self, agent_id: str, new_key: ModelKey):
        """If this agent's Elo trails the best learning agent by >100, copy
        the leader's params+hypers (exploit) and perturb (explore)."""
        if len(self.agents) < 2:
            self.hyper_mgr.explore(new_key)
            return
        elos = {aid: self.payoff.elo.get(a.current, self.payoff.init_elo)
                for aid, a in self.agents.items()}
        best = max(elos, key=elos.get)
        if best != agent_id and elos[best] - elos[agent_id] > 100.0:
            leader = self.agents[best]
            self.model_pool.push(new_key, self.model_pool.pull(leader.current))
            self.hyper_mgr.exploit_explore(new_key, leader.current)
        else:
            self.hyper_mgr.explore(new_key)

    # -- introspection ---------------------------------------------------------
    def league_state(self) -> dict:
        return {
            "frozen_pool": [str(k) for k in self.frozen_pool],
            "agents": {aid: str(a.current) for aid, a in self.agents.items()},
            "elo": {str(k): v for k, v in self.payoff.elo.items()},
            "num_results": len(self._results),
        }
