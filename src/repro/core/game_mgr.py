"""GameMgr: opponent-sampling algorithms over the pool (§3.1-3.2).

phi ~ Q(M). Implemented Q's, each matching a published scheme cited by the
paper:
  UniformGameMgr        — uniform over the (most recent N) historical models
                          [Bansal et al. 2017; the paper's ViZDoom run, N=50]
  PFSPGameMgr           — prioritized FSP, weight f(P[win]) with 'linear'
                          (1-p), 'squared' (1-p)^2, 'variance' p(1-p)
                          [Vinyals et al. 2019]
  SelfPlayPFSPGameMgr   — mixture: 35% current self, 65% PFSP — how the
                          AlphaStar Main Agent samples; the paper's
                          Pommerman experiment (§4.3) uses exactly this.
  EloMatchGameMgr       — probabilistic Elo-score matching, Gaussian kernel
                          over rating difference [Jaderberg et al. 2019, PBT]
  ExploiterGameMgr      — Agent-Exploiter: always plays the main agent's
                          current model [Vinyals et al. 2019]

Extension point mirrors the paper (§3.6): derive and implement
get_player()/add_player().
"""
from __future__ import annotations

import random
from typing import List, Optional, Sequence

import numpy as np

from repro.core.payoff import PayoffMatrix
from repro.core.types import MatchResult, ModelKey

GAME_MGRS = {}


def register_game_mgr(name):
    def deco(cls):
        GAME_MGRS[name] = cls
        cls.name = name
        return cls
    return deco


class GameMgr:
    """Base class: maintains the payoff matrix; subclasses choose opponents."""

    def __init__(self, payoff: Optional[PayoffMatrix] = None, seed: int = 0):
        self.payoff = payoff or PayoffMatrix()
        self.rng = random.Random(seed)

    # -- paper API -------------------------------------------------------------
    def add_player(self, key: ModelKey, parent: Optional[ModelKey] = None):
        self.payoff.add_model(key, init_elo=self.payoff.elo.get(parent) if parent else None)

    def on_match_result(self, result: MatchResult):
        self.payoff.record(result)

    def get_player(self, learner_key: ModelKey, candidates: Sequence[ModelKey]) -> ModelKey:
        raise NotImplementedError

    def get_opponent(self, learner_key: ModelKey,
                     candidates: Sequence[ModelKey]) -> ModelKey:
        if not candidates:
            return learner_key          # pure self-play until the pool grows
        return self.get_player(learner_key, candidates)

    def _choice(self, candidates: Sequence[ModelKey], probs: np.ndarray) -> ModelKey:
        probs = np.asarray(probs, np.float64)
        probs = probs / probs.sum() if probs.sum() > 0 else np.ones(len(candidates)) / len(candidates)
        idx = self.rng.choices(range(len(candidates)), weights=probs, k=1)[0]
        return candidates[idx]


@register_game_mgr("uniform")
class UniformGameMgr(GameMgr):
    """Uniform over the most recent `recent_n` frozen models (paper §4.2:
    ViZDoom used uniform over the most recent 50)."""

    def __init__(self, recent_n: int = 50, **kw):
        super().__init__(**kw)
        self.recent_n = recent_n

    def get_player(self, learner_key, candidates):
        cand = list(candidates)[-self.recent_n:]
        return self.rng.choice(cand)


@register_game_mgr("pfsp")
class PFSPGameMgr(GameMgr):
    """Prioritized FSP: harder opponents sampled more often."""

    WEIGHTINGS = {
        "linear": lambda p: 1.0 - p,
        "squared": lambda p: (1.0 - p) ** 2,
        "variance": lambda p: p * (1.0 - p),
    }

    def __init__(self, weighting: str = "squared", **kw):
        super().__init__(**kw)
        self.weighting = weighting

    def get_player(self, learner_key, candidates):
        p = self.payoff.winrates_vs(learner_key, candidates)
        w = self.WEIGHTINGS[self.weighting](p) + 1e-6
        return self._choice(list(candidates), w)


@register_game_mgr("sp_pfsp")
class SelfPlayPFSPGameMgr(PFSPGameMgr):
    """35% pure self-play vs current, 65% PFSP vs the pool — the AlphaStar
    Main-Agent mixture; used by the paper's Pommerman experiment."""

    def __init__(self, self_play_frac: float = 0.35, **kw):
        super().__init__(**kw)
        self.self_play_frac = self_play_frac

    def get_opponent(self, learner_key, candidates):
        if not candidates or self.rng.random() < self.self_play_frac:
            return learner_key
        return self.get_player(learner_key, candidates)


@register_game_mgr("elo_match")
class EloMatchGameMgr(GameMgr):
    """Quake-III/PBT style: sample opponents with probability proportional to
    a Gaussian kernel over Elo difference (sigma from the HyperMgr)."""

    def __init__(self, sigma: float = 200.0, **kw):
        super().__init__(**kw)
        self.sigma = sigma

    def get_player(self, learner_key, candidates):
        r0 = self.payoff.elo.get(learner_key, self.payoff.init_elo)
        diffs = np.array([self.payoff.elo.get(c, self.payoff.init_elo) - r0
                          for c in candidates])
        w = np.exp(-0.5 * (diffs / self.sigma) ** 2) + 1e-9
        return self._choice(list(candidates), w)


@register_game_mgr("league_pfsp")
class LeagueExploiterGameMgr(PFSPGameMgr):
    """League-Exploiter [Vinyals et al. 2019]: PFSP over the ENTIRE frozen
    pool, every lineage included — it hunts systemic weaknesses of the whole
    league rather than the main agent specifically. AlphaStar uses the
    'linear' (1-p) weighting here, softer than the main agent's squared."""

    def __init__(self, weighting: str = "linear", **kw):
        super().__init__(weighting=weighting, **kw)


@register_game_mgr("minimax")
class MinimaxExploiterGameMgr(GameMgr):
    """Minimax-Exploiter [arXiv:2311.17190]: a data-efficient exploiter
    curriculum over the target lineage. Instead of always attacking the
    newest (strongest) main model, walk the target's frozen history from
    oldest to newest and play the first model not yet beaten (pool winrate
    < `beat_threshold`) — easy wins first give a dense learning signal, and
    the curriculum advances one rung per conquest until the newest model is
    the only one left."""

    def __init__(self, target_agent_id: str = "main",
                 beat_threshold: float = 0.7, **kw):
        super().__init__(**kw)
        self.target_agent_id = target_agent_id
        self.beat_threshold = beat_threshold

    def get_opponent(self, learner_key, candidates):
        targets = sorted((c for c in candidates
                          if c.agent_id == self.target_agent_id),
                         key=lambda k: k.version)
        if not targets:
            return learner_key
        for t in targets:
            if learner_key not in self.payoff or t not in self.payoff:
                return t                      # no evidence yet: start here
            if self.payoff.winrate(learner_key, t) < self.beat_threshold:
                return t                      # current curriculum rung
        return targets[-1]                    # beat them all: press the newest

    def get_player(self, learner_key, candidates):
        return self.get_opponent(learner_key, candidates)


@register_game_mgr("exploiter")
class ExploiterGameMgr(GameMgr):
    """Agent-Exploiter: always targets the main agent's current model."""

    def __init__(self, target_agent_id: str = "main", **kw):
        super().__init__(**kw)
        self.target_agent_id = target_agent_id

    def get_opponent(self, learner_key, candidates):
        targets = [c for c in candidates if c.agent_id == self.target_agent_id]
        if not targets:
            return learner_key
        return targets[-1]   # most recent main model

    def get_player(self, learner_key, candidates):
        return self.get_opponent(learner_key, candidates)
