"""ModelPool: the concrete neural-net parameter store (§3.2).

The paper runs M_M replicas behind a load balancer with everything
in-memory for instantaneous read/write. On one host that collapses to a
dict, but the API is the paper's: `pull`/`push` for the current learning
params (Actors pull theta and phi periodically; the Learner pushes theta),
`freeze` at learning-period end (theta joins the opponent pool M), and a
replica-pick hook preserved so the microservice semantics stay visible.
"""
from __future__ import annotations

import random
import threading
from typing import Any, Dict, Optional

from repro.core.types import ModelKey


class ModelPool:
    def __init__(self, num_replicas: int = 1, seed: int = 0):
        self.num_replicas = max(1, num_replicas)
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._params: Dict[ModelKey, Any] = {}
        self._frozen: Dict[ModelKey, bool] = {}
        self._step: Dict[ModelKey, int] = {}
        self.read_counts = [0] * self.num_replicas  # replica load-balance bookkeeping

    def _pick_replica(self) -> int:
        r = self._rng.randrange(self.num_replicas)
        self.read_counts[r] += 1
        return r

    # -- API (paper protocol) -------------------------------------------------
    def push(self, key: ModelKey, params: Any, step: int = 0) -> None:
        with self._lock:
            if self._frozen.get(key):
                raise ValueError(f"model {key} is frozen; push refused")
            self._params[key] = params
            self._step[key] = step

    def pull(self, key: ModelKey) -> Any:
        self._pick_replica()
        with self._lock:
            return self._params[key]

    def pull_attr(self, key: ModelKey) -> dict:
        with self._lock:
            return {"step": self._step.get(key, 0), "frozen": self._frozen.get(key, False)}

    def freeze(self, key: ModelKey) -> None:
        with self._lock:
            if key not in self._params:
                raise KeyError(key)
            self._frozen[key] = True

    def keys(self):
        with self._lock:
            return list(self._params)

    def __contains__(self, key: ModelKey):
        return key in self._params

    def __len__(self):
        return len(self._params)
