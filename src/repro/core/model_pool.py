"""ModelPool: the concrete neural-net parameter store (§3.2).

The paper runs M_M replicas behind a load balancer with everything
in-memory for instantaneous read/write. On one host that collapses to a
dict, but the API is the paper's: `pull`/`push` for the current learning
params (Actors pull theta and phi periodically; the Learner pushes theta),
`freeze` at learning-period end (theta joins the opponent pool M), and a
replica-pick hook preserved so the microservice semantics stay visible.

Concurrency contract (the async league runtime hits this from every
worker thread):

* every operation is serialized under one lock — push/pull/freeze are
  linearizable;
* `snapshot_on_pull=True` makes `pull` return a deep copy of the stored
  pytree, so no caller can ever alias a buffer that another thread later
  hands to a donating train step (the PR 1 aliasing-bug class). Callers
  can override per call with `pull(key, copy=...)`.
* `membership_version` bumps whenever the key set changes — cheap
  signatures for callers (LeagueMgr's opponent cache) that want to
  revalidate membership incrementally instead of rescanning per task.
"""
from __future__ import annotations

import random
import threading
from typing import Any, Dict, Optional

from repro.core.types import ModelKey
from repro.utils.pytree import tree_copy


class ModelPool:
    def __init__(self, num_replicas: int = 1, seed: int = 0,
                 snapshot_on_pull: bool = False):
        self.num_replicas = max(1, num_replicas)
        self.snapshot_on_pull = snapshot_on_pull
        self._rng = random.Random(seed)
        self._lock = threading.Lock()
        self._params: Dict[ModelKey, Any] = {}
        self._frozen: Dict[ModelKey, bool] = {}
        self._step: Dict[ModelKey, int] = {}
        self.membership_version = 0          # bumps when the key set changes
        self.read_counts = [0] * self.num_replicas  # replica load-balance bookkeeping

    def _pick_replica(self) -> int:
        r = self._rng.randrange(self.num_replicas)
        self.read_counts[r] += 1
        return r

    # -- API (paper protocol) -------------------------------------------------
    # Contract: every method here takes the pool lock and returns without
    # waiting on anything else — no pool call ever blocks beyond lock
    # contention (there is no capacity limit to wait on).

    def push(self, key: ModelKey, params: Any, step: int = 0) -> None:
        """Store `params` under `key`. Never blocks (lock only). The stored
        object is the caller's pytree, LIVE — the pool does not copy on
        push, so callers must hand over a snapshot if they keep mutating
        (the Learner's `_snapshot` does exactly that) and must never push
        buffers a donating train step may later consume."""
        with self._lock:
            if self._frozen.get(key):
                raise ValueError(f"model {key} is frozen; push refused")
            if key not in self._params:
                self.membership_version += 1
            self._params[key] = params
            self._step[key] = step

    def pull(self, key: ModelKey, copy: Optional[bool] = None) -> Any:
        """Read `key`'s params. Never blocks (lock only). Snapshot vs live:
        with `copy=True` (or `copy=None` under a `snapshot_on_pull` pool)
        the caller gets a deep copy it can own outright; with `copy=False`
        it gets the LIVE stored object — read-only, and never safe to feed
        to a donating train step. Raises KeyError for unknown keys."""
        with self._lock:
            self._pick_replica()
            params = self._params[key]
            if self.snapshot_on_pull if copy is None else copy:
                params = tree_copy(params)
            return params

    def pull_attr(self, key: ModelKey) -> dict:
        """Metadata snapshot (step counter, frozen flag); non-blocking."""
        with self._lock:
            return {"step": self._step.get(key, 0), "frozen": self._frozen.get(key, False)}

    def freeze(self, key: ModelKey) -> None:
        """Mark `key` immutable: later `push`es to it raise. Non-blocking;
        the params themselves are not copied — freezing is a write-bar,
        not a snapshot."""
        with self._lock:
            if key not in self._params:
                raise KeyError(key)
            self._frozen[key] = True

    def keys(self):
        """Snapshot list of hosted keys (stale the moment the lock drops —
        use `membership_version` to detect changes cheaply)."""
        with self._lock:
            return list(self._params)

    def __contains__(self, key: ModelKey):
        return key in self._params

    def __len__(self):
        return len(self._params)
